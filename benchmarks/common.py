"""Shared benchmark utilities: timing + RM fixtures (CPU-sized rows)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.preprocess import pages_from_partition
from repro.core.spec import TransformSpec
from repro.data.synth import RM_CONFIGS, SyntheticRecSysSource

BENCH_ROWS = 1024  # rows per partition for CPU benching (paper: 8192)


def time_call(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median-ish wall time per call in seconds (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def rm_fixture(rm: str, rows: int = BENCH_ROWS):
    """(source, spec, device pages) for one RM config at bench rows."""
    src = SyntheticRecSysSource(RM_CONFIGS[rm], rows=rows)
    spec = TransformSpec.from_source(src)
    pages = {
        k: jnp.asarray(v)
        for k, v in pages_from_partition(src.partition(0), spec).items()
    }
    return src, spec, pages


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
