"""Fig. 13 — inter-node data movement: PreSto eliminates preprocessing
collectives.

Compiles the sharded preprocessing program in all three placements on a
16-device mesh (subprocess) and reports HLO collective bytes: presto must be
ZERO, disagg pays raw-pages-in + train-tensors-out collective-permutes for
every column family, and the cost-model hybrid pays them only for its
host-placed families.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_SCRIPT = """
import json, jax, jax.numpy as jnp
from repro.core.spec import TransformSpec
from repro.core.presto import PreStoEngine
from repro.core.preprocess import pages_from_partition
from repro.data.synth import RMDataConfig, SyntheticRecSysSource
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_mesh
cfg = RMDataConfig("b", 16, 8, 4, 8, 4, 64, 1 << 20, 100000, rows_per_partition=2048)
src = SyntheticRecSysSource(cfg, rows=2048)
spec = TransformSpec.from_source(src)
mesh = make_mesh((8, 2), ("data", "model"))
pages = {k: jnp.asarray(v) for k, v in pages_from_partition(src.partition(0), spec).items()}
out = {}
for placement in ("presto", "hybrid", "disagg"):
    eng = PreStoEngine(spec, mesh, placement=placement)
    txt = jax.jit(eng.preprocess_global).lower(pages).compile().as_text()
    c = analyze(txt)
    out[placement] = {"coll_bytes": c.coll_bytes, "breakdown": c.coll_breakdown,
                      "host_families": list(eng.host_families())}
print("RESULT" + json.dumps(out))
"""


def run() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                          text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    presto = out["presto"]["coll_bytes"]
    disagg = out["disagg"]["coll_bytes"]
    hybrid = out["hybrid"]["coll_bytes"]
    emit("comm/presto_coll_bytes", 0.0, f"bytes={presto:.0f}")
    emit("comm/disagg_coll_bytes", 0.0,
         f"bytes={disagg:.0f} eliminated_by_presto=100%"
         if presto == 0 else f"bytes={disagg:.0f}")
    host_fams = ",".join(out["hybrid"]["host_families"]) or "-"
    emit("comm/hybrid_coll_bytes", 0.0,
         f"bytes={hybrid:.0f} host_families={host_fams}")
    return out


if __name__ == "__main__":
    run()
