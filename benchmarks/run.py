"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Run:
    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


BENCHES = [
    ("latency_breakdown", "Fig 5/12: per-stage ETL latency, Disagg vs PreSto"),
    ("throughput", "Fig 11: preprocessing throughput PreSto vs Disagg(N)"),
    ("scaling", "Fig 3: throughput + consumer utilization vs #workers"),
    ("provisioning", "Fig 4/14: workers to saturate an 8-GPU node (T/P)"),
    ("comm", "Fig 13: collective bytes, presto=0 vs disagg"),
    ("tco", "Fig 15: cost- and energy-efficiency"),
    ("alt", "Fig 16: A100/U280/SmartSSD/v5e alternatives"),
    ("sensitivity", "Fig 17: latency vs #features"),
    ("resources", "Table II: per-kernel VMEM footprint"),
    ("roofline", "SRoofline: dry-run roofline table"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = []
    for name, desc in BENCHES:
        if args.only and name != args.only:
            continue
        print(f"# --- {name}: {desc}", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            mod.run()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
