"""Fig. 17 — sensitivity to the number of features to preprocess.

Sweeps feature counts at 0.25x..2x of the RM5 shape and times the key
operations.  Paper observation to reproduce: CPU-style (unfused multi-pass)
latency grows ~linearly with feature count; the PreSto path's advantage is
robust across the sweep (inter-feature parallelism absorbs features on
hardware; on this host we verify the linear scaling + constant fused ratio).
"""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_call
from repro.core.preprocess import pages_from_partition, preprocess_pages
from repro.core.spec import TransformSpec
from repro.data.synth import RMDataConfig, SyntheticRecSysSource

ROWS = 512


def run() -> dict:
    results = {}
    for scale in (0.25, 0.5, 1.0, 2.0):
        nd = max(int(504 * scale), 4)
        ns = max(int(42 * scale), 2)
        ng = max(int(42 * scale), 2)
        cfg = RMDataConfig(
            f"sens{scale}", nd, ns, 20, 32, ng, 1024, 1 << 24, 500_000,
            rows_per_partition=ROWS,
        )
        src = SyntheticRecSysSource(cfg, rows=ROWS)
        spec = TransformSpec.from_source(src)
        pages = {k: jax.numpy.asarray(v) for k, v in
                 pages_from_partition(src.partition(0), spec).items()}
        fused = jax.jit(lambda p, s=spec: preprocess_pages(p, s, mode="fused"))
        unfused = jax.jit(lambda p, s=spec: preprocess_pages(p, s, mode="unfused"))
        tf, tu = time_call(fused, pages), time_call(unfused, pages)
        emit(f"sensitivity/x{scale}", tu * 1e6,
             f"feats={nd}+{ns}+{ng} fused_us={tf*1e6:.0f} ratio={tu/tf:.2f}")
        results[scale] = {"unfused_s": tu, "fused_s": tf}
    return results


if __name__ == "__main__":
    run()
