"""Fig. 15 — energy-efficiency and cost-efficiency (TCO), paper constants.

cost_efficiency = throughput x duration / (CapEx + OpEx), 3-year duration,
$0.0733/kWh.  Baseline Disagg provisions the paper's published CPU-core
counts; PreSto provisions the published ISP-unit counts; both sustain the
same training throughput (numerators cancel), so the gains are TCO ratios —
validated against the paper's claimed 4.3x avg cost / 11.3x avg energy.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.costmodel import Comparison
from repro.core.planner import (
    PAPER_CORES_REQUIRED_8GPU,
    PAPER_ISP_UNITS_REQUIRED_8GPU,
)


def run() -> dict:
    cost_gains, energy_gains = [], []
    results = {}
    for rm, cores in PAPER_CORES_REQUIRED_8GPU.items():
        units = PAPER_ISP_UNITS_REQUIRED_8GPU[rm]
        cmp = Comparison(rm=rm, T=1.0, cpu_cores=cores, isp_units=units)
        s = cmp.summary()
        cost_gains.append(s["cost_efficiency_gain"])
        energy_gains.append(s["energy_efficiency_gain"])
        emit(f"tco/{rm}", 0.0,
             f"cost_gain={s['cost_efficiency_gain']:.2f}x "
             f"energy_gain={s['energy_efficiency_gain']:.2f}x "
             f"servers={s['cpu_servers']} isp={s['isp_units']}")
        results[rm] = s
    emit("tco/avg", 0.0,
         f"cost_gain={np.mean(cost_gains):.2f}x (paper: 4.3x) "
         f"energy_gain={np.mean(energy_gains):.2f}x (paper: 11.3x)")
    results["avg"] = {
        "cost_gain": float(np.mean(cost_gains)),
        "energy_gain": float(np.mean(energy_gains)),
    }
    return results


if __name__ == "__main__":
    run()
