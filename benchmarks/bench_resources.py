"""Table II analog — per-kernel on-chip (VMEM) footprint of the ISP units.

The paper reports FPGA LUT/BRAM/DSP utilization per unit; the TPU analog is
each Pallas kernel's VMEM working set (in+out blocks x2 for double
buffering) against the ~16 MiB/core budget, plus its arithmetic intensity.
"""

from __future__ import annotations

from benchmarks.common import emit

VMEM_BUDGET = 16 * 2**20  # bytes per TensorCore


def kernel_footprints() -> dict:
    from repro.kernels.bucketize import BOUNDARY_CHUNK, ROW_TILE
    from repro.kernels.decode import G_BLOCK
    from repro.kernels.lognorm import TILE_C, TILE_R
    from repro.kernels.sigridhash import VAL_TILE

    m = 4096  # RM5 bucket size
    w = 24  # RM id width
    return {
        # name: (in_bytes, out_bytes, scratch_bytes, flops_per_byte)
        "decode_bitpack": (G_BLOCK * w * 4, G_BLOCK * 32 * 4, 0, 2.0),
        "decode_bytesplit": (G_BLOCK * 4 * 4, G_BLOCK * 4 * 4, 0, 1.5),
        "bucketize": (ROW_TILE * 4 + m * 4, ROW_TILE * 4,
                      ROW_TILE * BOUNDARY_CHUNK, m / 8.0),
        "sigridhash": (VAL_TILE * 4 + 8, VAL_TILE * 4, 0, 12 / 8.0),
        "lognorm": (TILE_R * TILE_C * 4, TILE_R * TILE_C * 4, 0, 1 / 8.0),
        "fused_dense": (G_BLOCK * 4 * 4, G_BLOCK * 4 * 4, 0, 2.0),
        "fused_sparse": (G_BLOCK * w * 4 + 8, G_BLOCK * 32 * 4, 0, 3.5),
    }


def run() -> dict:
    results = {}
    total = 0
    for name, (i, o, s, ai) in kernel_footprints().items():
        working = 2 * (i + o) + s  # x2: grid pipelining double buffer
        frac = working / VMEM_BUDGET
        total += working
        emit(f"resources/{name}", 0.0,
             f"vmem_bytes={working} vmem_frac={frac:.4f} arith_intensity={ai:.2f}")
        results[name] = {"vmem": working, "frac": frac}
    emit("resources/all_units", 0.0,
         f"vmem_bytes={total} vmem_frac={total / VMEM_BUDGET:.4f} "
         f"(paper Table II: 54% LUT / 48% BRAM)")
    return results


if __name__ == "__main__":
    run()
