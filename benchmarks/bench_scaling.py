"""Fig. 3 — preprocessing throughput + consumer utilization vs #workers.

Runs the real producer-consumer pipeline (PrefetchLoader workers feeding a
DLRM train step) with 1..4 preprocessing workers and reports the effective
throughput and the trainer's utilization, reproducing the paper's
observation that the consumer starves until preprocessing throughput
matches training throughput.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.registry import get_recsys
from repro.core.pipeline import TrainingPipeline
from repro.core.presto import PreStoEngine
from repro.core.spec import TransformSpec
from repro.data.storage import PartitionedStore
from repro.data.synth import SyntheticRecSysSource
from repro.distributed.sharding import ShardingRules
from repro.models import recsys as RS
from repro.train import adamw, make_train_step, warmup_cosine


def run(max_workers: int = 4, partitions: int = 12) -> dict:
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=512)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(partitions, num_devices=4, source=src)
    rules = ShardingRules.make(None)
    opt = adamw(warmup_cosine(1e-3, 5, 200))
    loss_fn = lambda p, b: RS.loss_fn(p, b, rcfg, rules)
    step = jax.jit(make_train_step(loss_fn, opt))
    results = {}
    for workers in range(1, max_workers + 1):
        params = RS.init_params(jax.random.PRNGKey(0), rcfg)
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        engine = PreStoEngine(spec, mesh=None)
        pipe = TrainingPipeline(engine, store, step, num_workers=workers)
        state, stats, _ = pipe.run(state, range(partitions))
        rows_s = stats.steps * 512 / max(stats.wall_time_s, 1e-9)
        emit(f"scaling/workers_{workers}", stats.wall_time_s * 1e6 / stats.steps,
             f"rows_per_s={rows_s:.0f} consumer_util={stats.utilization:.2f}")
        results[workers] = {"rows_s": rows_s, "util": stats.utilization}
    return results


if __name__ == "__main__":
    run()
