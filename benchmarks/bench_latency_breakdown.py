"""Fig. 5 / Fig. 12 — per-stage mini-batch preprocessing latency.

Per RM: lower the operator graph all-host (the Disagg/CPU-style multi-pass
pipeline) and time each lowered graph stage, then time the all-ISP (fused
PreSto) and cost-model hybrid lowerings end-to-end on identical encoded
partitions.  The paper's observation to reproduce: feature generation +
normalization (Bucketize / SigridHash / Log) dominate (~79% on RM2-5) and
the fused ISP path removes the inter-stage traffic.
"""

from __future__ import annotations

import jax

from benchmarks.common import BENCH_ROWS, emit, rm_fixture, time_call
from repro.core.costmodel import choose_placement
from repro.core.opgraph import lower_transform, time_stages

# graph-stage kinds that are "Transform" work (vs Extract/decode and batch
# formation) for the paper's transform-fraction claim
_TRANSFORM_KINDS = {"bucketize", "sigridhash", "lognorm"}


def run(rms=("rm1", "rm2", "rm5")) -> dict:
    results = {}
    for rm in rms:
        src, spec, pages = rm_fixture(rm)

        host_plan = lower_transform(spec, "unfused")
        stage_times = time_stages(host_plan, pages)
        unfused_total = sum(stage_times.values())
        transform_s = sum(
            stage_times[st.name]
            for st in host_plan.stages
            if st.kind in _TRANSFORM_KINDS
        )
        transform_frac = transform_s / unfused_total
        for st in host_plan.stages:
            t = stage_times[st.name]
            emit(f"latency/{rm}/{st.name}", t * 1e6,
                 f"kind={st.kind} frac={t / unfused_total:.3f}")
        emit(f"latency/{rm}/unfused_total", unfused_total * 1e6,
             f"transform_frac={transform_frac:.3f}")

        fused_plan = lower_transform(spec, "fused")
        fused = jax.jit(fused_plan.execute)
        t_fused = time_call(fused, pages)
        speedup = unfused_total / t_fused
        emit(f"latency/{rm}/fused_total", t_fused * 1e6,
             f"fused_speedup={speedup:.2f}x rows={BENCH_ROWS}")

        placements = choose_placement(spec, BENCH_ROWS)
        hybrid_plan = lower_transform(spec, placements)
        t_hybrid = time_call(jax.jit(hybrid_plan.execute), pages)
        host_fams = ",".join(sorted(hybrid_plan.host_families())) or "-"
        emit(f"latency/{rm}/hybrid_total", t_hybrid * 1e6,
             f"host_families={host_fams}")

        results[rm] = {
            "unfused_s": unfused_total, "fused_s": t_fused,
            "hybrid_s": t_hybrid, "hybrid_host_families": host_fams,
            "transform_frac": transform_frac, "speedup": speedup,
            "stages_us": {k: v * 1e6 for k, v in stage_times.items()},
        }
    return results


if __name__ == "__main__":
    run()
