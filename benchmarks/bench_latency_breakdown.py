"""Fig. 5 / Fig. 12 — per-stage mini-batch preprocessing latency.

Per RM: time each ETL stage of the unfused (Disagg/CPU-style) pipeline and
the fused PreSto pipeline on identical encoded partitions.  The paper's
observation to reproduce: feature generation + normalization (Bucketize /
SigridHash / Log) dominate (~79% on RM2-5) and the fused ISP path removes
the inter-stage traffic.
"""

from __future__ import annotations

import jax

from benchmarks.common import BENCH_ROWS, emit, rm_fixture, time_call
from repro.core.preprocess import preprocess_pages, stage_functions


def run(rms=("rm1", "rm2", "rm5")) -> dict:
    results = {}
    for rm in rms:
        src, spec, pages = rm_fixture(rm)
        stages = stage_functions(spec)

        t_decode = time_call(stages["extract_decode"], pages)
        dense_raw, sparse_raw = stages["extract_decode"](pages)
        t_bucket = time_call(stages["gen_bucketize"], dense_raw)
        bucket_ids = stages["gen_bucketize"](dense_raw)
        t_hash = time_call(stages["norm_sigridhash"], sparse_raw, bucket_ids)
        hashed, gen_hashed = stages["norm_sigridhash"](sparse_raw, bucket_ids)
        t_log = time_call(stages["norm_log"], dense_raw)
        dense_norm = stages["norm_log"](dense_raw)
        t_form = time_call(
            stages["form_minibatch"], pages, dense_norm, hashed, gen_hashed
        )
        unfused_total = t_decode + t_bucket + t_hash + t_log + t_form

        fused = jax.jit(lambda p: preprocess_pages(p, spec, mode="fused"))
        t_fused = time_call(fused, pages)

        transform_frac = (t_bucket + t_hash + t_log) / unfused_total
        speedup = unfused_total / t_fused
        for stage, t in [
            ("extract_decode", t_decode), ("gen_bucketize", t_bucket),
            ("norm_sigridhash", t_hash), ("norm_log", t_log),
            ("form_minibatch", t_form),
        ]:
            emit(f"latency/{rm}/{stage}", t * 1e6,
                 f"frac={t / unfused_total:.3f}")
        emit(f"latency/{rm}/unfused_total", unfused_total * 1e6,
             f"transform_frac={transform_frac:.3f}")
        emit(f"latency/{rm}/fused_total", t_fused * 1e6,
             f"fused_speedup={speedup:.2f}x rows={BENCH_ROWS}")
        results[rm] = {
            "unfused_s": unfused_total, "fused_s": t_fused,
            "transform_frac": transform_frac, "speedup": speedup,
        }
    return results


if __name__ == "__main__":
    run()
