"""Fig. 16 — alternative accelerated preprocessing (A100 / U280 / PreSto).

Analytical reproduction anchored on the paper's published relationships:
PreSto(SmartSSD) = 2.5x A100 throughput, ~0.95x U280, with TDPs 25/250/225W;
PreSto(U280) slightly faster but 2.9x worse perf/W.  We add the
TPU-adaptation design point: preprocessing as a fraction of a v5e chip,
using OUR measured fused-kernel throughput and the roofline byte model
(preprocessing is HBM-bound at ~3.4 B/row/feature, so a v5e shard sustains
~the paper's per-SmartSSD rate at <2% chip occupancy — the storage-centric
placement costs almost nothing when fused into the training step).
"""

from __future__ import annotations

import jax

from benchmarks.common import BENCH_ROWS, emit, rm_fixture, time_call
from repro.core.preprocess import preprocess_pages
from repro.launch.roofline import HBM_BW

PAPER_POINTS = {
    # relative throughput vs PreSto(SmartSSD)=1.0, TDP watts
    "a100": (1 / 2.5, 250.0),
    "u280": (1.05, 225.0),
    "presto_u280": (1.08, 225.0),
    "presto_smartssd": (1.0, 25.0),
}


def run() -> dict:
    results = {}
    for name, (rel, watts) in PAPER_POINTS.items():
        emit(f"alt/{name}", 0.0,
             f"rel_throughput={rel:.2f} tdp_w={watts:.0f} "
             f"perf_per_w={rel / watts * 25.0:.2f} (vs smartssd=1)")
        results[name] = {"rel": rel, "watts": watts}

    # TPU-shard design point from measured kernels + roofline bytes
    src, spec, pages = rm_fixture("rm5")
    fused = jax.jit(lambda p: preprocess_pages(p, spec, mode="fused"))
    t = time_call(fused, pages)
    enc_bytes = sum(int(v.nbytes) for v in pages.values())
    out_bytes = BENCH_ROWS * (
        spec.cfg.n_dense * 4
        + spec.cfg.n_sparse * spec.cfg.max_sparse_len * 4
        + spec.cfg.n_generated * 4
    )
    bytes_per_row = (enc_bytes + out_bytes) / BENCH_ROWS
    # v5e: preprocessing is memory-bound; rows/s at full HBM
    v5e_rows_s = HBM_BW / bytes_per_row
    emit("alt/v5e_shard_roofline", t * 1e6,
         f"bytes_per_row={bytes_per_row:.0f} "
         f"roofline_rows_per_s={v5e_rows_s:.2e} "
         f"chip_frac_for_8192rows_per_s={8192 / v5e_rows_s:.4f}")
    results["v5e"] = {"bytes_per_row": bytes_per_row, "rows_s": v5e_rows_s}
    return results


if __name__ == "__main__":
    run()
