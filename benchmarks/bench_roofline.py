"""§Roofline report — reads results/dryrun.jsonl and prints the per-cell
roofline table (compute/memory/collective terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio, roofline fraction)."""

from __future__ import annotations

import json
import os

from benchmarks.common import emit

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")


def load(path: str = DEFAULT_PATH) -> list:
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return list(recs.values())


def run(path: str = DEFAULT_PATH) -> dict:
    recs = load(path)
    if not recs:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return {}
    ok = [r for r in recs if r["status"] == "ok"]
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        t = r["roofline"]
        emit(
            f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}",
            t["compute_s"] * 1e6,
            f"mem_us={t['memory_s']*1e6:.0f} coll_us={t['collective_s']*1e6:.0f} "
            f"dominant={t['dominant']} useful={t['useful_ratio']:.3f} "
            f"rf={t['roofline_fraction']:.3f}",
        )
    skipped = [r for r in recs if r["status"] == "skip"]
    errors = [r for r in recs if r["status"] == "error"]
    emit("roofline/summary", 0.0,
         f"ok={len(ok)} skip={len(skipped)} errors={len(errors)}")
    return {"ok": len(ok), "skip": len(skipped), "errors": len(errors)}


if __name__ == "__main__":
    run()
