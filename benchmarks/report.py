"""Render results/dryrun.jsonl as the EXPERIMENTS.md roofline tables."""

from __future__ import annotations

import json
import sys

from benchmarks.bench_roofline import DEFAULT_PATH, load


def gib(b: float) -> str:
    return f"{b / 2**30:.2f}"


def table(mesh: str, recs: list) -> str:
    rows = [
        "| arch | shape | c (ms) | m (ms) | x (ms) | dominant | temp GiB/dev "
        "| args GiB/dev | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | *skipped:* "
                f"{r['reason'][:40]} | — | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR: {r['reason'][:50]} |")
            continue
        t = r["roofline"]
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.1f} "
            f"| {t['memory_s']*1e3:.1f} | {t['collective_s']*1e3:.1f} "
            f"| {t['dominant']} | {gib(m['temp_bytes'])} "
            f"| {gib(m['argument_bytes'])} | {t['useful_ratio']:.2f} "
            f"| {t['roofline_fraction']:.3f} |"
        )
    return "\n".join(rows)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH
    recs = load(path)
    for mesh in ("single", "multi"):
        n_ok = sum(1 for r in recs if r["mesh"] == mesh and r["status"] == "ok")
        n_skip = sum(1 for r in recs if r["mesh"] == mesh and r["status"] == "skip")
        print(f"\n### {mesh}-pod mesh ({n_ok} compiled, {n_skip} skipped)\n")
        print(table(mesh, recs))


if __name__ == "__main__":
    main()
