"""Hillclimb profiling tool: per-collective and per-dot attribution with
loop-trip multipliers, from a compiled cell's HLO.

  PYTHONPATH=src python -m benchmarks.hlo_walk --arch glm4-9b --shape train_4k
"""

from __future__ import annotations

import argparse
import os
from collections import defaultdict


def walk_cell(arch: str, shape: str, multi_pod: bool = False, top: int = 18):
    import jax

    from repro.configs.registry import get_arch
    from repro.launch import hlo_cost as hc
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell

    cfg = get_arch(arch).config
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = build_cell(cfg, shape, mesh)
    with mesh:
        compiled = (
            jax.jit(spec.fn, in_shardings=spec.in_shardings)
            .lower(*spec.args)
            .compile()
        )
    txt = compiled.as_text()
    model = hc.HloCostModel(txt)
    comps = model.comps
    colls: dict = defaultdict(float)
    dots: dict = defaultdict(float)

    def collect(comp_name, scale):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            kind = None
            for k in hc._COLLECTIVES:
                if ins.op == k or ins.op == k + "-start":
                    kind = k
            if kind:
                payload = max(
                    hc._operand_bytes(ins, comp), hc._type_numel_bytes(ins.type_str)
                )
                colls[f"{kind} {ins.type_str[:52]}"] += payload * scale
            elif ins.op == "dot":
                dots[f"dot {ins.type_str[:52]}"] += hc._dot_flops(ins, comp) * scale
            elif ins.op == "while":
                m = hc._TRIP_RE.search(ins.attrs)
                trips = int(m.group(1)) if m else 1
                b = hc._BODY_RE.search(ins.attrs)
                if b:
                    collect(b.group(1), scale * trips)
            elif ins.op in ("fusion", "call"):
                m = hc._CALLS_RE.search(ins.attrs)
                if m:
                    collect(m.group(1), scale)

    collect("__entry__", 1)
    total = model.total()
    print(f"{arch} x {shape}: flops={total.flops/1e12:.2f}T "
          f"hbm={total.hbm_bytes/1e12:.3f}TB coll={total.coll_bytes/1e9:.1f}GB")
    print("-- collectives (bytes x trips) --")
    for k, v in sorted(colls.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {v/1e9:8.2f} GB  {k}")
    print("-- dots (flops x trips) --")
    for k, v in sorted(dots.items(), key=lambda kv: -kv[1])[:10]:
        print(f"  {v/1e12:8.2f} T   {k}")
    return compiled


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi", action="store_true")
    args = ap.parse_args()
    walk_cell(args.arch, args.shape, args.multi)


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    main()
