"""Fig. 11 — preprocessing throughput: PreSto (fused, 1 unit) vs Disagg(N).

Measured: fused vs unfused end-to-end rows/s on this host (the fused/unfused
ratio is the hardware-independent fraction).  Fleet-scale Disagg(N) follows
the paper's own analytical model: per-worker throughput scales linearly with
N workers; the paper's published equivalence (ISP unit ~ cores) anchors the
cross-hardware comparison in bench_provisioning / bench_tco.

``--multi-tenant`` benches the service surface instead: J jobs sharing one
``PreprocessingService`` pool vs the same jobs run solo, reporting per-job
and aggregate rows/s (the multi-user deployment the T/P planner provisions).
"""

from __future__ import annotations

import argparse
import threading
import time

import jax

from benchmarks.common import BENCH_ROWS, emit, rm_fixture, time_call
from repro.core.preprocess import preprocess_pages
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.storage import PartitionedStore
from repro.data.synth import RM_CONFIGS, SyntheticRecSysSource


def run(rms=("rm1", "rm2", "rm5")) -> dict:
    results = {}
    for rm in rms:
        src, spec, pages = rm_fixture(rm)
        fused = jax.jit(lambda p: preprocess_pages(p, spec, mode="fused"))
        unfused = jax.jit(lambda p: preprocess_pages(p, spec, mode="unfused"))
        tf = time_call(fused, pages)
        tu = time_call(unfused, pages)
        rows_s_f = BENCH_ROWS / tf
        rows_s_u = BENCH_ROWS / tu
        emit(f"throughput/{rm}/fused", tf * 1e6, f"rows_per_s={rows_s_f:.0f}")
        emit(f"throughput/{rm}/unfused", tu * 1e6, f"rows_per_s={rows_s_u:.0f}")
        # Disagg(N) analytical: N x single-worker unfused throughput
        for n in (1, 8, 32, 64):
            emit(f"throughput/{rm}/disagg_{n}", tu * 1e6 / n,
                 f"rows_per_s={rows_s_u * n:.0f} (paper linear-scaling model)")
        results[rm] = {"fused_rows_s": rows_s_f, "unfused_rows_s": rows_s_u}
    return results


def run_multi_tenant(
    rm: str = "rm1",
    *,
    jobs: int = 2,
    workers: int = 2,
    partitions_per_job: int = 4,
    rows: int = BENCH_ROWS,
) -> dict:
    """Service-level throughput: J tenants on one pool vs each tenant solo."""
    workers = max(workers, jobs)  # admission floor: one unit per tenant
    src = SyntheticRecSysSource(RM_CONFIGS[rm], rows=rows)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(jobs * partitions_per_job, num_devices=4, source=src)
    engine = PreStoEngine(spec)  # shared jit cache: solo and shared runs
    ranges = {
        f"{rm}-t{j}": range(j * partitions_per_job, (j + 1) * partitions_per_job)
        for j in range(jobs)
    }

    def job_spec(name: str) -> JobSpec:
        return JobSpec(name=name, partitions=ranges[name], engine=engine,
                       store=store, units=workers)

    def drain(session, sink: dict) -> None:
        t0 = time.perf_counter()
        sink["batches"] = sum(1 for _ in session)
        sink["wall_s"] = time.perf_counter() - t0

    engine.produce_batch(store, 0)  # compile outside the timed region
    solo_rows_s = {}
    for name in ranges:
        with PreprocessingService(num_workers=workers) as svc:
            sink: dict = {}
            drain(svc.submit(job_spec(name)), sink)
        solo_rows_s[name] = rows * sink["batches"] / sink["wall_s"]
        emit(f"throughput/{rm}/solo/{name}", sink["wall_s"] * 1e6 / sink["batches"],
             f"rows_per_s={solo_rows_s[name]:.0f}")

    with PreprocessingService(num_workers=workers) as svc:
        sinks = {name: {} for name in ranges}
        t0 = time.perf_counter()
        threads = [
            threading.Thread(target=drain, args=(svc.submit(job_spec(n)), sinks[n]))
            for n in ranges
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        shared_wall = time.perf_counter() - t0

    total_batches = sum(s["batches"] for s in sinks.values())
    agg_rows_s = rows * total_batches / shared_wall
    for name, sink in sinks.items():
        emit(f"throughput/{rm}/shared/{name}", sink["wall_s"] * 1e6 / sink["batches"],
             f"rows_per_s={rows * sink['batches'] / sink['wall_s']:.0f}")
    emit(f"throughput/{rm}/shared/aggregate", shared_wall * 1e6 / total_batches,
         f"rows_per_s={agg_rows_s:.0f} jobs={jobs} workers={workers}")
    return {"solo_rows_s": solo_rows_s, "aggregate_rows_s": agg_rows_s}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--multi-tenant", action="store_true",
                    help="bench the shared-pool service surface")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: small rows/partitions")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()
    if args.multi_tenant:
        run_multi_tenant(
            jobs=args.jobs,
            workers=args.workers,
            partitions_per_job=2 if args.smoke else 4,
            rows=256 if args.smoke else BENCH_ROWS,
        )
    else:
        run()
