"""Fig. 11 — preprocessing throughput: PreSto (fused, 1 unit) vs Disagg(N).

Measured: fused vs unfused end-to-end rows/s on this host (the fused/unfused
ratio is the hardware-independent fraction).  Fleet-scale Disagg(N) follows
the paper's own analytical model: per-worker throughput scales linearly with
N workers; the paper's published equivalence (ISP unit ~ cores) anchors the
cross-hardware comparison in bench_provisioning / bench_tco.

``--multi-tenant`` benches the service surface instead: J jobs sharing one
``PreprocessingService`` pool vs the same jobs run solo, reporting per-job
and aggregate rows/s (the multi-user deployment the T/P planner provisions).

``--cache`` adds the content-addressed feature cache (core.featcache) to the
shared pool and gives tenants ``--overlap``-fraction overlapping partition
ranges: the same multi-tenant run is timed twice, cold (no cache) and with a
fresh shared cache, reporting the cross-tenant dedup hit rate and the total-
preprocessing-time speedup the cache buys.

``--skew <zipf-alpha>`` benches device-aware scheduling: one job over a
device fleet whose partition->device ownership follows a Zipf(alpha) quota
(Meta's ingestion skew), run three ways — uniform ownership, skewed with
locality-blind round-robin, and skewed with locality-aware routing + host
fallback.  Throughput here is MODELED end-to-end (each simulated device
serializes its ledger; the host pool parallelizes): real wall time cannot
see simulated contention, the ledgers can.  Every delivered batch is
asserted bitwise identical across all three runs, and the routed run must
beat the blind run's makespan with a non-zero host-fallback count.

``--pipeline`` benches the zero-stall produce path: the strictly serial
per-partition loop (read -> page-build -> one solo launch -> block) against
``PreStoEngine.produce_stream`` — megabatched launches (K partitions, one
kernel dispatch) with the next chunk's read/page-build double-buffered
behind the in-flight kernel.  Sweeps megabatch K with overlap on and off
plus lookahead depth (how many staged chunks queue behind the in-flight
kernel), asserts every configuration bitwise identical to the serial run
(with the process-wide executable cache on AND off), asserts the best
pipelined config at least matches serial throughput, and writes the whole
sweep to a ``BENCH_throughput_pipeline.json`` artifact so the perf
trajectory is tracked.

``--autotune`` benches the self-tuning produce path through the service
surface: static megabatch-K sessions for every rung of the power-of-two
ladder vs one session with the online ``MegabatchTuner`` enabled (seeded
from the cost model, hill-climbing K from measured launch timings).
Asserts the tuned K lands within one ladder step of the best static K,
autotuned throughput beats the serial loop and stays within noise of the
best static session, and every mode — autotune on/off, lookahead 1/2/4,
cache pre-warm on/off — delivers batches bitwise identical to the serial
reference.  Writes a ``BENCH_throughput_autotune.json`` artifact (each mode
has its own default so the two sweeps never clobber each other; ``--out``
overrides).

``--dedup`` benches sample-level dedup (RecD): dup-factor-d datasets whose
sparse feature blocks repeat d times per session, staged in dedup form
(unique blocks + per-sample refs) vs the same logical rows staged flat.
Per dup factor it reports bytes moved off storage (unique vs logical, from
the store ledgers), modeled ops/ISP-seconds savings (the dedup-aware cost
model), and the measured stage+transform speedup — asserting every produce
mode (solo, megabatch, pipelined stream, shared-service with the block
cache) bitwise identical to the inflated-classic reference, that measured
byte savings match the schema's unique fraction, and speedup > 1x at the
top dup factor.  Writes ``BENCH_throughput_dedup.json``.

``--sim`` benches nothing on this host at all: it runs a ``--sessions``-job
multi-tenant schedule through the discrete-event sim engine (core.simclock)
in virtual time — Zipf-skewed session sizes, seeded arrivals, per-QoS-class
deadlines — comparing SLO-aware admission (reject/degrade up front, rc
preempts exploratory) against a FIFO baseline that admits everything and
starves the tail.  Asserts byte-identical event traces on same-seed replay
and zero starvation under SLO admission; writes ``BENCH_sim_slo.json``.
"""

from __future__ import annotations

import argparse
import json
import math
import threading
import time

import jax
import numpy as np

import dataclasses

from benchmarks.common import BENCH_ROWS, emit, rm_fixture, time_call
from repro.core.autotune import k_ladder
from repro.core.costmodel import (
    DEFAULT_PLACEMENT_MODEL,
    ContentionAwareCostModel,
    partition_costs,
)
from repro.core.execcache import EXECUTABLES
from repro.core.featcache import FeatureCache
from repro.core.preprocess import pages_from_partition, preprocess_pages
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.columnar import inflate_partition
from repro.data.storage import DeviceFleet, PartitionedStore, zipf_owner_map
from repro.data.synth import RM_CONFIGS, SyntheticRecSysSource

EPILOG = """\
modes:
  (default)                  fused-vs-unfused single-tenant throughput (Fig. 11)
  --multi-tenant             J tenants on one shared service pool vs solo runs
  --multi-tenant --cache     tenants overlap by --overlap; timed without and
                             with a shared content-addressed feature cache;
                             reports dedup hit rate + total-time speedup
  --multi-tenant --no-cache  overlapping tenants, uncached baseline only
  --skew A                   Zipf(A)-skewed partition ownership over --devices
                             simulated ISP devices: locality-blind round-robin
                             vs device-aware routing + host fallback; reports
                             per-device occupancy and the modeled end-to-end
                             speedup (asserts bitwise-identical batches and a
                             non-zero fallback count under skew)

  --pipeline                 zero-stall produce path: serial loop vs
                             megabatched + double-buffered produce_stream;
                             sweeps megabatch K and lookahead depth, asserts
                             bitwise identity (executable cache on and off)
                             and pipelined >= serial; writes
                             BENCH_throughput_pipeline.json

  --autotune                 self-tuning produce path: static-K service
                             sessions vs one session with the online
                             MegabatchTuner; asserts tuned K within one
                             ladder step of the best static K, autotuned >
                             serial, and bitwise identity across autotune /
                             lookahead / pre-warm modes; writes
                             BENCH_throughput_autotune.json

  --dedup                    sample-level dedup (RecD): dup-factor sweep of
                             unique-block staging vs flat staging; reports
                             bytes-moved + modeled ops savings + measured
                             speedup, asserts bitwise identity in every
                             produce mode; writes BENCH_throughput_dedup.json

  --sim                      multi-tenant schedule in VIRTUAL time (no real
                             sleeps): --sessions Zipf-skewed sessions with
                             deadlines, SLO-aware admission vs a FIFO
                             baseline; reports per-QoS-class SLO attainment
                             + modeled makespan, asserts byte-identical
                             same-seed trace replay and zero starvation
                             under SLO admission; writes BENCH_sim_slo.json

  --chaos                    storage fault domain drill: seeded I/O chaos
                             (transient reads, torn blocks, spill
                             corruption, slow reads, one device offline)
                             against every produce mode; asserts each
                             fault-injected run delivers batches bitwise
                             identical to the fault-free reference, an
                             offline device fails over, and a poisoned
                             store surfaces a structured SessionError
                             within the retry budget; writes
                             BENCH_throughput_chaos.json

examples:
  PYTHONPATH=src python -m benchmarks.bench_throughput --multi-tenant --smoke
  PYTHONPATH=src python -m benchmarks.bench_throughput \\
      --multi-tenant --smoke --cache --overlap 0.5
  PYTHONPATH=src python -m benchmarks.bench_throughput --skew 1.1 --smoke
  PYTHONPATH=src python -m benchmarks.bench_throughput --pipeline --smoke
  PYTHONPATH=src python -m benchmarks.bench_throughput --autotune --smoke
  PYTHONPATH=src python -m benchmarks.bench_throughput --sim --sessions 1000
  PYTHONPATH=src python -m benchmarks.bench_throughput --chaos --smoke
"""


def run(rms=("rm1", "rm2", "rm5")) -> dict:
    results = {}
    for rm in rms:
        src, spec, pages = rm_fixture(rm)
        fused = jax.jit(lambda p: preprocess_pages(p, spec, mode="fused"))
        unfused = jax.jit(lambda p: preprocess_pages(p, spec, mode="unfused"))
        tf = time_call(fused, pages)
        tu = time_call(unfused, pages)
        rows_s_f = BENCH_ROWS / tf
        rows_s_u = BENCH_ROWS / tu
        emit(f"throughput/{rm}/fused", tf * 1e6, f"rows_per_s={rows_s_f:.0f}")
        emit(f"throughput/{rm}/unfused", tu * 1e6, f"rows_per_s={rows_s_u:.0f}")
        # Disagg(N) analytical: N x single-worker unfused throughput
        for n in (1, 8, 32, 64):
            emit(f"throughput/{rm}/disagg_{n}", tu * 1e6 / n,
                 f"rows_per_s={rows_s_u * n:.0f} (paper linear-scaling model)")
        results[rm] = {"fused_rows_s": rows_s_f, "unfused_rows_s": rows_s_u}
    return results


def tenant_ranges(jobs: int, partitions_per_job: int, overlap: float) -> dict:
    """Per-tenant partition windows overlapping by `overlap` fraction.

    Tenant j starts at j*stride where stride = round(ppj * (1 - overlap)),
    so consecutive tenants share ~overlap of their partitions (the RecD-style
    re-preprocessing the feature cache deduplicates)."""
    stride = max(1, round(partitions_per_job * (1.0 - overlap)))
    return {j: range(j * stride, j * stride + partitions_per_job) for j in range(jobs)}


def run_multi_tenant(
    rm: str = "rm1",
    *,
    jobs: int = 2,
    workers: int = 2,
    partitions_per_job: int = 4,
    rows: int = BENCH_ROWS,
    overlap: float = 0.0,
    cache: bool | None = None,
) -> dict:
    """Service-level throughput: J tenants on one pool vs each tenant solo.

    cache=None: the PR-2 bench (disjoint tenants, solo-vs-shared).
    cache=False: overlapping tenants, uncached shared run only.
    cache=True: overlapping tenants timed uncached AND with a fresh shared
    ``FeatureCache`` — reports the cross-tenant dedup hit rate and speedup.
    """
    workers = max(workers, jobs)  # admission floor: one unit per tenant
    src = SyntheticRecSysSource(RM_CONFIGS[rm], rows=rows)
    spec = TransformSpec.from_source(src)
    windows = tenant_ranges(jobs, partitions_per_job, overlap)
    num_partitions = max(w.stop for w in windows.values())
    store = PartitionedStore(num_partitions, num_devices=4, source=src)
    engine = PreStoEngine(spec)  # shared jit cache: every run compiles once
    ranges = {f"{rm}-t{j}": windows[j] for j in range(jobs)}

    def job_spec(name: str) -> JobSpec:
        return JobSpec(name=name, partitions=ranges[name], engine=engine,
                       store=store, units=workers)

    def drain(session, sink: dict) -> None:
        t0 = time.perf_counter()
        sink["batches"] = sum(1 for _ in session)
        sink["wall_s"] = time.perf_counter() - t0
        st = session.stats()
        sink["produce_s"] = st.produce_time_s  # pool-worker preprocess seconds
        sink["cache_hits"] = st.cache_hits

    def shared_run(feature_cache=None):
        with PreprocessingService(num_workers=workers,
                                  cache=feature_cache) as svc:
            sinks = {name: {} for name in ranges}
            t0 = time.perf_counter()
            threads = [
                threading.Thread(target=drain,
                                 args=(svc.submit(job_spec(n)), sinks[n]))
                for n in ranges
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
        return wall, sinks

    engine.produce_batch(store, 0)  # compile outside the timed region
    results: dict = {}

    if cache is None:
        solo_rows_s = {}
        for name in ranges:
            with PreprocessingService(num_workers=workers) as svc:
                sink: dict = {}
                drain(svc.submit(job_spec(name)), sink)
            solo_rows_s[name] = rows * sink["batches"] / sink["wall_s"]
            emit(f"throughput/{rm}/solo/{name}",
                 sink["wall_s"] * 1e6 / sink["batches"],
                 f"rows_per_s={solo_rows_s[name]:.0f}")
        results["solo_rows_s"] = solo_rows_s

    shared_wall, sinks = shared_run()
    total_batches = sum(s["batches"] for s in sinks.values())
    agg_rows_s = rows * total_batches / shared_wall
    for name, sink in sinks.items():
        emit(f"throughput/{rm}/shared/{name}",
             sink["wall_s"] * 1e6 / sink["batches"],
             f"rows_per_s={rows * sink['batches'] / sink['wall_s']:.0f}")
    emit(f"throughput/{rm}/shared/aggregate", shared_wall * 1e6 / total_batches,
         f"rows_per_s={agg_rows_s:.0f} jobs={jobs} workers={workers} "
         f"overlap={overlap:.2f}")
    nocache_produce = sum(s["produce_s"] for s in sinks.values())
    results["aggregate_rows_s"] = agg_rows_s
    results["nocache_wall_s"] = shared_wall
    results["nocache_produce_s"] = nocache_produce

    if cache:
        # Alternate uncached and (fresh-)cached rounds and take best-of per
        # mode: process-level drift (allocator/GC/thermal) otherwise taxes
        # whichever phase runs later, drowning the dedup signal at smoke
        # sizes.  The first uncached round above joins the pool.
        nc_walls, nc_produce = [shared_wall], [nocache_produce]
        c_walls, c_produce, c_stats = [], [], []
        for _ in range(3):
            feature_cache = FeatureCache(capacity_bytes=1 << 30)
            w, csinks = shared_run(feature_cache)
            c_walls.append(w)
            c_produce.append(sum(s["produce_s"] for s in csinks.values()))
            c_stats.append((feature_cache.stats(), csinks))
            w, nsinks = shared_run()
            nc_walls.append(w)
            nc_produce.append(sum(s["produce_s"] for s in nsinks.values()))
        cstats, csinks = c_stats[0]  # every cached round behaves alike
        ctotal = sum(s["batches"] for s in csinks.values())
        cached_wall, cached_produce = min(c_walls), min(c_produce)
        shared_wall, nocache_produce = min(nc_walls), min(nc_produce)
        # keep the returned dict coherent with the printed best-of numbers
        results["nocache_wall_s"] = shared_wall
        results["nocache_produce_s"] = nocache_produce
        dedup = cstats.hits + cstats.follows  # claims served without produce
        emit(f"throughput/{rm}/shared_cache/aggregate",
             cached_wall * 1e6 / ctotal,
             f"rows_per_s={rows * ctotal / cached_wall:.0f} "
             f"dedup_hits={dedup} hit_rate={cstats.hit_rate:.2f}")
        speedup = nocache_produce / max(cached_produce, 1e-9)
        print(f"cache: dedup_hits={dedup} (finished={cstats.hits} "
              f"in_flight={cstats.follows}) probes={cstats.probes} "
              f"hit_rate={cstats.hit_rate:.2f} "
              f"produces {cstats.probes}->{cstats.misses} per round")
        print(f"cache: total_preprocess_time no-cache={nocache_produce:.3f}s "
              f"cache={cached_produce:.3f}s speedup={speedup:.2f}x "
              f"(wall {shared_wall:.3f}s -> {cached_wall:.3f}s; best of "
              f"{len(nc_walls)}/{len(c_walls)} alternating rounds)")
        results.update(
            cache_wall_s=cached_wall,
            cache_produce_s=cached_produce,
            dedup_hits=dedup,
            hit_rate=cstats.hit_rate,
            speedup=speedup,
        )
    return results


def run_skew(
    rm: str = "rm1",
    *,
    devices: int = 4,
    alpha: float = 1.1,
    partitions: int = 32,
    rows: int = BENCH_ROWS,
    seed: int = 0,
) -> dict:
    """Uniform vs Zipf-skewed partition popularity, with/without fallback.

    Three runs of ONE job over `partitions` partitions on `devices`
    simulated ISP devices (fresh ledgers each):

    * ``uniform`` — round-robin ownership, device-aware routing (reference
      batches; fallback must never fire: no device is past the threshold).
    * ``blind``   — Zipf(alpha) ownership, locality-blind round-robin: every
      produce still runs on the owning device, so the hot device's ledger
      serializes most of the job.
    * ``routed``  — same ownership, locality-aware claims + host fallback.

    Modeled end-to-end seconds = max(per-device busy, host busy / fleet
    size).  Asserts the acceptance criterion: routed beats blind under skew
    while every batch stays bitwise identical to the uniform run.
    """
    src = SyntheticRecSysSource(RM_CONFIGS[rm], rows=rows)
    spec = TransformSpec.from_source(src)
    engine = PreStoEngine(spec)  # shared jit cache: every run compiles once
    # a fallback candidate waits behind the OTHER claims bound to its
    # device (queue_depth - 1 <= ceil(P/D) - 1 under uniform ownership), so
    # ceil(P/D) is the tightest threshold fallback can never cross until
    # skew concentrates ownership past it
    threshold = math.ceil(partitions / devices)
    skew_map = zipf_owner_map(partitions, devices, alpha=alpha, seed=seed)
    hot = max(skew_map.count(d) for d in range(devices))
    model = ContentionAwareCostModel(queue_threshold=threshold)

    def one_run(owner_map, locality: bool):
        fleet = DeviceFleet.from_cost_model(devices, model)
        store = PartitionedStore(
            partitions, num_devices=devices, source=src, fleet=fleet,
            owner_map=owner_map,
        )
        t0 = time.perf_counter()
        with PreprocessingService(
            num_workers=devices, devices=fleet, locality=locality,
            cost_model=model,
        ) as svc:
            sess = svc.submit(JobSpec(
                name=f"{rm}-skew", partitions=range(partitions), engine=engine,
                store=store, units=devices, queue_depth=partitions,
            ))
            out = {pid: mb for pid, mb in sess}
            st = sess.stats()
        wall = time.perf_counter() - t0
        return out, st, fleet, wall

    engine.produce_batch(
        PartitionedStore(partitions, num_devices=devices, source=src), 0
    )  # compile outside every run
    print(f"skew bench: {rm} {partitions}x{rows}-row partitions on {devices} "
          f"devices, zipf alpha={alpha} (hot device owns {hot}), "
          f"queue threshold={threshold}")

    runs = {
        "uniform": one_run(None, True),
        "blind": one_run(skew_map, False),
        "routed": one_run(skew_map, True),
    }
    results: dict = {"alpha": alpha, "hot_partitions": hot}
    total_rows = rows * partitions
    for name, (out, st, fleet, wall) in runs.items():
        makespan = fleet.makespan_s(host_parallelism=devices)
        modeled_rows_s = total_rows / max(makespan, 1e-12)
        emit(f"throughput/{rm}/skew/{name}", makespan * 1e6,
             f"modeled_rows_per_s={modeled_rows_s:.0f} "
             f"fallbacks={st.host_fallbacks} wall_s={wall:.2f}")
        results[name] = {
            "makespan_s": makespan,
            "modeled_rows_s": modeled_rows_s,
            "host_fallbacks": st.host_fallbacks,
            "device_busy_s": [d.busy_s for d in fleet],
        }

    print(f"\n{'run':<9} {'modeled rows/s':>14} {'makespan':>10} "
          f"{'hot-dev busy':>12} {'fallbacks':>9}")
    for name, (out, st, fleet, wall) in runs.items():
        makespan = fleet.makespan_s(host_parallelism=devices)
        print(f"{name:<9} {total_rows / max(makespan, 1e-12):>14.0f} "
              f"{makespan * 1e3:>8.2f}ms {fleet.max_busy_s() * 1e3:>10.2f}ms "
              f"{st.host_fallbacks:>9}")

    # the correctness anchor: routing never changes batch bytes
    uniform_out = runs["uniform"][0]
    for name in ("blind", "routed"):
        out = runs[name][0]
        assert sorted(out) == sorted(uniform_out), f"{name} lost partitions"
        for pid, mb in uniform_out.items():
            for key in mb:
                np.testing.assert_array_equal(
                    np.asarray(mb[key]), np.asarray(out[pid][key]),
                    err_msg=f"{name} pid={pid} key={key} diverged under skew")
    print("bitwise: blind == routed == uniform for every delivered batch")

    if alpha > 0:
        routed, blind = results["routed"], results["blind"]
        assert routed["host_fallbacks"] > 0, (
            "skewed ownership past the queue threshold must trigger host "
            "fallback")
        assert routed["makespan_s"] < blind["makespan_s"], (
            "device-aware routing must beat locality-blind round-robin "
            f"under skew ({routed['makespan_s']:.6f}s vs "
            f"{blind['makespan_s']:.6f}s)")
        speedup = blind["makespan_s"] / routed["makespan_s"]
        results["speedup"] = speedup
        print(f"device-aware routing + host fallback: {speedup:.2f}x modeled "
              f"end-to-end speedup over locality-blind round-robin "
              f"({blind['host_fallbacks']} -> {routed['host_fallbacks']} "
              f"fallbacks)")
    return results


def run_pipeline(
    rm: str = "rm1",
    *,
    partitions: int = 12,
    rows: int = BENCH_ROWS,
    ks=(1, 2, 4),
    lookaheads=(1, 2, 4),
    rounds: int = 3,
    min_speedup: float = 1.0,
    out_json: str = "BENCH_throughput_pipeline.json",
) -> dict:
    """Serial produce loop vs the zero-stall pipeline, with bitwise asserts.

    * ``serial`` — the pre-pipeline hot path: per partition, read ->
      page-build -> one solo jit launch -> ``block_until_ready``.
    * ``pipelined[K]`` — ``produce_stream(megabatch=K, overlap=True)``: one
      launch per K partitions, the next chunk's read/page-build running
      while the current kernel executes.  ``overlap=False`` is also timed
      per K to split the megabatch win from the overlap win.
    * ``lookahead[D]`` — at the best static K, a depth-D window of staged
      chunks queued behind the in-flight kernel (D=1 is the classic double
      buffer).

    Every configuration's batches are asserted bitwise identical to the
    serial reference — with the process-wide executable cache on (engines
    share one compile) and off (a private-compile engine) — and the best
    pipelined configuration must reach ``min_speedup`` x serial throughput.
    Timing alternates serial/pipelined rounds and takes best-of to shed
    process-level drift.  The full sweep lands in ``out_json``.
    """
    src = SyntheticRecSysSource(RM_CONFIGS[rm], rows=rows)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(partitions, num_devices=4, source=src)
    engine = PreStoEngine(spec)
    pids = list(range(partitions))
    total_rows = rows * partitions

    # reference batches + compile warmup for every shape, outside timing
    reference = {pid: engine.produce_batch(store, pid) for pid in pids}
    for k in ks:
        for _ in engine.produce_stream(store, pids, megabatch=k):
            pass

    def assert_bitwise(tag: str, produced: dict) -> None:
        assert sorted(produced) == pids, f"{tag} lost partitions"
        for pid in pids:
            for key in reference[pid]:
                np.testing.assert_array_equal(
                    np.asarray(reference[pid][key]),
                    np.asarray(produced[pid][key]),
                    err_msg=f"{tag} pid={pid} key={key} diverged",
                )

    # bitwise: every sweep point, executable cache ON (shared compiles)
    for k in ks:
        for overlap in (True, False):
            got = dict(
                engine.produce_stream(store, pids, megabatch=k, overlap=overlap)
            )
            assert_bitwise(f"pipelined k={k} overlap={overlap}", got)
        for d in lookaheads:
            if d == 1:
                continue  # identical to the overlap=True point above
            got = dict(
                engine.produce_stream(store, pids, megabatch=k, lookahead=d)
            )
            assert_bitwise(f"pipelined k={k} lookahead={d}", got)
    # bitwise: executable cache OFF (private compile, fresh engine)
    cold = PreStoEngine(spec, use_exec_cache=False)
    assert_bitwise(
        "exec-cache-off",
        dict(cold.produce_stream(store, pids, megabatch=max(ks),
                                 lookahead=max(lookaheads))),
    )
    print(f"bitwise: megabatched/overlapped == serial for all K in {tuple(ks)} "
          f"x lookahead in {tuple(lookaheads)} (executable cache on and off)")

    def t_serial() -> float:
        t0 = time.perf_counter()
        for pid in pids:
            engine.produce_batch(store, pid)
        return time.perf_counter() - t0

    def t_stream(k: int, overlap: bool, lookahead: int = 1) -> float:
        t0 = time.perf_counter()
        for _ in engine.produce_stream(store, pids, megabatch=k,
                                       overlap=overlap, lookahead=lookahead):
            pass
        return time.perf_counter() - t0

    serial_walls = []
    walls = {k: {"overlap": [], "no_overlap": []} for k in ks}

    def one_round() -> None:  # alternate: drift taxes no one mode
        serial_walls.append(t_serial())
        for k in ks:
            walls[k]["overlap"].append(t_stream(k, True))
            walls[k]["no_overlap"].append(t_stream(k, False))

    for _ in range(max(rounds, 1)):
        one_round()
    # wall-clock gates on shared CI runners are noisy: before failing the
    # min_speedup assert below, buy up to two extra best-of rounds — a real
    # regression survives them, a scheduling hiccup does not
    for _ in range(2):
        best = min(min(walls[k]["overlap"]) for k in ks)
        if min(serial_walls) / best >= min_speedup:
            break
        one_round()
    serial_s = min(serial_walls)
    serial_rows_s = total_rows / serial_s
    emit(f"throughput/{rm}/pipeline/serial", serial_s * 1e6 / partitions,
         f"rows_per_s={serial_rows_s:.0f}")

    model = DEFAULT_PLACEMENT_MODEL
    per_part_isp_s = engine.route_costs(rows=rows).isp_s
    sweep = {}
    for k in ks:
        ov, no = min(walls[k]["overlap"]), min(walls[k]["no_overlap"])
        sweep[k] = {
            "overlap_wall_s": ov,
            "overlap_rows_per_s": total_rows / ov,
            "no_overlap_wall_s": no,
            "no_overlap_rows_per_s": total_rows / no,
            "modeled_amortization": model.megabatch_amortization(
                per_part_isp_s, k
            ),
        }
        emit(f"throughput/{rm}/pipeline/k{k}", ov * 1e6 / partitions,
             f"rows_per_s={total_rows / ov:.0f} speedup={serial_s / ov:.2f}x "
             f"no_overlap_rows_per_s={total_rows / no:.0f}")
    best_k = min(ks, key=lambda k: sweep[k]["overlap_wall_s"])
    best = sweep[best_k]["overlap_wall_s"]
    speedup = serial_s / best

    # lookahead sweep at the best static K: depth-D staged-chunk window
    la_sweep = {}
    for d in lookaheads:
        wd = min(t_stream(best_k, True, d) for _ in range(max(rounds, 1)))
        la_sweep[d] = {"wall_s": wd, "rows_per_s": total_rows / wd}
        emit(f"throughput/{rm}/pipeline/lookahead{d}", wd * 1e6 / partitions,
             f"rows_per_s={total_rows / wd:.0f} k={best_k} "
             f"speedup={serial_s / wd:.2f}x")

    print(f"\n{'config':<19} {'rows/s':>10} {'wall':>9} {'speedup':>8}")
    print(f"{'serial':<19} {serial_rows_s:>10.0f} {serial_s * 1e3:>7.1f}ms "
          f"{'1.00x':>8}")
    for k in ks:
        for label, key in (("pipelined", "overlap_wall_s"),
                           ("megabatch-only", "no_overlap_wall_s")):
            w = sweep[k][key]
            print(f"{label + f' K={k}':<19} {total_rows / w:>10.0f} "
                  f"{w * 1e3:>7.1f}ms {serial_s / w:>7.2f}x")
    for d in lookaheads:
        w = la_sweep[d]["wall_s"]
        print(f"{f'lookahead D={d} K={best_k}':<19} {total_rows / w:>10.0f} "
              f"{w * 1e3:>7.1f}ms {serial_s / w:>7.2f}x")
    print(f"\nzero-stall produce path: best K={best_k}, "
          f"{speedup:.2f}x over the serial loop "
          f"({serial_rows_s:.0f} -> {total_rows / best:.0f} rows/s; "
          f"target 1.5x: {'PASS' if speedup >= 1.5 else 'below'})")

    results = {
        "rm": rm,
        "rows": rows,
        "partitions": partitions,
        "rounds": rounds,
        "serial": {"wall_s": serial_s, "rows_per_s": serial_rows_s},
        "pipelined": {str(k): sweep[k] for k in ks},
        "lookahead": {str(d): la_sweep[d] for d in lookaheads},
        "best": {
            "k": best_k,
            "rows_per_s": total_rows / best,
            "speedup": speedup,
        },
        "bitwise_identical": True,
        "exec_cache": EXECUTABLES.stats(),
    }
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")
    assert speedup >= min_speedup, (
        f"pipelined produce path must reach {min_speedup:.2f}x serial "
        f"throughput, measured {speedup:.2f}x"
    )
    return results


def run_autotune(
    rm: str = "rm1",
    *,
    partitions: int = 32,
    rows: int = 256,
    ks=(1, 2, 4),
    lookaheads=(1, 2, 4),
    rounds: int = 3,
    noise: float = 0.15,
    out_json: str = "BENCH_throughput_autotune.json",
) -> dict:
    """Online megabatch-K autotuning through the service, vs static K.

    One single-worker ``PreprocessingService`` session per configuration:

    * ``static[K]``  — ``JobSpec(megabatch=K)``: the PR-5 fixed-K pipeline.
    * ``autotuned``  — ``JobSpec(autotune=True)``: the ``MegabatchTuner``
      seeds K from the cost model and hill-climbs the power-of-two ladder
      online from measured overlap-corrected launch timings, with a depth-2
      staged-chunk lookahead window.
    * ``serial``     — the raw per-partition ``produce_batch`` loop.

    Gates: the tuned K must land within one ladder step of the best static
    K, the autotuned session must beat the serial loop and stay within
    ``noise`` of the best static session.  Bitwise identity to the serial
    reference is asserted for every mode — each static K, autotune with
    lookahead 1/2/4, and cache pre-warm on/off over mixed cold/cached
    content.  Timing alternates rounds and takes best-of; wall-clock gates
    buy up to two extra rounds before failing.
    """
    src = SyntheticRecSysSource(RM_CONFIGS[rm], rows=rows)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(partitions, num_devices=4, source=src)
    engine = PreStoEngine(spec)
    pids = list(range(partitions))
    total_rows = rows * partitions
    ladder = k_ladder(max(ks))

    # reference batches + compile warmup for every chunk shape the tuner
    # can visit, outside timing
    reference = {pid: engine.produce_batch(store, pid) for pid in pids}
    for k in ks:
        for _ in engine.produce_stream(store, pids, megabatch=k):
            pass

    def assert_bitwise(tag: str, produced: dict) -> None:
        missing = [p for p in pids if p not in produced]
        assert not missing, f"{tag} lost partitions {missing}"
        for pid in pids:
            for key in reference[pid]:
                np.testing.assert_array_equal(
                    np.asarray(reference[pid][key]),
                    np.asarray(produced[pid][key]),
                    err_msg=f"{tag} pid={pid} key={key} diverged",
                )

    def service_run(cache=None, span=None, **kw):
        with PreprocessingService(num_workers=1, cache=cache) as svc:
            t0 = time.perf_counter()
            sess = svc.submit(JobSpec(
                name=f"{rm}-auto", partitions=span or range(partitions),
                engine=engine, store=store, units=1,
                queue_depth=partitions, **kw))
            out = {pid: mb for pid, mb in sess}
            st = sess.stats()
            wall = time.perf_counter() - t0
        return wall, out, st

    # bitwise: static rungs, autotune x lookahead, pre-warm on/off
    for k in ks:
        _, out, _ = service_run(megabatch=k)
        assert_bitwise(f"static k={k}", out)
    for d in lookaheads:
        _, out, _ = service_run(autotune=True, megabatch=max(ks), lookahead=d)
        assert_bitwise(f"autotune lookahead={d}", out)
    # pre-warm needs mixed content: a fully cached session short-circuits
    # every claim and never opens a peek window, so seed only the back half
    # — the front half produces cold while the walker pre-warms the rest
    cache = FeatureCache(capacity_bytes=1 << 30)
    service_run(cache=cache, span=range(partitions // 2, partitions))
    _, out, warm_st = service_run(
        cache=cache, autotune=True, lookahead=max(lookaheads))
    assert_bitwise("prewarm-on", out)
    _, out, _ = service_run(
        cache=cache, autotune=True, lookahead=max(lookaheads), prewarm=False)
    assert_bitwise("prewarm-off", out)
    print(f"bitwise: static K in {tuple(ks)}, autotuned lookahead in "
          f"{tuple(lookaheads)}, pre-warm on/off == serial reference "
          f"(prewarm_hits={warm_st.prewarm_hits})")

    def t_serial() -> float:
        t0 = time.perf_counter()
        for pid in pids:
            engine.produce_batch(store, pid)
        return time.perf_counter() - t0

    serial_walls: list = []
    static_walls = {k: [] for k in ks}
    auto_walls: list = []
    tuned_ks: list = []

    def one_round() -> None:  # alternate: drift taxes no one mode
        serial_walls.append(t_serial())
        for k in ks:
            w, _, _ = service_run(megabatch=k)
            static_walls[k].append(w)
        w, _, st = service_run(autotune=True, megabatch=max(ks), lookahead=2)
        auto_walls.append(w)
        tuned_ks.append(st.tuned_k)

    def verdict():
        auto_s = min(auto_walls)
        tuned_k = tuned_ks[auto_walls.index(auto_s)]
        best_k = min(ks, key=lambda k: min(static_walls[k]))
        best_static_s = min(static_walls[best_k])
        steps = abs(ladder.index(tuned_k) - ladder.index(best_k))
        ok = (steps <= 1
              and auto_s < min(serial_walls)
              and auto_s <= best_static_s * (1.0 + noise))
        return ok, auto_s, tuned_k, best_k, best_static_s, steps

    for _ in range(max(rounds, 1)):
        one_round()
    # wall-clock gates on shared runners are noisy: buy up to two extra
    # rounds before failing — a real regression survives them
    for _ in range(2):
        if verdict()[0]:
            break
        one_round()
    ok, auto_s, tuned_k, best_k, best_static_s, steps = verdict()
    serial_s = min(serial_walls)

    emit(f"throughput/{rm}/autotune/serial", serial_s * 1e6 / partitions,
         f"rows_per_s={total_rows / serial_s:.0f}")
    for k in ks:
        w = min(static_walls[k])
        emit(f"throughput/{rm}/autotune/static_k{k}", w * 1e6 / partitions,
             f"rows_per_s={total_rows / w:.0f} speedup={serial_s / w:.2f}x")
    emit(f"throughput/{rm}/autotune/tuned", auto_s * 1e6 / partitions,
         f"rows_per_s={total_rows / auto_s:.0f} tuned_k={tuned_k} "
         f"best_static_k={best_k} speedup={serial_s / auto_s:.2f}x")

    print(f"\n{'config':<16} {'rows/s':>10} {'wall':>9} {'speedup':>8}")
    print(f"{'serial':<16} {total_rows / serial_s:>10.0f} "
          f"{serial_s * 1e3:>7.1f}ms {'1.00x':>8}")
    for k in ks:
        w = min(static_walls[k])
        print(f"{f'static K={k}':<16} {total_rows / w:>10.0f} "
              f"{w * 1e3:>7.1f}ms {serial_s / w:>7.2f}x")
    print(f"{'autotuned':<16} {total_rows / auto_s:>10.0f} "
          f"{auto_s * 1e3:>7.1f}ms {serial_s / auto_s:>7.2f}x")
    print(f"\nself-tuning produce path: tuned K={tuned_k}, best static "
          f"K={best_k} ({steps} ladder step(s) apart), autotuned "
          f"{serial_s / auto_s:.2f}x over serial, "
          f"{best_static_s / auto_s:.2f}x vs best static")

    results = {
        "rm": rm,
        "rows": rows,
        "partitions": partitions,
        "rounds": len(serial_walls),
        "serial": {"wall_s": serial_s, "rows_per_s": total_rows / serial_s},
        "static": {str(k): {"wall_s": min(static_walls[k]),
                            "rows_per_s": total_rows / min(static_walls[k])}
                   for k in ks},
        "autotuned": {
            "wall_s": auto_s,
            "rows_per_s": total_rows / auto_s,
            "tuned_k": tuned_k,
            "best_static_k": best_k,
            "ladder": ladder,
            "ladder_steps_from_best": steps,
            "prewarm_hits": warm_st.prewarm_hits,
        },
        "bitwise_identical": True,
        "exec_cache": EXECUTABLES.stats(),
    }
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")
    assert steps <= 1, (
        f"tuned K={tuned_k} must land within one ladder step of the best "
        f"static K={best_k} (ladder {ladder})")
    assert auto_s < serial_s, (
        f"autotuned session must beat the serial loop "
        f"({auto_s:.3f}s vs {serial_s:.3f}s)")
    assert auto_s <= best_static_s * (1.0 + noise), (
        f"autotuned session must stay within {noise:.0%} of the best "
        f"static K={best_k} ({auto_s:.3f}s vs {best_static_s:.3f}s)")
    return results


def run_sim(
    *,
    sessions: int = 1000,
    seed: int = 3,
    workers: int = 8,
    devices: int = 4,
    arrival_window_s: float = 4.0,
    out_json: str = "BENCH_sim_slo.json",
) -> dict:
    """Multi-tenant schedule in VIRTUAL time: SLO admission vs FIFO.

    The same Zipf(1.3)-skewed, seeded workload (a few huge sessions, a long
    tail of tiny ones, 10% release candidates on tighter deadlines) is run
    through the discrete-event engine twice per policy — no real sleeps, so
    1000 sessions of modeled schedule finish in wall-clock seconds:

    * ``slo``  — deadline-aware admission: overflow demand is REJECTED at
      arrival, the rest admitted or degraded (fewer units than asked); rc
      jobs preempt exploratory ones.  Nothing admitted may starve.
    * ``fifo`` — admit everything, serve in arrival order: under the same
      overload the tail waits unboundedly and starves.

    Asserts (the acceptance criteria): same-seed SLO replay yields a
    byte-identical event trace; SLO starvation count is exactly 0 while the
    jobs FIFO would have starved show up as rejected/degraded instead; the
    FIFO baseline starves a non-zero tail (skipped for tiny --sessions
    where the fleet is never overloaded).  Writes the per-class
    SLO-attainment report to ``out_json``.
    """
    from repro.core.simclock import SimHarness

    kw = dict(num_workers=workers, num_devices=devices)
    wl = dict(arrival_window_s=arrival_window_s)
    print(f"sim bench: {sessions} zipf sessions over {arrival_window_s}s, "
          f"{workers} workers / {devices} devices, seed={seed}")

    reports, walls = {}, {}
    traces = []
    for run_i in range(2):  # twice: the replay must be byte-identical
        h = SimHarness(seed=seed, policy="slo", **kw)
        h.workload(sessions, **wl)
        t0 = time.perf_counter()
        reports["slo"] = h.run()
        walls["slo"] = time.perf_counter() - t0
        traces.append(h.trace_bytes())
    assert traces[0] == traces[1], (
        "same-seed SLO replay must produce a byte-identical event trace")
    print(f"replay: {len(traces[0])}-byte event trace identical across "
          f"two seed={seed} runs")

    h = SimHarness(seed=seed, policy="fifo", **kw)
    h.workload(sessions, **wl)
    t0 = time.perf_counter()
    reports["fifo"] = h.run()
    walls["fifo"] = time.perf_counter() - t0

    results: dict = {"sessions": sessions, "seed": seed, "workers": workers,
                     "devices": devices, "arrival_window_s": arrival_window_s}
    for policy, rep in reports.items():
        emit(f"throughput/sim/{policy}", rep.makespan_s * 1e6,
             f"starved={rep.starved_count} wall_s={walls[policy]:.2f}")
        results[policy] = dict(rep.to_dict(), wall_s=walls[policy])
        print(f"\n[{policy}] makespan={rep.makespan_s:.2f}s modeled "
              f"({walls[policy]:.2f}s wall, {rep.events_processed} events) "
              f"starved={rep.starved_count}")
        print(f"  {'class':<12} {'jobs':>5} {'admit':>6} {'degr':>5} "
              f"{'rej':>5} {'starv':>6} {'slo':>6} {'p99':>8}")
        for cls, row in rep.by_class().items():
            p99 = row["p99_latency_s"]
            print(f"  {cls:<12} {row['jobs']:>5} {row['admitted']:>6} "
                  f"{row['degraded']:>5} {row['rejected']:>5} "
                  f"{row['starved']:>6} {row['slo_attainment']:>6.2f} "
                  f"{(f'{p99:.2f}s' if p99 is not None else '-'):>8}")

    slo, fifo = reports["slo"], reports["fifo"]
    assert slo.starved_count == 0, (
        f"SLO admission must reject/degrade instead of starve "
        f"(starved={slo.starved_count})")
    shed = sum(1 for o in slo.outcomes if o.status in ("rejected", "degraded"))
    assert shed > 0, "overloaded SLO schedule must shed load visibly"
    if sessions >= 200:
        assert fifo.starved_count > 0, (
            "the FIFO baseline must starve a tail under the same overload "
            f"(starved={fifo.starved_count})")
    print(f"\nslo: 0 starved ({shed} rejected/degraded up front) vs "
          f"fifo: {fifo.starved_count} starved of {sessions}")

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")
    return results


def run_dedup(
    rm: str = "rm2",
    *,
    dups=(2, 4, 8),
    dup_pool: int = 16,
    partitions: int = 8,
    rows: int = BENCH_ROWS,
    rounds: int = 3,
    min_speedup: float = 1.0,
    out_json: str = "BENCH_throughput_dedup.json",
) -> dict:
    """Sample-level dedup (RecD): unique-block staging vs flat staging.

    Per dup factor d the same logical dataset is produced two ways:

    * ``flat`` — the pre-dedup hot path: every partition inflated to the
      classic per-sample layout (outside timing — undeduped data never pays
      inflation), then staged (bitpack regroup at LOGICAL rows) and run
      through the compiled plan at logical geometry.
    * ``dedup`` — pages staged at unique-block geometry (rows/d) carrying a
      per-sample ref vector; the sparse chain runs on unique blocks and a
      gather inside the same compiled program expands to logical rows just
      before batch formation.

    Bytes moved are ledger facts, not wall-clock guesses: a dedup store
    read charges ``Partition.nbytes`` (unique) while ``logical_bytes_read``
    tracks what the same read would have streamed flat — the reduction must
    match the schema's unique fraction exactly.  Modeled ops/ISP-seconds
    savings come from the dedup-aware cost model.  Every produce mode —
    solo, megabatched, pipelined stream, and a two-tenant shared service
    with the block cache (``dup_pool`` gives tenants real block overlap) —
    is asserted bitwise identical to the flat reference, and the top dup
    factor's stage+transform speedup must reach ``min_speedup``x.
    """
    base = RM_CONFIGS[rm]
    results = {"rm": rm, "rows": rows, "partitions": partitions,
               "dup_pool": dup_pool, "factors": {}}
    pids = list(range(partitions))
    top = max(dups)
    for d in dups:
        assert rows % d == 0 and (rows // d) % 32 == 0, (d, rows)
        cfg = dataclasses.replace(
            base, rows_per_partition=rows, dup_factor=d, dup_pool=dup_pool
        )
        src = SyntheticRecSysSource(cfg, seed=3)
        spec = TransformSpec.from_source(src)
        engine = PreStoEngine(spec)

        # -- bytes moved: ledger facts from a full epoch of reads ----------
        store = PartitionedStore(partitions, num_devices=4, source=src)
        parts = [store.read(pid) for pid in pids]
        unique_b, logical_b = store.bytes_read, store.logical_bytes_read
        saved = logical_b - unique_b
        # the unique fraction the schema dictates: stored/logical per part
        schema_unique = sum(p.nbytes() for p in parts) / sum(
            p.logical_nbytes() for p in parts
        )
        assert unique_b / logical_b <= schema_unique + 1e-9, (
            "ledger moved more than the schema's unique bytes"
        )

        # -- modeled savings: the dedup-aware cost model -------------------
        flat_spec = TransformSpec.from_source(
            SyntheticRecSysSource(
                dataclasses.replace(cfg, dup_factor=1, dup_pool=0), seed=3
            )
        )
        c_d, c_f = partition_costs(spec, rows), partition_costs(flat_spec, rows)

        # -- staging inputs (content generation outside timing) ------------
        flats = [inflate_partition(p) for p in parts]

        def produce(part) -> dict:
            return engine.jit_preprocess_cached()(
                engine._put_pages(pages_from_partition(part, spec))
            )

        # reference + compile warmup for both geometries, outside timing
        reference = {}
        for pid, part, flat in zip(pids, parts, flats):
            got = produce(part)
            want = produce(flat)
            reference[pid] = want
            for key in want:
                np.testing.assert_array_equal(
                    np.asarray(got[key]), np.asarray(want[key]),
                    err_msg=f"dedup solo d={d} pid={pid} key={key} diverged",
                )

        def assert_bitwise(tag: str, produced: dict) -> None:
            assert sorted(produced) == pids, f"{tag} lost partitions"
            for pid in pids:
                for key in reference[pid]:
                    np.testing.assert_array_equal(
                        np.asarray(reference[pid][key]),
                        np.asarray(produced[pid][key]),
                        err_msg=f"{tag} pid={pid} key={key} diverged",
                    )

        # bitwise: megabatched launch and the pipelined stream (dedup pages)
        assert_bitwise(
            f"megabatch d={d}",
            dict(zip(pids, engine.produce_batches(store, pids))),
        )
        assert_bitwise(
            f"pipeline d={d}",
            dict(engine.produce_stream(store, pids, megabatch=2)),
        )

        # bitwise + block dedup: two tenants sharing the service block cache
        svc = PreprocessingService(
            num_workers=2, cache=FeatureCache(capacity_bytes=256 << 20)
        )
        try:
            half = partitions // 2
            sA = svc.submit(JobSpec(name=f"A{d}", spec=spec, store=store,
                                    engine=engine, partitions=pids[:half]))
            outA = dict(iter(sA))
            sB = svc.submit(JobSpec(name=f"B{d}", spec=spec, store=store,
                                    engine=engine, partitions=pids[half:]))
            outB = dict(iter(sB))
            block_hits = sB.stats().block_hits
            published = sA.stats().blocks_published
        finally:
            svc.close()
        assert_bitwise(f"service d={d}", {**outA, **outB})
        assert published > 0, "cold tenant published no blocks"
        assert block_hits > 0, (
            "pooled dup dataset: second tenant must assemble from blocks"
        )

        # -- wall clock: stage (page build) + compiled transform -----------
        def t_epoch(source_parts) -> float:
            t0 = time.perf_counter()
            for part in source_parts:
                jax.block_until_ready(produce(part))
            return time.perf_counter() - t0

        dedup_walls, flat_walls = [], []

        def one_round() -> None:  # alternate: drift taxes no one mode
            flat_walls.append(t_epoch(flats))
            dedup_walls.append(t_epoch(parts))

        for _ in range(max(rounds, 1)):
            one_round()
        # wall-clock gates on shared runners are noisy: buy up to two extra
        # best-of rounds before failing the top factor's speedup assert
        if d == top:
            for _ in range(2):
                if min(flat_walls) / min(dedup_walls) >= min_speedup:
                    break
                one_round()
        flat_s, dedup_s = min(flat_walls), min(dedup_walls)
        speedup = flat_s / dedup_s
        total_rows = rows * partitions
        emit(f"throughput/{rm}/dedup/d{d}", dedup_s * 1e6 / partitions,
             f"rows_per_s={total_rows / dedup_s:.0f} "
             f"flat_rows_per_s={total_rows / flat_s:.0f} "
             f"bytes_saved={saved} speedup={speedup:.2f}x")
        results["factors"][str(d)] = {
            "unique_bytes_read": unique_b,
            "logical_bytes_read": logical_b,
            "bytes_moved_reduction": saved / logical_b,
            "schema_unique_fraction": schema_unique,
            "modeled_ops_savings": 1.0 - c_d.ops / c_f.ops,
            "modeled_isp_s_savings": 1.0 - c_d.isp_s / c_f.isp_s,
            "flat_wall_s": flat_s,
            "dedup_wall_s": dedup_s,
            "flat_rows_per_s": total_rows / flat_s,
            "dedup_rows_per_s": total_rows / dedup_s,
            "speedup": speedup,
            "block_cache": {"published": published, "hits": block_hits},
            "bitwise_identical": True,
        }

    print(f"\n{'dup':>4} {'bytes moved':>24} {'saved':>7} {'mod.ops':>8} "
          f"{'rows/s flat':>12} {'rows/s dedup':>13} {'speedup':>8}")
    for d in dups:
        r = results["factors"][str(d)]
        print(f"{d:>4} {r['unique_bytes_read']:>11,} /{r['logical_bytes_read']:>11,} "
              f"{r['bytes_moved_reduction'] * 100:>6.1f}% "
              f"{r['modeled_ops_savings'] * 100:>7.1f}% "
              f"{r['flat_rows_per_s']:>12.0f} {r['dedup_rows_per_s']:>13.0f} "
              f"{r['speedup']:>7.2f}x")
    top_r = results["factors"][str(top)]
    print(f"\nsample-level dedup: d={top} moves "
          f"{top_r['bytes_moved_reduction'] * 100:.1f}% fewer bytes and runs "
          f"{top_r['speedup']:.2f}x faster than flat staging "
          f"(every mode bitwise identical)")
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")
    assert top_r["speedup"] >= min_speedup, (
        f"dedup staging at d={top} must reach {min_speedup:.2f}x flat "
        f"throughput, measured {top_r['speedup']:.2f}x"
    )
    return results


def run_chaos(
    rm: str = "rm1",
    *,
    partitions: int = 12,
    rows: int = 256,
    workers: int = 3,
    io_retries: int = 4,
    out_json: str = "BENCH_throughput_chaos.json",
) -> dict:
    """Storage fault domain drill: seeded I/O chaos, bitwise-identical output.

    A clean engine+store pair produces the fault-free reference batches.
    Then each produce mode (pipeline / autotune / cache+spill) runs a full
    service session against a store wired to a seeded ``IoFaultInjector``
    throwing transient read errors, torn (bit-flipped) blocks, slow reads,
    spill-block corruption, and one whole device knocked offline mid-run —
    and every delivered batch is asserted bitwise identical to the clean
    reference.  Faults cost LATENCY (bounded retry/backoff, device
    failover), never correctness.  Two negative drills close the loop: a
    poisoned store (every read faults) must surface a structured
    ``SessionError`` within the retry budget instead of hanging, and the
    offline drill must re-route the dead device's partitions through the
    failover path.  Writes ``out_json``.
    """
    from repro.core.featcache import default_spill_store
    from repro.core.service import SessionError
    from repro.data.storage import IoFaultInjector

    src = SyntheticRecSysSource(RM_CONFIGS[rm], rows=rows)
    spec = TransformSpec.from_source(src)
    engine = PreStoEngine(spec)
    pids = list(range(partitions))
    clean_store = PartitionedStore(partitions, num_devices=4, source=src)
    reference = {pid: engine.produce_batch(clean_store, pid) for pid in pids}
    total_rows = rows * partitions

    def assert_bitwise(tag: str, produced: dict) -> None:
        assert sorted(produced) == pids, (
            f"{tag}: lost partitions {sorted(set(pids) - set(produced))}"
        )
        for pid in pids:
            for key in reference[pid]:
                np.testing.assert_array_equal(
                    np.asarray(reference[pid][key]),
                    np.asarray(produced[pid][key]),
                    err_msg=f"{tag} pid={pid} key={key} diverged under faults",
                )

    def faulted_session(tag, injector, *, cache=None, **job_kw):
        """One service run against an injected store; returns (got, stats)."""
        fleet = DeviceFleet.from_cost_model(4, DEFAULT_PLACEMENT_MODEL)
        store = PartitionedStore(
            partitions, num_devices=4, source=src, fleet=fleet,
            fault_injector=injector)
        svc = PreprocessingService(
            num_workers=workers, devices=fleet, cache=cache)
        try:
            session = svc.submit(JobSpec(
                name=tag, partitions=pids, engine=engine, store=store,
                io_retries=io_retries, io_backoff_s=0.002, **job_kw))
            got = {}
            t0 = time.perf_counter()
            for pid, mb in session:
                got[pid] = mb
            wall = time.perf_counter() - t0
            return got, session.stats(), svc.events.counts(), wall
        finally:
            svc.close()

    chaos_spec = dict(transient=0.25, corrupt=0.15, spill=0.4,
                      slow=0.1, slow_s=5e-4, offline_device=1,
                      offline_after=partitions)
    modes = {
        "pipeline": dict(megabatch=2, lookahead=2),
        "autotune": dict(autotune=True),
        "cache": dict(),  # shared feature cache + spill tier (below)
    }
    results: dict = {"modes": {}}
    tot_injected, tot_retries, tot_failovers = 0, 0, 0
    for i, (tag, job_kw) in enumerate(modes.items()):
        inj = IoFaultInjector(seed=11 + i, **chaos_spec)
        cache = None
        if tag == "cache":
            # a small memory tier forces evictions into the spill store,
            # whose blocks the injector corrupts at rest — corrupt spill
            # hits must be detected, dropped, and recomputed cold
            spill = default_spill_store(4)
            spill.fault_injector = inj
            cache = FeatureCache(1 << 20, spill=spill)
        got, st, events, wall = faulted_session(
            tag, inj, cache=cache, **job_kw)
        assert_bitwise(tag, got)
        assert st.done and not st.cancelled, f"{tag}: session did not drain"
        assert st.quarantined == 0, (
            f"{tag}: {st.quarantined} partition(s) quarantined inside the "
            f"retry budget"
        )
        injected = sum(inj.summary().values())
        tot_injected += injected
        tot_retries += st.retries
        tot_failovers += st.failovers
        emit(f"throughput/{rm}/chaos/{tag}", wall * 1e6 / partitions,
             f"rows_per_s={total_rows / wall:.0f} injected={injected} "
             f"retries={st.retries} failovers={st.failovers}")
        results["modes"][tag] = {
            "wall_s": wall,
            "rows_per_s": total_rows / wall,
            "injected": inj.summary(),
            "retries": st.retries,
            "failovers": st.failovers,
            "events": events,
            "bitwise_identical": True,
        }
    assert tot_injected > 0, "the chaos drill injected no faults at all"
    assert tot_retries > 0, "injected faults were never retried"

    # offline failover drill: device 1 dies on the FIRST read — every one of
    # its partitions must re-route through the failover path, and the run
    # still delivers bitwise-identical batches
    inj = IoFaultInjector(seed=29, offline_device=1, offline_after=1)
    got, st, events, _w = faulted_session("failover", inj)
    assert_bitwise("failover", got)
    assert st.failovers >= 1, "offline device produced no failovers"
    assert events.get("device_offline", 0) == 1
    results["failover"] = {
        "failovers": st.failovers, "retries": st.retries, "events": events,
    }
    tot_failovers += st.failovers

    # poison drill: every read faults — the session must surface a
    # structured SessionError within the retry budget, never hang
    inj = IoFaultInjector(seed=43, transient=1.0)
    fleet = DeviceFleet.from_cost_model(4, DEFAULT_PLACEMENT_MODEL)
    store = PartitionedStore(partitions, num_devices=4, source=src,
                             fleet=fleet, fault_injector=inj)
    svc = PreprocessingService(num_workers=workers, devices=fleet)
    try:
        session = svc.submit(JobSpec(
            name="poison", partitions=pids, engine=engine, store=store,
            io_retries=2, io_backoff_s=1e-3))
        t0 = time.perf_counter()
        try:
            for _ in session:
                pass
            raise AssertionError("poisoned store delivered batches")
        except SessionError as e:
            poison_s = time.perf_counter() - t0
            assert e.attempts == 2, e.attempts
        st = session.stats()
        assert st.quarantined >= 1, "poisoned run quarantined nothing"
        session.cancel()
    finally:
        svc.close()
    results["poison"] = {
        "error_latency_s": poison_s, "quarantined": st.quarantined,
    }

    print(f"\n{'mode':<10} {'rows/s':>10} {'injected':>9} {'retries':>8} "
          f"{'failovers':>10}")
    for tag, r in results["modes"].items():
        print(f"{tag:<10} {r['rows_per_s']:>10.0f} "
              f"{sum(r['injected'].values()):>9} {r['retries']:>8} "
              f"{r['failovers']:>10}")
    print(f"\nstorage chaos: {tot_injected} injected fault(s) absorbed "
          f"across {len(modes)} produce modes ({tot_retries} retries, "
          f"{tot_failovers} failovers) — every delivered batch bitwise "
          f"identical to the fault-free run; poisoned store surfaced "
          f"SessionError in {poison_s * 1e3:.0f}ms")
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description=__doc__, epilog=EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--multi-tenant", action="store_true",
                    help="bench the shared-pool service surface")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: small rows/partitions")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--cache", dest="cache", action="store_const", const=True,
                    default=None,
                    help="overlapping tenants; time uncached vs shared "
                         "feature cache, report dedup hit rate + speedup")
    ap.add_argument("--no-cache", dest="cache", action="store_const",
                    const=False,
                    help="overlapping tenants, uncached baseline only")
    ap.add_argument("--overlap", type=float, default=0.5,
                    help="fraction of partition overlap between consecutive "
                         "tenants in --cache/--no-cache modes (default 0.5)")
    ap.add_argument("--skew", type=float, default=None, metavar="ALPHA",
                    help="bench device-aware scheduling under Zipf(ALPHA)-"
                         "skewed partition ownership (0 = uniform quotas)")
    ap.add_argument("--devices", type=int, default=4,
                    help="simulated ISP devices in --skew mode (default 4)")
    ap.add_argument("--pipeline", action="store_true",
                    help="bench the zero-stall produce path (megabatched "
                         "launches + read/compute overlap) vs the serial "
                         "loop; writes BENCH_throughput_pipeline.json")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="--pipeline: assert pipelined >= this x serial "
                         "throughput (default 1.0, i.e. never slower)")
    ap.add_argument("--autotune", action="store_true",
                    help="bench the self-tuning produce path: online "
                         "megabatch-K autotuning vs every static K; asserts "
                         "tuned K within one ladder step of the best static "
                         "K and bitwise identity in every mode; writes "
                         "BENCH_throughput_autotune.json")
    ap.add_argument("--dedup", action="store_true",
                    help="bench sample-level dedup (RecD): unique-block "
                         "staging vs flat staging over a dup-factor sweep; "
                         "reports bytes-moved + modeled ops savings + "
                         "measured speedup, asserts bitwise identity in "
                         "every produce mode; writes "
                         "BENCH_throughput_dedup.json")
    ap.add_argument("--dup-pool", type=int, default=16,
                    help="--dedup: dataset-level shared block pool size "
                         "(cross-partition/cross-tenant overlap; default 16)")
    ap.add_argument("--sim", action="store_true",
                    help="run the multi-tenant schedule in VIRTUAL time: "
                         "SLO-aware admission vs a FIFO baseline over the "
                         "same seeded Zipf workload; asserts byte-identical "
                         "same-seed trace replay and zero SLO starvation; "
                         "writes BENCH_sim_slo.json")
    ap.add_argument("--sessions", type=int, default=1000,
                    help="--sim: number of Zipf-skewed sessions "
                         "(default 1000)")
    ap.add_argument("--sim-seed", type=int, default=3,
                    help="--sim: workload + engine seed (default 3)")
    ap.add_argument("--arrival-window", type=float, default=4.0,
                    help="--sim: seconds of virtual time the session "
                         "arrivals span; smaller = heavier overload "
                         "(default 4.0)")
    ap.add_argument("--chaos", action="store_true",
                    help="storage fault domain drill: seeded I/O faults "
                         "against every produce mode, asserting "
                         "bitwise-identical delivery, device failover, and "
                         "prompt quarantine of a poisoned store; writes "
                         "BENCH_throughput_chaos.json")
    ap.add_argument("--out", default=None,
                    help="--pipeline/--autotune/--sim/--chaos: JSON artifact "
                         "path override (default: "
                         "BENCH_throughput_pipeline.json / "
                         "BENCH_throughput_autotune.json / "
                         "BENCH_sim_slo.json / BENCH_throughput_chaos.json "
                         "per mode)")
    args = ap.parse_args()
    if args.chaos:
        run_chaos(
            partitions=6 if args.smoke else 12,
            rows=64 if args.smoke else 256,
            workers=max(args.workers, 3),
            out_json=args.out or "BENCH_throughput_chaos.json",
        )
    elif args.dedup:
        run_dedup(
            dups=(2, 4) if args.smoke else (2, 4, 8),
            dup_pool=args.dup_pool,
            partitions=8 if args.smoke else 16,
            rows=256 if args.smoke else BENCH_ROWS,
            rounds=2 if args.smoke else 3,
            min_speedup=args.min_speedup,
            out_json=args.out or "BENCH_throughput_dedup.json",
        )
    elif args.sim:
        # --smoke shrinks the workload but keeps the ARRIVAL RATE: the
        # FIFO-starves-a-tail assertion needs the fleet overloaded, and
        # 200 sessions over the full 4s window would not be
        sim_sessions = (200 if args.smoke and args.sessions == 1000
                        else args.sessions)
        window = args.arrival_window * sim_sessions / max(args.sessions, 1)
        run_sim(
            sessions=sim_sessions,
            seed=args.sim_seed,
            workers=args.workers if args.workers != 2 else 8,
            devices=args.devices,
            arrival_window_s=window,
            out_json=args.out or "BENCH_sim_slo.json",
        )
    elif args.autotune:
        run_autotune(
            partitions=32 if args.smoke else 48,
            rows=256 if args.smoke else 1024,
            ks=(1, 2, 4),
            out_json=args.out or "BENCH_throughput_autotune.json",
        )
    elif args.pipeline:
        run_pipeline(
            partitions=12 if args.smoke else 32,
            rows=1024 if args.smoke else 2048,
            ks=(1, 2, 4),
            rounds=3,
            min_speedup=args.min_speedup,
            out_json=args.out or "BENCH_throughput_pipeline.json",
        )
    elif args.skew is not None:
        run_skew(
            devices=args.devices,
            alpha=args.skew,
            partitions=16 if args.smoke else 32,
            rows=256 if args.smoke else BENCH_ROWS,
        )
    elif args.multi_tenant:
        # cache modes use wider windows so --overlap has partitions to share,
        # and full-size rows even under --smoke: the dedup saving must stay
        # visible above this host's per-produce scheduling jitter
        ppj = (4 if args.smoke else 8) if args.cache is not None else (
            2 if args.smoke else 4)
        rows = BENCH_ROWS if args.cache is not None else (
            256 if args.smoke else BENCH_ROWS)
        run_multi_tenant(
            jobs=args.jobs,
            workers=args.workers,
            partitions_per_job=ppj,
            rows=rows,
            overlap=args.overlap if args.cache is not None else 0.0,
            cache=args.cache,
        )
    else:
        run()
