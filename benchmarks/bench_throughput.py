"""Fig. 11 — preprocessing throughput: PreSto (fused, 1 unit) vs Disagg(N).

Measured: fused vs unfused end-to-end rows/s on this host (the fused/unfused
ratio is the hardware-independent fraction).  Fleet-scale Disagg(N) follows
the paper's own analytical model: per-worker throughput scales linearly with
N workers; the paper's published equivalence (ISP unit ~ cores) anchors the
cross-hardware comparison in bench_provisioning / bench_tco.
"""

from __future__ import annotations

import jax

from benchmarks.common import BENCH_ROWS, emit, rm_fixture, time_call
from repro.core.preprocess import preprocess_pages


def run(rms=("rm1", "rm2", "rm5")) -> dict:
    results = {}
    for rm in rms:
        src, spec, pages = rm_fixture(rm)
        fused = jax.jit(lambda p: preprocess_pages(p, spec, mode="fused"))
        unfused = jax.jit(lambda p: preprocess_pages(p, spec, mode="unfused"))
        tf = time_call(fused, pages)
        tu = time_call(unfused, pages)
        rows_s_f = BENCH_ROWS / tf
        rows_s_u = BENCH_ROWS / tu
        emit(f"throughput/{rm}/fused", tf * 1e6, f"rows_per_s={rows_s_f:.0f}")
        emit(f"throughput/{rm}/unfused", tu * 1e6, f"rows_per_s={rows_s_u:.0f}")
        # Disagg(N) analytical: N x single-worker unfused throughput
        for n in (1, 8, 32, 64):
            emit(f"throughput/{rm}/disagg_{n}", tu * 1e6 / n,
                 f"rows_per_s={rows_s_u * n:.0f} (paper linear-scaling model)")
        results[rm] = {"fused_rows_s": rows_s_f, "unfused_rows_s": rows_s_u}
    return results


if __name__ == "__main__":
    run()
