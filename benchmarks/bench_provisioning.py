"""Fig. 4 / Fig. 14 — workers required to saturate an 8-GPU training node.

Two parts: (a) the paper's published provisioning constants (CPU cores and
ISP units per RM) with the implied per-unit speedup; (b) our measured T/P
provisioning on the reduced RM1 pipeline (the planner mechanics themselves).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.registry import get_recsys
from repro.core.pipeline import TrainingPipeline
from repro.core.planner import (
    PAPER_CORES_REQUIRED_8GPU,
    PAPER_ISP_UNITS_REQUIRED_8GPU,
    paper_speedup_per_unit,
)
from repro.core.presto import PreStoEngine
from repro.core.spec import TransformSpec
from repro.data.storage import PartitionedStore
from repro.data.synth import SyntheticRecSysSource
from repro.distributed.sharding import ShardingRules
from repro.models import recsys as RS
from repro.train import adamw, make_train_step, warmup_cosine


def run() -> dict:
    results = {}
    for rm in PAPER_CORES_REQUIRED_8GPU:
        cores = PAPER_CORES_REQUIRED_8GPU[rm]
        units = PAPER_ISP_UNITS_REQUIRED_8GPU[rm]
        emit(f"provisioning/{rm}/paper", 0.0,
             f"cpu_cores={cores} isp_units={units} "
             f"per_unit_speedup={paper_speedup_per_unit(rm):.1f}x")
        results[rm] = {"cores": cores, "units": units}

    # measured T/P on the reduced pipeline (planner mechanics)
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=512)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(8, num_devices=4, source=src)
    rules = ShardingRules.make(None)
    opt = adamw(warmup_cosine(1e-3, 5, 100))
    loss_fn = lambda p, b: RS.loss_fn(p, b, rcfg, rules)
    step = jax.jit(make_train_step(loss_fn, opt))
    params = RS.init_params(jax.random.PRNGKey(0), rcfg)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    pipe = TrainingPipeline(PreStoEngine(spec, mesh=None), store, step)
    plan = pipe.provision(state)
    emit("provisioning/measured_T_over_P", 0.0,
         f"T={plan.train_throughput:.0f} P={plan.worker_throughput:.0f} "
         f"workers={plan.workers_required}")
    results["measured"] = {
        "T": plan.train_throughput, "P": plan.worker_throughput,
        "workers": plan.workers_required,
    }

    # per-placement-group provisioning on the hybrid engine: ISP units and
    # host workers are separate resources, each sized ceil(T/P_group)
    hpipe = TrainingPipeline(
        PreStoEngine(spec, mesh=None, placement="hybrid"), store, step
    )
    gplan = hpipe.provision_by_placement(state)
    groups = " ".join(
        f"{g}={gplan.group_units[g]}(P={gplan.group_throughput[g]:.0f})"
        for g in sorted(gplan.group_units)
    )
    emit("provisioning/measured_by_placement", 0.0,
         f"T={gplan.train_throughput:.0f} {groups}")
    results["measured_by_placement"] = {
        "T": gplan.train_throughput,
        "group_units": gplan.group_units,
        "group_throughput": gplan.group_throughput,
    }
    return results


if __name__ == "__main__":
    run()
