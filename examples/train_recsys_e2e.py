"""End-to-end driver: train a ~100M-parameter DLRM for a few hundred steps
through the full PreSto pipeline (Fig. 1): Extract (columnar store) ->
Transform (fused ISP kernels, shared service pool) -> Load (session stream)
-> train (consumer), with T/P provisioning driving the job's QoS target,
checkpointing, and restart safety.

    PYTHONPATH=src python examples/train_recsys_e2e.py [--steps 200]
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    JobSpec,
    PreprocessingService,
    PreStoEngine,
    TrainingPipeline,
    TransformSpec,
)
from repro.data.storage import PartitionedStore
from repro.data.synth import RMDataConfig, SyntheticRecSysSource
from repro.distributed.sharding import ShardingRules
from repro.models.recsys import RecSysConfig, init_params, loss_fn
from repro.train import CheckpointManager, adamw, make_train_step, warmup_cosine
from repro.common import param_count


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--rows", type=int, default=512)
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()

    # ~100M params: RM1 feature geometry with 20k-row embedding tables
    # (39 tables x 20,000 x 128 = 99.8M) + MLPs.
    data = RMDataConfig("rm1-100m", 13, 26, 1, 1, 13, 1024, 1 << 20, 20_000,
                        rows_per_partition=args.rows)
    rcfg = RecSysConfig(name="rm1-100m", data=data)
    src = SyntheticRecSysSource(data, rows=args.rows)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(args.steps + 8, num_devices=8, source=src)
    engine = PreStoEngine(spec)
    rules = ShardingRules.make(None)

    params = init_params(jax.random.PRNGKey(0), rcfg)
    print(f"model: {param_count(params)/1e6:.1f}M parameters")
    opt = adamw(warmup_cosine(2e-3, 20, args.steps))
    step = jax.jit(make_train_step(lambda p, b: loss_fn(p, b, rcfg, rules), opt))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}

    pipe = TrainingPipeline(engine, store, step)
    plan = pipe.provision(state)
    print(f"provisioning: T={plan.train_throughput:.0f} rows/s, "
          f"P={plan.worker_throughput:.0f} rows/s/worker -> "
          f"{plan.workers_required} preprocessing workers (paper step 2: T/P)")

    # the provisioned pool, as a service; the job's QoS target is the
    # measured training throughput T, so demand converges to ceil(T/P)
    service = PreprocessingService(num_workers=args.workers)
    session = service.submit(JobSpec(
        name="rm1-100m", engine=engine, store=store,
        partitions=range(args.steps + 8),
        target_samples_per_s=plan.train_throughput))

    with tempfile.TemporaryDirectory() as ckdir:
        ckpt = CheckpointManager(ckdir, keep=2)
        t0 = time.time()
        state, stats, metrics = pipe.run_session(
            state, session, max_steps=args.steps
        )
        ckpt.save(int(state["step"]), state)
        ckpt.wait()
        wall = time.time() - t0
        losses = [m["loss"] for m in metrics]
        k = max(len(losses) // 10, 1)
        sess_stats = session.stats()
        print(f"trained {stats.steps} steps ({stats.steps*args.rows} samples) "
              f"in {wall:.0f}s; consumer-util {stats.utilization:.2f}; "
              f"straggler re-issues {stats.reissues}; "
              f"QoS demand {sess_stats.demand_units} unit(s)")
        print(f"loss: first10={np.mean(losses[:k]):.4f} "
              f"last10={np.mean(losses[-k:]):.4f} (should decrease)")
        print(f"checkpoint at step {ckpt.latest_step()} -> restart-safe")
        assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not improve"
    service.close()


if __name__ == "__main__":
    main()
