"""PreSto vs Disagg vs Hybrid, side by side — the paper's core comparison
plus the per-family placement the operator-graph IR unlocks.

1. Kernel level (this host): fused ISP path vs multi-pass CPU-style path.
2. System level (16 simulated devices): the compiled collective footprint —
   storage-centric placement moves ZERO bytes between Extract and Load;
   disaggregated placement pays raw-pages-in + tensors-out permutes for
   every column family; hybrid pays them only for the families the cost
   model sends to hosts.

    PYTHONPATH=src python examples/presto_vs_disagg.py
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.core import TransformSpec, pages_from_partition, preprocess_pages
from repro.data.synth import RM_CONFIGS, SyntheticRecSysSource


def kernel_level() -> None:
    import time
    print("=== kernel level (RM5 geometry, 1024 rows) ===")
    src = SyntheticRecSysSource(RM_CONFIGS["rm5"], rows=1024)
    spec = TransformSpec.from_source(src)
    pages = {k: jnp.asarray(v)
             for k, v in pages_from_partition(src.partition(0), spec).items()}
    fused = jax.jit(lambda p: preprocess_pages(p, spec, mode="fused"))
    unfused = jax.jit(lambda p: preprocess_pages(p, spec, mode="unfused"))
    for fn in (fused, unfused):
        jax.block_until_ready(fn(pages))
    def t(fn):
        best = 1e9
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(pages))
            best = min(best, time.perf_counter() - t0)
        return best
    tf, tu = t(fused), t(unfused)
    print(f"unfused (Disagg-style multi-pass): {tu*1e3:.1f} ms/partition")
    print(f"fused   (PreSto ISP pipeline):     {tf*1e3:.1f} ms/partition "
          f"-> {tu/tf:.2f}x")


_SH = """
import jax, jax.numpy as jnp
from repro.core import TransformSpec, PreStoEngine, pages_from_partition
from repro.data.synth import RMDataConfig, SyntheticRecSysSource
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_mesh
cfg = RMDataConfig("x", 16, 8, 4, 8, 4, 64, 1 << 20, 100000, rows_per_partition=2048)
src = SyntheticRecSysSource(cfg, rows=2048)
spec = TransformSpec.from_source(src)
mesh = make_mesh((8, 2), ("data", "model"))
pages = {k: jnp.asarray(v) for k, v in pages_from_partition(src.partition(0), spec).items()}
for placement in ("presto", "hybrid", "disagg"):
    eng = PreStoEngine(spec, mesh, placement=placement)
    c = analyze(jax.jit(eng.preprocess_global).lower(pages).compile().as_text())
    host = ",".join(eng.host_families()) or "-"
    print(f"{placement:7s}: collective bytes = {c.coll_bytes/1e3:.1f} KB "
          f"(permute={c.coll_breakdown['collective-permute']/1e3:.1f} KB, "
          f"host families: {host})")
"""


def system_level() -> None:
    print("=== system level (16-device mesh, compiled HLO) ===")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SH], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    print(out.stdout.strip())
    print("(presto=0: preprocessing collocated with the consuming shard — "
          "the paper's in-storage placement, Fig. 8; hybrid moves only its "
          "host-placed families' bytes)")


if __name__ == "__main__":
    kernel_level()
    system_level()
