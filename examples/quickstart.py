"""Quickstart: the PreSto pipeline in ~40 lines.

Generates one encoded columnar partition (the paper's mini-batch unit),
preprocesses it with the fused ISP kernels (decode+Bucketize+SigridHash+Log
in VMEM), and takes a few DLRM training steps on the result.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.registry import get_recsys
from repro.core import PreStoEngine, TransformSpec, pages_from_partition
from repro.data.synth import SyntheticRecSysSource
from repro.distributed.sharding import ShardingRules
from repro.models import recsys as RS
from repro.train import adamw, make_train_step, warmup_cosine


def main() -> None:
    # 1. storage: a synthetic RM1-style dataset, one 512-row partition
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=512)
    spec = TransformSpec.from_source(src)
    part = src.partition(0)
    print(f"partition: {part.nbytes()/1e6:.2f} MB encoded columnar pages")

    # 2. Transform: fused ISP kernels -> train-ready mini-batch
    engine = PreStoEngine(spec)
    pages = {k: jnp.asarray(v) for k, v in pages_from_partition(part, spec).items()}
    mb = engine.jit_preprocess()(pages)
    print("mini-batch:", {k: tuple(v.shape) for k, v in mb.items()})

    # 3. Load + train: DLRM consumes the mini-batch
    rules = ShardingRules.make(None)
    params = RS.init_params(jax.random.PRNGKey(0), rcfg)
    opt = adamw(warmup_cosine(1e-3, 5, 100))
    step = jax.jit(make_train_step(lambda p, b: RS.loss_fn(p, b, rcfg, rules), opt))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    for i in range(5):
        state, metrics = step(state, mb)
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"acc={float(metrics['accuracy']):.3f}")


if __name__ == "__main__":
    main()
