"""Quickstart: the PreSto pipeline in ~40 lines, as a service client.

Submits one job to a `PreprocessingService` (the shared ISP pool): the
service's workers Extract encoded columnar partitions and Transform them
with the fused ISP kernels (decode+Bucketize+SigridHash+Log in VMEM); the
returned `Session` streams train-ready mini-batches that a DLRM consumes.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.registry import get_recsys
from repro.core import JobSpec, PreprocessingService, TransformSpec
from repro.data.storage import PartitionedStore
from repro.data.synth import SyntheticRecSysSource
from repro.distributed.sharding import ShardingRules
from repro.models import recsys as RS
from repro.train import adamw, make_train_step, warmup_cosine


def main() -> None:
    # 1. storage: a synthetic RM1-style dataset, five 512-row partitions
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=512)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(5, num_devices=4, source=src)
    print(f"partition: {src.partition(0).nbytes()/1e6:.2f} MB encoded columnar pages")

    # 2. Transform-as-a-service: submit the job, stream mini-batches
    service = PreprocessingService(num_workers=2)
    session = service.submit(JobSpec(
        name="quickstart", spec=spec, store=store,
        partitions=range(5), placement="presto"))

    # 3. Load + train: DLRM consumes the session's stream
    rules = ShardingRules.make(None)
    params = RS.init_params(jax.random.PRNGKey(0), rcfg)
    opt = adamw(warmup_cosine(1e-3, 5, 100))
    step = jax.jit(make_train_step(lambda p, b: RS.loss_fn(p, b, rcfg, rules), opt))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    for i, (pid, mb) in enumerate(session):
        state, metrics = step(state, mb)
        print(f"step {i} (partition {pid}): loss={float(metrics['loss']):.4f} "
              f"acc={float(metrics['accuracy']):.3f}")
    st = session.stats()
    print(f"session: {st.delivered}/{st.total} batches, "
          f"{st.achieved_samples_per_s:.0f} samples/s, "
          f"starvation {st.starvation:.2f}")
    service.close()


if __name__ == "__main__":
    main()
