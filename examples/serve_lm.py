"""Serve a small LM with batched requests: prefill + greedy decode.

Demonstrates the serving substrate on reduced configs of the assigned
architectures — KV caches for attention layers, recurrent state for
SSM/hybrid layers, cross-attention caches for the enc-dec model.

    PYTHONPATH=src python examples/serve_lm.py --arch jamba-v0.1-52b --gen 24
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.distributed.sharding import ShardingRules
from repro.models import encdec, transformer as tfm
from repro.train import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-v0.1-52b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced
    rules = ShardingRules.make(None)
    rng = np.random.default_rng(0)
    max_seq = args.prompt_len + args.gen
    B = args.batch

    if cfg.is_encdec:
        params = encdec.init_params(jax.random.PRNGKey(0), cfg)
        frames = jnp.asarray(rng.normal(size=(B, args.prompt_len, cfg.d_model)),
                             jnp.float32)
        enc_out = encdec.encode(params, frames, cfg, rules)
        k, hd = cfg.n_kv_heads, cfg.hd
        def cross_kv(lp):
            kk = (enc_out @ lp["xattn"]["wk"].astype(enc_out.dtype)
                  ).reshape(B, args.prompt_len, k, hd)
            vv = (enc_out @ lp["xattn"]["wv"].astype(enc_out.dtype)
                  ).reshape(B, args.prompt_len, k, hd)
            return kk, vv
        cks, cvs = jax.vmap(cross_kv)(params["dec_layers"])
        caches = {
            "self_k": jnp.zeros((cfg.n_layers, B, max_seq, k, hd), enc_out.dtype),
            "self_v": jnp.zeros((cfg.n_layers, B, max_seq, k, hd), enc_out.dtype),
            "cross_k": cks, "cross_v": cvs,
        }
        decode = lambda p, t, c, n: encdec.decode_step(p, t, c, n, cfg, rules)
        token = jnp.ones((B, 1), jnp.int32)
        start = 0
        print(f"{cfg.name}: encoded {args.prompt_len} frames; decoding...")
    else:
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        prompts = jnp.asarray(
            rng.integers(1, cfg.vocab_size, (B, args.prompt_len)), jnp.int32)
        logits, caches = jax.jit(
            lambda p, t: tfm.prefill(p, t, cfg, rules, max_seq))(params, prompts)
        token = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        decode = lambda p, t, c, n: tfm.decode_step(p, t, c, n, cfg, rules)
        start = args.prompt_len
        print(f"{cfg.name}: prefilled {B}x{args.prompt_len}; decoding...")

    serve = jax.jit(make_serve_step(decode))
    out = [token]
    t0 = time.time()
    for i in range(args.gen - 1):
        token, logits, caches = serve(params, token, caches, jnp.int32(start + i))
        out.append(token)
    jax.block_until_ready(token)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    assert gen.min() >= 0 and gen.max() < cfg.vocab_size
    print(f"decoded {args.gen-1} steps x {B} requests in {dt:.2f}s "
          f"({B*(args.gen-1)/dt:.1f} tok/s); sample: {gen[0, :12].tolist()}")


if __name__ == "__main__":
    main()
