"""Small shared utilities: timing, tree accounting, formatting."""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Iterator

import jax
import numpy as np


class Timer:
    """Wall-clock timer usable as context manager or start/stop pairs."""

    def __init__(self) -> None:
        self.elapsed = 0.0
        self._start: float | None = None

    def __enter__(self) -> "Timer":
        self._start = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        assert self._start is not None
        self.elapsed += time.perf_counter() - self._start
        self._start = None


@contextmanager
def timed(label: str, sink: dict | None = None) -> Iterator[None]:
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    if sink is not None:
        sink[label] = sink.get(label, 0.0) + dt


def bytes_of_tree(tree: Any) -> int:
    """Total nbytes across all array leaves of a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def param_count(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves if hasattr(l, "shape"))


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} EiB"


def human_flops(n: float) -> str:
    for unit in ("FLOP", "KFLOP", "MFLOP", "GFLOP", "TFLOP", "PFLOP"):
        if abs(n) < 1000.0:
            return f"{n:.2f} {unit}"
        n /= 1000.0
    return f"{n:.2f} EFLOP"
