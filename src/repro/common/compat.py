"""Version-compat shims for jax APIs that moved between releases.

The repo targets current jax but must run on older installs (e.g. 0.4.x):

* ``jax.shard_map``      — lived in ``jax.experimental.shard_map`` with
  ``check_rep``/``auto`` instead of ``check_vma``/``axis_names``.
* ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)`` —
  absent on older jax; see ``repro.launch.mesh.make_mesh``.

Every call site goes through these wrappers so the feature probe lives in
exactly one place.
"""

from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp


@functools.lru_cache(maxsize=None)
def _shard_map_impl():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # jax < 0.6
    return fn, frozenset(inspect.signature(fn).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    ``axis_names`` (partial-manual) maps to the old ``auto=`` complement;
    ``check_vma`` maps to the old ``check_rep``.
    """
    fn, params = _shard_map_impl()
    kwargs = {}
    if "check_vma" in params:
        kwargs["check_vma"] = check_vma
    elif "check_rep" in params:
        kwargs["check_rep"] = check_vma
    if axis_names is not None:
        if "axis_names" in params:
            kwargs["axis_names"] = axis_names
        elif "auto" in params:
            kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def axis_size(axis_name):
    """``jax.lax.axis_size`` with the psum(1) idiom as the old-jax fallback."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def all_gather(x, axis_name, *, axis_index=None):
    """``jax.lax.all_gather`` (stacked, axis 0), usable in partial-manual
    shard_map regions on old jax.

    Old jax/XLA (0.4.x) hard-crashes the SPMD partitioner on gather/permute
    collectives inside a partial-manual region (only the psum family
    survives), so there we emulate: each shard scatters its operand into its
    slot of a zeroed (n, ...) buffer and the buffers are psum'd — slots are
    disjoint, so the sum IS the gather, and the all-reduce keeps the operand
    dtype on the wire (e.g. int8 compressed grads).  The fallback needs the
    shard's own ``axis_index`` passed in as data (``jax.lax.axis_index`` is
    also unsupported there); callers that may run on old jax must supply it.
    """
    if hasattr(jax, "shard_map"):
        return jax.lax.all_gather(x, axis_name)
    if axis_index is None:
        raise ValueError(
            "compat.all_gather on old jax requires axis_index (pass the "
            "shard's index in as shard_map data)"
        )
    n = axis_size(axis_name)
    buf = jnp.zeros((n,) + x.shape, x.dtype)
    buf = jax.lax.dynamic_update_slice(buf, x[None], (axis_index,) + (0,) * x.ndim)
    return jax.lax.psum(buf, axis_name)
