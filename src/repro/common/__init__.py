from repro.common.util import (
    Timer,
    bytes_of_tree,
    human_bytes,
    human_flops,
    param_count,
)

__all__ = [
    "Timer",
    "bytes_of_tree",
    "human_bytes",
    "human_flops",
    "param_count",
]
