"""Elastic scaling + failure handling around the checkpoint substrate.

The contract that makes elasticity cheap in this framework:

1. checkpoints are topology-agnostic (full-array leaves; see checkpoint.py);
2. data is regenerable by (seed, partition_id) (see data.synth/tokens), so
   a resized job replays from `state['step']` with a re-partitioned id range
   and loses nothing;
3. the mesh is a pure function of the device count (launch.mesh), so a new
   incarnation simply rebuilds mesh + shardings and restores.

ElasticTrainer.run drives that loop: build mesh -> restore latest
-> train -> on simulated/real failure, reconstruct and continue.  Straggler
mitigation lives in the data layer (WorkQueue re-issue); DCN gradient
compression in train.compression.  What is intentionally NOT here: in-job
hot-swap of devices (JAX processes are fixed-topology; real deployments
restart the job binary, which is exactly the path exercised).

This trainer-side contract is the design template for the PREPROCESSING
control plane in ``core.ctrlplane``: the same regenerable-data +
checkpoint-frontier argument makes the pool's worker kill/join and service
restart bitwise-safe.  The failure drill is shared — ``fail_at`` here runs
through ``ctrlplane.FailureInjector``, the same injector the pool-side
chaos tests and ``launch/serve_preprocess.py --kill`` scripts use — so one
crash simulation covers both halves of the system.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.core.ctrlplane import FailureInjector
from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class ElasticTrainer:
    make_mesh: Callable[[], Any]  # () -> Mesh (reads the CURRENT device set)
    make_state: Callable[[Any], Any]  # mesh -> fresh sharded TrainState
    make_step: Callable[[Any], Any]  # mesh -> train_step(state, batch)
    state_shardings: Callable[[Any], Any]  # mesh -> sharding pytree
    ckpt: CheckpointManager
    checkpoint_every: int = 50

    def bootstrap(self):
        """Build (mesh, state, step_fn), restoring if a checkpoint exists."""
        mesh = self.make_mesh()
        fresh = self.make_state(mesh)
        latest = self.ckpt.latest_step()
        if latest is not None:
            shardings = self.state_shardings(mesh)
            state = self.ckpt.restore(latest, target=fresh, shardings=shardings)
        else:
            state = fresh
        return mesh, state, self.make_step(mesh)

    def run(
        self,
        batches,  # iterable of (step_idx, batch)
        *,
        max_steps: Optional[int] = None,
        fail_at: Optional[int] = None,  # simulate a node failure (test hook)
    ):
        mesh, state, step_fn = self.bootstrap()
        done = int(state["step"])
        metrics = None
        inject = FailureInjector(fail_at=fail_at)  # shared chaos drill
        for i, batch in batches:
            if i < done:
                continue  # replay-skip: data is deterministic in step idx
            inject.check(i)  # raises SimulatedFailure (a RuntimeError)
            state, metrics = step_fn(state, batch)
            done = i + 1
            if done % self.checkpoint_every == 0:
                self.ckpt.save(done, state)
            if max_steps is not None and done >= max_steps:
                break
        self.ckpt.save(done, state)
        self.ckpt.wait()
        return state, metrics
