from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import ElasticTrainer
from repro.train.optimizer import (
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    make_optimizer,
    warmup_cosine,
)
from repro.train.step import (
    apply_updates,
    init_state,
    make_compressed_train_step,
    make_serve_step,
    make_train_step,
    make_train_step_with_ingest,
    opt_state_pspecs,
    state_shardings,
)

__all__ = [
    "CheckpointManager",
    "ElasticTrainer",
    "Optimizer",
    "adafactor",
    "adamw",
    "apply_updates",
    "clip_by_global_norm",
    "init_state",
    "make_compressed_train_step",
    "make_optimizer",
    "make_serve_step",
    "make_train_step",
    "make_train_step_with_ingest",
    "opt_state_pspecs",
    "state_shardings",
    "warmup_cosine",
]
