"""Sharded optimizers: AdamW and Adafactor, plus LR schedules.

Functional API (init/update) with optimizer states inheriting the parameter
PartitionSpecs (Adam) or factored reductions of them (Adafactor rows/cols),
so optimizer memory shards exactly like parameters under FSDP+TP.

Adafactor (factored second moment, no first moment) is the default for the
300B+ MoE configs: ~4 bytes/param of optimizer+param state instead of
Adam's 12, which is what makes grok-1/llama4-maverick fit a v5e pod.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


# -- LR schedules -------------------------------------------------------------


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


# -- global-norm clipping ---------------------------------------------------------


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


# -- Optimizer interface -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Dict[str, Any]]
    update: Callable[[Any, Dict[str, Any], Any], Tuple[Any, Dict[str, Any], Dict[str, Any]]]


def adamw(
    lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.0, clip_norm: float = 1.0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        lr = lr_fn(count)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_ = b1 * m + (1 - b1) * g32
            v_ = b2 * v + (1 - b2) * g32 * g32
            step = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype), m_, v_

        flat, tdef = jax.tree_util.tree_flatten(params)
        gflat = tdef.flatten_up_to(grads)
        mflat = tdef.flatten_up_to(state["m"])
        vflat = tdef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(gflat, mflat, vflat, flat)]
        updates = tdef.unflatten([o[0] for o in out])
        new_state = {
            "m": tdef.unflatten([o[1] for o in out]),
            "v": tdef.unflatten([o[2] for o in out]),
            "count": count,
        }
        return updates, new_state, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


def adafactor(
    lr_fn, decay: float = 0.8, eps: float = 1e-30, clip_norm: float = 1.0,
    min_dim_size_to_factor: int = 128,
) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern), beta1=0."""

    def factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor and p.shape[
            -2
        ] >= min_dim_size_to_factor

    def init(params):
        def st(p):
            if factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(st, params), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        lr = lr_fn(count)
        beta = 1.0 - count.astype(jnp.float32) ** -decay

        def upd(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if factored(p):
                vr = beta * st["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * st["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (
                    vr[..., None]
                    * vc[..., None, :]
                    / jnp.maximum(vr.mean(axis=-1)[..., None, None], eps)
                )
                pre = g32 * jax.lax.rsqrt(denom + eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                pre = g32 * jax.lax.rsqrt(v + eps)
                new_st = {"v": v}
            # update clipping (RMS<=1) per Adafactor
            rms = jnp.sqrt(jnp.mean(pre * pre) + 1e-12)
            pre = pre / jnp.maximum(1.0, rms)
            return (-lr * pre).astype(p.dtype), new_st

        flat, tdef = jax.tree_util.tree_flatten(params)
        gflat = tdef.flatten_up_to(grads)
        sflat = tdef.flatten_up_to(state["f"])
        out = [upd(g, s, p) for g, s, p in zip(gflat, sflat, flat)]
        updates = tdef.unflatten([o[0] for o in out])
        new_state = {"f": tdef.unflatten([o[1] for o in out]), "count": count}
        return updates, new_state, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(name)
