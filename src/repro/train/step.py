"""Train/serve step factories: pjit programs with explicit state shardings.

make_train_step        — grads (+ optional microbatch accumulation, optional
                         cross-pod int8 compression) + optimizer update.
make_train_step_with_ingest — ONE jit program: encoded pages -> PreSto
                         preprocessing -> model -> grads -> update.  This is
                         the paper's Fig. 1 pipeline fused end-to-end; in
                         presto placement the Extract+Transform stages add
                         zero collectives to the step.
make_serve_step        — one-token decode against caches.

TrainState is a plain dict {params, opt, step[, err]} so checkpointing and
elastic re-sharding stay format-trivial.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map

from repro.distributed.sharding import ShardingRules
from repro.train.compression import crosspod_compressed_mean, init_error_state
from repro.train.optimizer import Optimizer


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# Optimizer-state pspecs via shape matching against param pspecs


def opt_state_pspecs(optimizer: Optimizer, params_struct, param_pspecs):
    """Derive opt-state PartitionSpecs: a state leaf whose shape equals the
    param's shape inherits the param pspec; factored (row/col) leaves drop
    the corresponding axis; scalars replicate."""
    state_struct = jax.eval_shape(optimizer.init, params_struct)
    pflat = jax.tree_util.tree_flatten(params_struct)[0]
    specflat = jax.tree_util.tree_flatten(
        param_pspecs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    by_shape: Dict[tuple, P] = {}
    for p, s in zip(pflat, specflat):
        by_shape.setdefault(tuple(p.shape), s)

    def match(leaf):
        shape = tuple(leaf.shape)
        if shape == ():
            return P()
        if shape in by_shape:
            return by_shape[shape]
        # factored leaf: find param whose shape[:-1] or shape[:-2]+[-1] matches
        for pshape, spec in by_shape.items():
            axes = list(spec) + [None] * (len(pshape) - len(list(spec)))
            if shape == pshape[:-1]:
                return P(*axes[:-1])
            if shape == pshape[:-2] + pshape[-1:]:
                return P(*(axes[:-2] + axes[-1:]))
        return P()

    return jax.tree.map(match, state_struct)


def state_shardings(
    mesh, optimizer: Optimizer, params_struct, param_pspecs, *, with_err: bool = False
):
    opt_specs = opt_state_pspecs(optimizer, params_struct, param_pspecs)
    specs = {"params": param_pspecs, "opt": opt_specs, "step": P()}
    if with_err:
        specs["err"] = param_pspecs
    if mesh is None:
        return specs
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def init_state(
    rng, init_params_fn: Callable, optimizer: Optimizer, *, with_err: bool = False
):
    params = init_params_fn(rng)
    state = {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if with_err:
        state["err"] = init_error_state(params)
    return state


# ---------------------------------------------------------------------------
# Train step


def make_train_step(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
    donate: bool = True,
):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def accumulate(params, batch):
        if microbatches <= 1:
            return grads_of(params, batch)
        split = lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(carry, b):
            acc, loss_sum = carry
            loss, metrics, grads = grads_of(params, b)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_sum + loss), metrics

        # accumulate in the param dtype: f32 models keep f32 accumulation;
        # bf16 giants (grok/llama4) save a full f32 param-sized buffer
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        (acc, loss_sum), metrics = jax.lax.scan(body, (zeros, 0.0), mb)
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / microbatches, metrics, grads

    def train_step(state, batch):
        loss, metrics, grads = accumulate(state["params"], batch)
        updates, opt, om = optimizer.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        new_state = dict(state, params=params, opt=opt, step=state["step"] + 1)
        return new_state, {**metrics, **om}

    return train_step


def make_compressed_train_step(
    loss_fn: Callable,
    optimizer: Optimizer,
    mesh,
    batch_pspec_fn: Callable[[Any], Any],  # batch struct -> pspecs
):
    """Train step with int8 + error-feedback gradient compression on the
    cross-pod (DCN) hop.  shard_map manual over 'pod' only: each pod computes
    its local-batch gradients (auto-sharded over data/model inside), then
    pods exchange int8 gradients.

    NOTE: `loss_fn` runs inside the pod-manual region, so it must be built
    with ShardingRules that do NOT reference the 'pod' axis (e.g.
    `ShardingRules.make(mesh, overrides={"batch": ("data",)})`) — mixing the
    manual axis into an auto sharding constraint is rejected by JAX."""
    assert "pod" in mesh.axis_names

    def train_step(state, batch):
        batch_specs = batch_pspec_fn(batch)

        def pod_body(pod_ids, params, opt, step, err, batch_pod):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch_pod
            )
            # pod_ids is P("pod")-sharded arange: pod_ids[0] is this pod's
            # index, needed by the old-jax all_gather fallback (see compat)
            grads, err = crosspod_compressed_mean(
                grads, err, "pod", axis_index=pod_ids[0]
            )
            updates, opt, om = optimizer.update(grads, opt, params)
            params = apply_updates(params, updates)
            return params, opt, step + 1, err, {**metrics, **om}

        # metric structure is loss_fn-dependent: discover it via eval_shape
        npods = mesh.shape["pod"]
        local_batch = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (x.shape[0] // npods,) + x.shape[1:], x.dtype
            ),
            batch,
        )
        params_struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state["params"]
        )
        metrics_struct = jax.eval_shape(
            lambda p, b: loss_fn(p, b)[1], params_struct, local_batch
        )
        metric_specs = jax.tree.map(
            lambda _: P(), {**metrics_struct, "grad_norm": 0, "lr": 0}
        )
        replicated = jax.tree.map(lambda _: P(), state["params"])
        opt_rep = jax.tree.map(lambda _: P(), state["opt"])
        pod_ids = jnp.arange(npods, dtype=jnp.int32)
        out = shard_map(
            pod_body,
            mesh=mesh,
            axis_names={"pod"},
            in_specs=(P("pod"), replicated, opt_rep, P(), replicated, batch_specs),
            out_specs=(replicated, opt_rep, P(), replicated, metric_specs),
            check_vma=False,
        )(pod_ids, state["params"], state["opt"], state["step"], state["err"], batch)
        params, opt, step, err, metrics = out
        return dict(params=params, opt=opt, step=step, err=err), metrics

    return train_step


def make_train_step_with_ingest(
    engine,  # PreStoEngine
    model_loss_fn: Callable,  # (params, minibatch) -> (loss, metrics)
    optimizer: Optimizer,
):
    """Fused Extract→Transform→Load→train program (paper Fig. 1)."""

    def step(state, pages):
        minibatch = engine.preprocess_global(pages)
        (loss, metrics), grads = jax.value_and_grad(model_loss_fn, has_aux=True)(
            state["params"], minibatch
        )
        updates, opt, om = optimizer.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        return dict(state, params=params, opt=opt, step=state["step"] + 1), {
            **metrics,
            **om,
        }

    return step


# ---------------------------------------------------------------------------
# Serve step


def make_serve_step(decode_fn: Callable):
    """decode_fn(params, token, caches, cache_len) -> (logits, caches)."""

    def serve_step(params, token, caches, cache_len):
        logits, new_caches = decode_fn(params, token, caches, cache_len)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_token[:, None], logits, new_caches

    return serve_step
