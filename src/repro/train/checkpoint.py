"""Fault-tolerant checkpointing: atomic, async, topology-agnostic.

Layout (one directory per step):
    <root>/step_000123.tmp/      — written first
        MANIFEST.json            — step, leaf paths, shapes, dtypes
        <leafpath>.npy           — one file per pytree leaf (full array)
    <root>/step_000123/          — atomic rename once all leaves are synced

Restart safety: readers only ever see fully-written checkpoints (the rename
is the commit point); a crash mid-save leaves only a .tmp dir that the next
writer garbage-collects.  Restore is *topology-agnostic*: leaves are full
(unsharded) arrays re-device_put against whatever mesh/shardings the new job
uses — this is what makes elastic re-scaling (Section: train.elastic) a
checkpoint round-trip.  At fleet scale you would write per-shard files +
a replica-group manifest; the format keeps that as a strict extension
(leaf files gain a shard suffix), which we note rather than implement since
this container is single-host.

Async mode: device->host transfer happens on the caller thread (cheap),
file IO on a background thread; `wait()` joins before the next save.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _leaf_path(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)
        self._gc_tmp()

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Any) -> str:
        self.wait()
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        host = [(_leaf_path(p), np.asarray(jax.device_get(v))) for p, v in flat]
        final = os.path.join(self.root, f"step_{step:09d}")

        def write():
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": []}
            for name, arr in host:
                fn = name.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"].append(
                    {"path": name, "file": fn, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)}
                )
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # commit point
            self._gc_old()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return final

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore -------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.root):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.root, d, "MANIFEST.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(
        self, step: Optional[int] = None, *, target: Any = None, shardings: Any = None
    ) -> Any:
        """Load a checkpoint.  `target` (a pytree of like-structured values or
        ShapeDtypeStructs) reconstructs the tree; `shardings` (same structure)
        device_puts each leaf for the CURRENT mesh — any topology."""
        if step is None:
            step = self.latest_step()
            assert step is not None, f"no checkpoint under {self.root}"
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        by_path = {l["path"]: l for l in manifest["leaves"]}
        assert target is not None, "restore requires a target structure"
        flat, tdef = jax.tree_util.tree_flatten_with_path(target)
        shard_flat = (
            jax.tree_util.tree_flatten(shardings, is_leaf=lambda x: x is None or hasattr(x, "mesh"))[0]
            if shardings is not None
            else [None] * len(flat)
        )
        leaves = []
        for (path, tgt), sh in zip(flat, shard_flat):
            name = _leaf_path(path)
            meta = by_path[name]
            arr = np.load(os.path.join(d, meta["file"]))
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return tdef.unflatten(leaves)

    # -- gc ------------------------------------------------------------------------
    def _gc_old(self) -> None:
        steps = sorted(
            int(_STEP_RE.match(d).group(1))
            for d in os.listdir(self.root)
            if _STEP_RE.match(d)
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"), ignore_errors=True)

    def _gc_tmp(self) -> None:
        for d in os.listdir(self.root):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)
