"""Gradient compression for the cross-pod (DCN) reduction.

Napkin math for WHERE to compress (recorded in EXPERIMENTS.md §Perf): the
intra-pod reduce runs over ICI (~50 GB/s/link); the pod-to-pod hop runs over
DCN (~6-25 GB/s effective).  Compressing the ICI stage trades cheap bytes
for VPU work; compressing the DCN stage removes the slowest wire's bytes.
So the pipeline is: full-precision reduce within pod (automatic, XLA), then
int8 all-gather + sum ACROSS pods with error feedback.

int8 quantization: per-tensor symmetric scale = max|g|/127; the residual
(g - dequant(q)) is carried in the error-feedback state and added to the
next step's gradient — unbiased in the long run (Seide et al., Karimireddy
et al.).  The all-gather of s8 operands is visible in the compiled HLO and
counts 4x fewer collective bytes than an f32 all-reduce.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import compat


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """g -> (q int8, scale f32 scalar, residual)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    residual = g32 - q.astype(jnp.float32) * scale
    return q, scale, residual


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def crosspod_compressed_mean(
    grads: Any, err: Any, axis: str = "pod", axis_index: Any = None
) -> Tuple[Any, Any]:
    """Inside a shard_map manual over `axis`: compressed mean of grads.

    grads are pod-local means; returns (global mean approx, new error state).
    ``axis_index`` (this shard's position on `axis`, as traced data) is
    required on old jax — see ``compat.all_gather``.
    """
    npods = compat.axis_size(axis)

    def one(g, e):
        q, scale, residual = quantize_int8(g + e)
        # int8 over DCN (s8 collective operands in the compiled HLO)
        q_all = compat.all_gather(q, axis, axis_index=axis_index)  # (npods, ...)
        s_all = compat.all_gather(scale, axis, axis_index=axis_index)  # (npods,)
        deq = q_all.astype(jnp.float32) * s_all.reshape(
            (npods,) + (1,) * g.ndim
        )
        return deq.mean(axis=0).astype(g.dtype), residual

    flat, tdef = jax.tree_util.tree_flatten(grads)
    eflat = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat, eflat)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])
