"""Columnar page decoders — Pallas TPU (the paper's hardwired Decode unit).

Gather-free decode via the *aligned-group layout*: 32 consecutive w-bit
values occupy exactly w uint32 words, so a (G, w) word tile decodes to a
(G, 32) value tile with only static slices/shifts — no data-dependent
addressing, which the TPU VPU cannot do efficiently.  The j-th value of
every group lives at the same static (word, bit) offset, so the kernel is an
unrolled 32-step shift/or pipeline over full vectors.

Same trick for BYTE_STREAM_SPLIT floats: each group of 4 values takes one
word from each of the 4 byte planes; reassembly is static byte shuffling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

G_BLOCK = 128  # groups per grid step


def _bitunpack_body(p: jax.Array, width: int) -> jax.Array:
    """(G, w) uint32 words -> (G, 32) uint32 values; static shifts only."""
    w = width
    mask = jnp.uint32(0xFFFFFFFF) if w == 32 else jnp.uint32((1 << w) - 1)
    cols = []
    for j in range(32):
        bit = j * w
        wid, off = bit >> 5, bit & 31
        lo = p[:, wid] >> jnp.uint32(off)
        if off == 0:
            val = lo
        elif off + w > 32:
            val = lo | (p[:, wid + 1] << jnp.uint32(32 - off))
        else:
            val = lo
        cols.append((val & mask)[:, None])
    return jnp.concatenate(cols, axis=1)


def _bitunpack_kernel(p_ref, o_ref, *, width: int):
    o_ref[0] = _bitunpack_body(p_ref[0], width).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def bitunpack_pallas(
    packed: jax.Array, *, width: int, interpret: bool = False
) -> jax.Array:
    """packed (F, G, w) uint32, G % G_BLOCK == 0 -> (F, G, 32) int32."""
    f, g, w = packed.shape
    assert w == width and g % G_BLOCK == 0, (packed.shape, width)
    return pl.pallas_call(
        functools.partial(_bitunpack_kernel, width=width),
        out_shape=jax.ShapeDtypeStruct((f, g, 32), jnp.int32),
        grid=(f, g // G_BLOCK),
        in_specs=[pl.BlockSpec((1, G_BLOCK, w), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((1, G_BLOCK, 32), lambda i, j: (i, j, 0)),
        interpret=interpret,
    )(packed)


def _bytesplit_body(p: jax.Array) -> jax.Array:
    """(G, 4) plane words -> (G, 4) f32 values."""
    cols = []
    for j in range(4):
        sh = jnp.uint32(8 * j)
        b0 = (p[:, 0] >> sh) & jnp.uint32(0xFF)
        b1 = (p[:, 1] >> sh) & jnp.uint32(0xFF)
        b2 = (p[:, 2] >> sh) & jnp.uint32(0xFF)
        b3 = (p[:, 3] >> sh) & jnp.uint32(0xFF)
        cols.append((b0 | (b1 << 8) | (b2 << 16) | (b3 << 24))[:, None])
    words = jnp.concatenate(cols, axis=1)
    return jax.lax.bitcast_convert_type(words, jnp.float32)


def _bytesplit_kernel(p_ref, o_ref):
    o_ref[0] = _bytesplit_body(p_ref[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def bytesplit_pallas(plane_words: jax.Array, *, interpret: bool = False) -> jax.Array:
    """plane_words (F, G, 4) uint32 -> (F, G, 4) f32."""
    f, g, four = plane_words.shape
    assert four == 4 and g % G_BLOCK == 0, plane_words.shape
    return pl.pallas_call(
        _bytesplit_kernel,
        out_shape=jax.ShapeDtypeStruct((f, g, 4), jnp.float32),
        grid=(f, g // G_BLOCK),
        in_specs=[pl.BlockSpec((1, G_BLOCK, 4), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((1, G_BLOCK, 4), lambda i, j: (i, j, 0)),
        interpret=interpret,
    )(plane_words)
