"""Bucketize feature-generation kernel (Alg. 1) — Pallas TPU.

Paper's FPGA unit does a pipelined binary search per element.  The TPU-native
adaptation is a *vectorized compare-and-count*: for sorted boundaries b,
``digitize(a) = #{j : b[j] <= a}``, computed as a broadcast compare reduced
over boundary chunks.  Napkin math for why this beats binary search on TPU:

* binary search = log2(m) data-dependent gathers; VMEM gathers with vector
  indices are unsupported/slow on the VPU.
* compare-and-count = m compares/element on 8x128 lanes.  At ~7.7e12 vector
  ops/s/chip, a (1024-value, m=4096) tile costs ~0.5 us and the kernel stays
  entirely compute-local: each HBM byte of feature data is read exactly once
  (Pallas grid pipelining double-buffers the next tile during compute — the
  paper's double-buffering, for free).

Inter-feature parallelism = grid dim 0 (one boundary set per feature).
Intra-feature parallelism = 8x128 vector lanes + grid dim 1 over row tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 1024  # values per grid step (8 sublanes x 128 lanes)
BOUNDARY_CHUNK = 512  # boundaries reduced per inner-loop iteration


def _bucketize_kernel(vals_ref, bounds_ref, out_ref, *, m: int):
    a = vals_ref[0, :]  # (ROW_TILE,) f32
    nchunks = m // BOUNDARY_CHUNK

    def body(k, acc):
        b = bounds_ref[0, pl.ds(k * BOUNDARY_CHUNK, BOUNDARY_CHUNK)]
        cmp = a[:, None] >= b[None, :]
        return acc + jnp.sum(cmp, axis=1, dtype=jnp.int32)

    acc = jnp.zeros((ROW_TILE,), jnp.int32)
    if nchunks > 0:
        acc = jax.lax.fori_loop(0, nchunks, body, acc)
    rem = m - nchunks * BOUNDARY_CHUNK
    if rem:
        b = bounds_ref[0, pl.ds(nchunks * BOUNDARY_CHUNK, rem)]
        acc = acc + jnp.sum(a[:, None] >= b[None, :], axis=1, dtype=jnp.int32)
    out_ref[0, :] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def bucketize_pallas(
    values: jax.Array, boundaries: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """values (F, R) f32 with R % ROW_TILE == 0; boundaries (F, m) sorted f32
    (pad with +inf to a lane multiple).  Returns (F, R) int32 in [0, m]."""
    f, r = values.shape
    _, m = boundaries.shape
    assert r % ROW_TILE == 0, (r, ROW_TILE)
    grid = (f, r // ROW_TILE)
    return pl.pallas_call(
        functools.partial(_bucketize_kernel, m=m),
        out_shape=jax.ShapeDtypeStruct((f, r), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ROW_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((1, m), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, ROW_TILE), lambda i, j: (i, j)),
        interpret=interpret,
    )(values, boundaries)
