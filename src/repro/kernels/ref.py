"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the semantic ground truth: kernels in
bucketize.py / sigridhash.py / lognorm.py / decode.py / fused.py must match
these bit-for-bit (integer ops) or to float tolerance (transcendentals).
These oracles are themselves validated against the numpy encoders in
``repro.data.encoding`` (see tests/test_kernels.py).
"""

from __future__ import annotations

import jax.numpy as jnp

# -- SigridHash (Alg. 2) ------------------------------------------------------
# TPU adaptation: TorchArrow's SigridHash is a 64-bit seeded hash; TPU vector
# lanes are 32-bit, so we use a murmur3-style 32-bit avalanche with the seed
# folded in twice.  Contract preserved: deterministic, seeded, uniform over
# [0, d).  (Recorded in DESIGN.md §2.)

def fmix32(h: jnp.ndarray) -> jnp.ndarray:
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def sigridhash(values: jnp.ndarray, seed: int, max_value: int) -> jnp.ndarray:
    """values int32 -> int32 indices in [0, max_value)."""
    v = values.astype(jnp.uint32)
    s = jnp.uint32(seed)
    h = (v ^ (s * jnp.uint32(0x9E3779B1))) * jnp.uint32(0xCC9E2D51) + s
    h = fmix32(h)
    return (h % jnp.uint32(max_value)).astype(jnp.int32)


# -- Bucketize (Alg. 1) -------------------------------------------------------


def bucketize(values: jnp.ndarray, boundaries: jnp.ndarray) -> jnp.ndarray:
    """np.digitize semantics: c[i] = #{j : boundaries[j] <= values[i]}.

    values (..., n) f32, boundaries (m,) sorted f32 -> (..., n) int32 in [0, m].
    """
    return jnp.sum(
        values[..., None] >= boundaries[(None,) * values.ndim], axis=-1
    ).astype(jnp.int32)


# -- Log normalization ---------------------------------------------------------


def lognorm(x: jnp.ndarray) -> jnp.ndarray:
    """TorchArrow-style dense normalization: log1p over non-negative features."""
    return jnp.log1p(jnp.maximum(x, 0.0))


# -- Decode: bitpack ------------------------------------------------------------


def bitunpack(packed: jnp.ndarray, n: int, width: int) -> jnp.ndarray:
    """packed uint32 (w,) flat words -> (n,) uint32 values (LSB-first)."""
    p = packed.astype(jnp.uint32)
    i = jnp.arange(n, dtype=jnp.uint32)
    bit_pos = i * jnp.uint32(width)
    word_idx = (bit_pos >> 5).astype(jnp.int32)
    bit_off = bit_pos & jnp.uint32(31)
    lo = p[word_idx] >> bit_off
    hi = jnp.where(bit_off == 0, jnp.uint32(0), p[word_idx + 1] << (32 - bit_off))
    mask = (
        jnp.uint32(0xFFFFFFFF)
        if width == 32
        else jnp.uint32((1 << width) - 1)
    )
    return (lo | hi) & mask


def bitunpack_grouped(packed_groups: jnp.ndarray, width: int) -> jnp.ndarray:
    """Grouped layout oracle: (..., G, w) words -> (..., G, 32) uint32.

    Group g holds values [32g, 32(g+1)) in words [g*w, (g+1)*w) — the layout
    the Pallas decode kernel consumes (no cross-group bit straddle).
    """
    w = width
    p = packed_groups.astype(jnp.uint32)
    outs = []
    for j in range(32):
        bit = j * w
        wid, off = bit >> 5, bit & 31
        lo = p[..., wid] >> jnp.uint32(off)
        if off == 0:
            val = lo
        else:
            nxt = p[..., wid + 1] if (off + w > 32) else jnp.zeros_like(lo)
            val = lo | (nxt << jnp.uint32(32 - off))
        mask = jnp.uint32(0xFFFFFFFF) if w == 32 else jnp.uint32((1 << w) - 1)
        outs.append(val & mask)
    return jnp.stack(outs, axis=-1)


# -- Decode: byte-stream-split ---------------------------------------------------


def bytesplit_decode_grouped(plane_words: jnp.ndarray) -> jnp.ndarray:
    """(..., G, 4) plane words -> (..., G, 4) f32 values.

    plane_words[..., g, k] = word g of byte-plane k; value i = g*4 + j takes
    byte j from each plane word g.
    """
    p = plane_words.astype(jnp.uint32)
    outs = []
    for j in range(4):
        b0 = (p[..., 0] >> jnp.uint32(8 * j)) & jnp.uint32(0xFF)
        b1 = (p[..., 1] >> jnp.uint32(8 * j)) & jnp.uint32(0xFF)
        b2 = (p[..., 2] >> jnp.uint32(8 * j)) & jnp.uint32(0xFF)
        b3 = (p[..., 3] >> jnp.uint32(8 * j)) & jnp.uint32(0xFF)
        outs.append(b0 | (b1 << 8) | (b2 << 16) | (b3 << 24))
    words = jnp.stack(outs, axis=-1)
    return jax_bitcast_u32_f32(words)


def jax_bitcast_u32_f32(x: jnp.ndarray) -> jnp.ndarray:
    import jax

    return jax.lax.bitcast_convert_type(x, jnp.float32)


# -- Fused ISP paths --------------------------------------------------------------


def fused_dense(plane_words: jnp.ndarray) -> jnp.ndarray:
    """Extract(Decode) + Log in one pass: bytesplit words -> normalized f32."""
    return lognorm(bytesplit_decode_grouped(plane_words))


def fused_sparse(
    packed_groups: jnp.ndarray, width: int, seed: int, max_value: int
) -> jnp.ndarray:
    """Extract(Decode) + SigridHash in one pass: packed ids -> hashed ids."""
    ids = bitunpack_grouped(packed_groups, width)
    return sigridhash(ids.astype(jnp.int32), seed, max_value)
