"""Log dense-feature normalization kernel — Pallas TPU.

log1p(max(x, 0)) elementwise.  Memory-bound (1 transcendental per 4 bytes in
+ 4 bytes out); exists standalone for the unfused Disagg-style pipeline and
for ablation — the PreSto path uses the fused decode+log kernel in fused.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 8
TILE_C = 1024


def _lognorm_kernel(x_ref, o_ref):
    o_ref[...] = jnp.log1p(jnp.maximum(x_ref[...], 0.0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def lognorm_pallas(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """x (R, C) f32 with R % 8 == 0, C % 1024 == 0 -> log1p(max(x,0))."""
    r, c = x.shape
    assert r % TILE_R == 0 and c % TILE_C == 0, (r, c)
    return pl.pallas_call(
        _lognorm_kernel,
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        grid=(r // TILE_R, c // TILE_C),
        in_specs=[pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j)),
        interpret=interpret,
    )(x)
