"""SigridHash feature-normalization kernel (Alg. 2) — Pallas TPU.

Seeded avalanche hash + range reduction, elementwise over sparse ids.  TPU
lanes are 32-bit so we use a murmur3-finalizer mix (see kernels/ref.py for
the contract note).  One HBM read + one HBM write per element; fully
VPU-bound.  Per-feature (seed, max_value) pairs ride in as a tiny (F, 2)
param array — grid dim 0 is the feature (inter-feature parallelism), the
8x128 lanes cover ids (intra-feature parallelism).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VAL_TILE = 1024


def hash_body(v: jax.Array, seed: jax.Array, d: jax.Array) -> jax.Array:
    """murmur3-finalizer seeded hash + range reduce; all uint32 lane ops."""
    c1 = jnp.uint32(0xCC9E2D51)
    c2 = jnp.uint32(0x85EBCA6B)
    c3 = jnp.uint32(0xC2B2AE35)
    golden = jnp.uint32(0x9E3779B1)
    h = (v ^ (seed * golden)) * c1 + seed
    h = h ^ (h >> 16)
    h = h * c2
    h = h ^ (h >> 13)
    h = h * c3
    h = h ^ (h >> 16)
    return (h % d).astype(jnp.int32)


def _hash_kernel(vals_ref, params_ref, out_ref):
    v = vals_ref[0, :].astype(jnp.uint32)
    out_ref[0, :] = hash_body(v, params_ref[0, 0], params_ref[0, 1])


@functools.partial(jax.jit, static_argnames=("interpret",))
def sigridhash_pallas(
    values: jax.Array, params: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """values (F, N) int32, params (F, 2) uint32 [seed, max_value] -> (F, N) i32."""
    f, n = values.shape
    assert n % VAL_TILE == 0, (n, VAL_TILE)
    return pl.pallas_call(
        _hash_kernel,
        out_shape=jax.ShapeDtypeStruct((f, n), jnp.int32),
        grid=(f, n // VAL_TILE),
        in_specs=[
            pl.BlockSpec((1, VAL_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, VAL_TILE), lambda i, j: (i, j)),
        interpret=interpret,
    )(values, params)
