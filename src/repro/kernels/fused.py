"""Fused ISP pipelines — Pallas TPU.  The paper's accelerator in one pass.

PreSto's PE reads encoded bytes once from flash and emits train-ready values;
every intermediate stays on-chip.  The TPU analogue: one kernel that decodes
the columnar page AND applies the transform inside VMEM, so HBM traffic is
exactly (encoded bytes in) + (train-ready bytes out).  Pallas grid
pipelining overlaps the next tile's HBM fetch with the current tile's
compute — the paper's double buffering.

fused_dense : bytesplit words --decode--> f32 --Log--> normalized f32
fused_sparse: bitpacked ids   --decode--> i32 --SigridHash--> table indices
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.decode import G_BLOCK, _bitunpack_body, _bytesplit_body
from repro.kernels.sigridhash import hash_body


def _fused_dense_kernel(p_ref, o_ref):
    x = _bytesplit_body(p_ref[0])
    o_ref[0] = jnp.log1p(jnp.maximum(x, 0.0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_dense_pallas(plane_words: jax.Array, *, interpret: bool = False) -> jax.Array:
    """(F, G, 4) encoded words -> (F, G, 4) log-normalized f32."""
    f, g, four = plane_words.shape
    assert four == 4 and g % G_BLOCK == 0, plane_words.shape
    return pl.pallas_call(
        _fused_dense_kernel,
        out_shape=jax.ShapeDtypeStruct((f, g, 4), jnp.float32),
        grid=(f, g // G_BLOCK),
        in_specs=[pl.BlockSpec((1, G_BLOCK, 4), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((1, G_BLOCK, 4), lambda i, j: (i, j, 0)),
        interpret=interpret,
    )(plane_words)


def _fused_sparse_kernel(p_ref, params_ref, o_ref, *, width: int):
    ids = _bitunpack_body(p_ref[0], width)  # (G, 32) uint32
    o_ref[0] = hash_body(ids, params_ref[0, 0], params_ref[0, 1])


def _fused_gen_kernel(p_ref, bounds_ref, params_ref, o_ref, *, m: int):
    """Feature GENERATION fully fused: bytesplit-decode -> Bucketize ->
    SigridHash, one HBM read of encoded words, one write of table ids.

    §Perf (preprocess cell): the unfused path writes/rereads the raw dense
    values and the bucket ids; fusing the whole generated-feature chain
    keeps both intermediates in VMEM (3 HBM round trips -> 1)."""
    x = _bytesplit_body(p_ref[0])  # (G, 4) f32 raw dense values
    vals = x.reshape(-1)  # (G*4,)
    chunk = 512
    nchunks = m // chunk

    def body(i, acc):
        b = bounds_ref[0, pl.ds(i * chunk, chunk)]
        return acc + jnp.sum(vals[:, None] >= b[None, :], axis=1, dtype=jnp.int32)

    acc = jnp.zeros((vals.shape[0],), jnp.int32)
    if nchunks:
        acc = jax.lax.fori_loop(0, nchunks, body, acc)
    rem = m - nchunks * chunk
    if rem:
        b = bounds_ref[0, pl.ds(nchunks * chunk, rem)]
        acc = acc + jnp.sum(vals[:, None] >= b[None, :], axis=1, dtype=jnp.int32)
    hashed = hash_body(
        acc.astype(jnp.uint32), params_ref[0, 0], params_ref[0, 1]
    )
    o_ref[0] = hashed.reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_gen_pallas(
    plane_words: jax.Array,  # (F, G, 4) encoded dense words (gen sources)
    boundaries: jax.Array,  # (F, m) sorted bucket boundaries
    params: jax.Array,  # (F, 2) uint32 [seed, max]
    *,
    interpret: bool = False,
) -> jax.Array:
    f, g, four = plane_words.shape
    _, m = boundaries.shape
    assert four == 4 and g % G_BLOCK == 0, plane_words.shape
    return pl.pallas_call(
        functools.partial(_fused_gen_kernel, m=m),
        out_shape=jax.ShapeDtypeStruct((f, g, 4), jnp.int32),
        grid=(f, g // G_BLOCK),
        in_specs=[
            pl.BlockSpec((1, G_BLOCK, 4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, m), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, G_BLOCK, 4), lambda i, j: (i, j, 0)),
        interpret=interpret,
    )(plane_words, boundaries, params)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def fused_sparse_pallas(
    packed: jax.Array, params: jax.Array, *, width: int, interpret: bool = False
) -> jax.Array:
    """packed (F, G, w) uint32, params (F, 2) uint32 [seed, max] -> (F, G, 32) i32."""
    f, g, w = packed.shape
    assert w == width and g % G_BLOCK == 0, (packed.shape, width)
    return pl.pallas_call(
        functools.partial(_fused_sparse_kernel, width=width),
        out_shape=jax.ShapeDtypeStruct((f, g, 32), jnp.int32),
        grid=(f, g // G_BLOCK),
        in_specs=[
            pl.BlockSpec((1, G_BLOCK, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, G_BLOCK, 32), lambda i, j: (i, j, 0)),
        interpret=interpret,
    )(packed, params)
