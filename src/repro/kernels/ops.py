"""Public jit'd wrappers around the Pallas preprocessing kernels.

Handles padding to tile boundaries, dtype plumbing, and the interpret-mode
switch (Pallas TPU kernels execute in interpret mode on CPU hosts — this is
how the kernels are validated in this container; on a real v5e the same
calls compile to Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bucketize as _bk
from repro.kernels import decode as _dk
from repro.kernels import fused as _fk
from repro.kernels import lognorm as _lk
from repro.kernels import sigridhash as _sk

# interpret=True whenever we are not on a real TPU.
INTERPRET: bool = jax.default_backend() != "tpu"


def _pad_axis(x: jax.Array, axis: int, multiple: int, value) -> tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def bucketize(values, boundaries, *, interpret: bool | None = None) -> jax.Array:
    """Feature generation (Alg. 1). values (F, R) f32, boundaries (F, m) sorted.

    Returns (F, R) int32 bucket ids in [0, m]."""
    interpret = INTERPRET if interpret is None else interpret
    values = jnp.asarray(values, jnp.float32)
    boundaries = jnp.asarray(boundaries, jnp.float32)
    v, r = _pad_axis(values, 1, _bk.ROW_TILE, 0.0)
    b, _ = _pad_axis(boundaries, 1, 128, jnp.inf)
    out = _bk.bucketize_pallas(v, b, interpret=interpret)
    return out[:, :r]


def sigridhash(values, seeds, max_values, *, interpret: bool | None = None) -> jax.Array:
    """Feature normalization (Alg. 2). values (F, N) i32 -> (F, N) i32 in [0, d)."""
    interpret = INTERPRET if interpret is None else interpret
    values = jnp.asarray(values)
    if values.dtype != jnp.int32:
        values = values.astype(jnp.int32)
    params = jnp.stack(
        [jnp.asarray(seeds, jnp.uint32), jnp.asarray(max_values, jnp.uint32)], axis=1
    )
    v, n = _pad_axis(values, 1, _sk.VAL_TILE, 0)
    out = _sk.sigridhash_pallas(v, params, interpret=interpret)
    return out[:, :n]


def lognorm(x, *, interpret: bool | None = None) -> jax.Array:
    """Dense normalization: log1p(max(x, 0)) elementwise, any shape."""
    interpret = INTERPRET if interpret is None else interpret
    x = jnp.asarray(x, jnp.float32)
    shape = x.shape
    flat = x.reshape(-1)
    tile = _lk.TILE_R * _lk.TILE_C
    padded, n = _pad_axis(flat, 0, tile, 0.0)
    out = _lk.lognorm_pallas(
        padded.reshape(-1, _lk.TILE_C), interpret=interpret
    ).reshape(-1)
    return out[:n].reshape(shape)


def decode_bitpack(packed, *, width: int, interpret: bool | None = None) -> jax.Array:
    """Grouped bitpack decode: (F, G, w) words -> (F, G*32) int32 values."""
    interpret = INTERPRET if interpret is None else interpret
    packed = jnp.asarray(packed).view(jnp.uint32) if isinstance(packed, np.ndarray) else jnp.asarray(packed)
    packed = packed.astype(jnp.uint32)
    f, g, w = packed.shape
    p, gorig = _pad_axis(packed, 1, _dk.G_BLOCK, 0)
    out = _dk.bitunpack_pallas(p, width=width, interpret=interpret)
    return out[:, :gorig].reshape(f, gorig * 32)


def decode_bytesplit(plane_words, *, interpret: bool | None = None) -> jax.Array:
    """Grouped byte-split decode: (F, G, 4) words -> (F, G*4) f32 values."""
    interpret = INTERPRET if interpret is None else interpret
    w = jnp.asarray(plane_words).astype(jnp.uint32)
    f, g, _ = w.shape
    p, gorig = _pad_axis(w, 1, _dk.G_BLOCK, 0)
    out = _dk.bytesplit_pallas(p, interpret=interpret)
    return out[:, :gorig].reshape(f, gorig * 4)


def fused_dense(plane_words, *, interpret: bool | None = None) -> jax.Array:
    """ISP dense path: decode + Log in one kernel. (F,G,4) -> (F, G*4) f32."""
    interpret = INTERPRET if interpret is None else interpret
    w = jnp.asarray(plane_words).astype(jnp.uint32)
    f, g, _ = w.shape
    p, gorig = _pad_axis(w, 1, _dk.G_BLOCK, 0)
    out = _fk.fused_dense_pallas(p, interpret=interpret)
    return out[:, :gorig].reshape(f, gorig * 4)


def fused_gen(
    plane_words, boundaries, seeds, max_values, *, interpret: bool | None = None
) -> jax.Array:
    """ISP generation path: decode + Bucketize + SigridHash in one kernel.

    plane_words (F, G, 4) encoded dense sources, boundaries (F, m) sorted ->
    (F, G*4) int32 table indices."""
    interpret = INTERPRET if interpret is None else interpret
    w = jnp.asarray(plane_words).astype(jnp.uint32)
    f, g, _ = w.shape
    b = jnp.asarray(boundaries, jnp.float32)
    b, _ = _pad_axis(b, 1, 128, jnp.inf)
    params = jnp.stack(
        [jnp.asarray(seeds, jnp.uint32), jnp.asarray(max_values, jnp.uint32)], axis=1
    )
    pw, gorig = _pad_axis(w, 1, _dk.G_BLOCK, 0)
    out = _fk.fused_gen_pallas(pw, b, params, interpret=interpret)
    return out[:, :gorig].reshape(f, gorig * 4)


def fused_sparse(
    packed, seeds, max_values, *, width: int, interpret: bool | None = None
) -> jax.Array:
    """ISP sparse path: decode + SigridHash in one kernel.

    packed (F, G, w) uint32 -> (F, G*32) int32 indices in [0, d)."""
    interpret = INTERPRET if interpret is None else interpret
    packed = jnp.asarray(packed).astype(jnp.uint32)
    f, g, w = packed.shape
    params = jnp.stack(
        [jnp.asarray(seeds, jnp.uint32), jnp.asarray(max_values, jnp.uint32)], axis=1
    )
    p, gorig = _pad_axis(packed, 1, _dk.G_BLOCK, 0)
    out = _fk.fused_sparse_pallas(p, params, width=width, interpret=interpret)
    return out[:, :gorig].reshape(f, gorig * 32)


# -- host-side layout helpers -------------------------------------------------


def regroup_bitpack(packed_flat: np.ndarray, n_values: int, width: int) -> np.ndarray:
    """Flat packed words (from data.encoding.bitpack) -> (G, w) grouped layout.

    Requires n_values % 32 == 0 (dataset partitions guarantee this)."""
    assert n_values % 32 == 0, n_values
    g = n_values // 32
    return np.ascontiguousarray(packed_flat[: g * width].reshape(g, width))


def regroup_bytesplit(plane_words_flat: np.ndarray, n_values: int) -> np.ndarray:
    """Flat plane words (from bytesplit_encode) -> (G, 4) grouped layout."""
    assert n_values % 4 == 0, n_values
    g = n_values // 4
    planes = plane_words_flat[: g * 4].reshape(4, g)
    return np.ascontiguousarray(planes.T)
