# Pallas TPU kernels for the compute hot-spots the paper accelerates in its
# ISP units: Decode (columnar pages), Bucketize (feature generation),
# SigridHash + Log (feature normalization), and the fused decode+transform
# ISP pipelines.  ops.py = jit'd public wrappers; ref.py = pure-jnp oracles.
from repro.kernels import ops, ref
from repro.kernels.ops import (
    bucketize,
    decode_bitpack,
    decode_bytesplit,
    fused_dense,
    fused_gen,
    fused_sparse,
    lognorm,
    regroup_bitpack,
    regroup_bytesplit,
    sigridhash,
)

# -- op -> kernel registry -----------------------------------------------------
# Consulted by the opgraph lowering (repro.core.opgraph): OP_KERNELS maps a
# single operator kind to its standalone pass; FUSED_KERNELS maps a chain of
# operator kinds (one column family's decode->transform chain) to the single
# Pallas kernel that executes the whole chain in one HBM round-trip — a chain
# is ISP-fusable iff its kind tuple has an entry here.
OP_KERNELS = {
    "decode.bytesplit": decode_bytesplit,
    "decode.bitpack": decode_bitpack,
    "bucketize": bucketize,
    "sigridhash": sigridhash,
    "lognorm": lognorm,
}

FUSED_KERNELS = {
    ("decode.bytesplit", "lognorm"): fused_dense,
    ("decode.bitpack", "sigridhash"): fused_sparse,
    ("decode.bytesplit", "bucketize", "sigridhash"): fused_gen,
}

# Operator kinds whose output at row r depends ONLY on input values of row r
# (decodes, per-value transforms, and their fusions — everything here is
# elementwise over the row-group axis, with per-feature parameters riding the
# feature axis).  This is the property that makes the megabatched produce
# path safe: stacking K partitions along the row axis and running ONE launch
# is bitwise identical to K solo launches iff every lowered stage kind is
# row-local.  ``core.opgraph.LoweredPlan.megabatch_safe`` consults this set;
# a new operator that mixes rows (e.g. a batch-norm over the partition) must
# NOT be added here, and its plans will simply refuse to megabatch.
ROW_LOCAL_KINDS = frozenset(
    {
        "decode.bytesplit",
        "decode.bitpack",
        "decode.lengths",
        "decode.labels",
        "bucketize",
        "sigridhash",
        "lognorm",
        "formbatch",  # pure per-row reshapes/transposes
    }
    | {"fused:" + "+".join(kinds) for kinds in FUSED_KERNELS}
)

__all__ = [
    "FUSED_KERNELS",
    "OP_KERNELS",
    "ROW_LOCAL_KINDS",
    "bucketize",
    "decode_bitpack",
    "decode_bytesplit",
    "fused_dense",
    "fused_gen",
    "fused_sparse",
    "lognorm",
    "ops",
    "ref",
    "regroup_bitpack",
    "regroup_bytesplit",
    "sigridhash",
]
