# Pallas TPU kernels for the compute hot-spots the paper accelerates in its
# ISP units: Decode (columnar pages), Bucketize (feature generation),
# SigridHash + Log (feature normalization), and the fused decode+transform
# ISP pipelines.  ops.py = jit'd public wrappers; ref.py = pure-jnp oracles.
from repro.kernels import ops, ref
from repro.kernels.ops import (
    bucketize,
    decode_bitpack,
    decode_bytesplit,
    fused_dense,
    fused_gen,
    fused_sparse,
    lognorm,
    regroup_bitpack,
    regroup_bytesplit,
    sigridhash,
)

__all__ = [
    "bucketize",
    "decode_bitpack",
    "decode_bytesplit",
    "fused_dense",
    "fused_gen",
    "fused_sparse",
    "lognorm",
    "ops",
    "ref",
    "regroup_bitpack",
    "regroup_bytesplit",
    "sigridhash",
]
