from repro.distributed.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    logical_pspec,
    shard_activation,
)

__all__ = [
    "DEFAULT_RULES",
    "ShardingRules",
    "logical_pspec",
    "shard_activation",
]
