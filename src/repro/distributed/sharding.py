"""Logical-axis sharding rules (MaxText-style) for DP/FSDP/TP/EP/SP.

Every parameter and key activation is annotated with *logical* axis names;
a ShardingRules table maps those to physical mesh axes.  The production
meshes are (data, model) single-pod and (pod, data, model) multi-pod:

  batch   -> (pod, data)   data parallelism (pod is an outer pure-DP axis)
  vocab   -> model          TP: embedding/LM-head row sharding
  heads   -> model          TP: attention head sharding
  ff      -> model          TP: MLP hidden sharding
  experts -> model          EP: expert sharding for MoE
  fsdp    -> data           FSDP: weight + optimizer-state sharding of the
                            non-TP weight axis (all-gathered per layer)
  kv_seq  -> data           SP/CP: KV-cache sequence sharding for
                            long-context decode (batch too small to shard)
  tables  -> model          RecSys: embedding-table row sharding

Rules are a plain dict so configs can override per-arch (e.g. disable FSDP
for small models, enable kv_seq only for long_500k).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

AxisVal = Union[None, str, tuple]

DEFAULT_RULES: dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,  # activation d_model axis: replicated
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "fsdp": "data",
    "kv_seq": None,  # set to "data" for context-parallel decode
    "tables": "model",
    "layers": None,  # scan-stacked leading axis
    "ssm_heads": "model",
    "conv": None,
}


@dataclasses.dataclass
class ShardingRules:
    mapping: dict[str, AxisVal]
    mesh: Optional[Mesh] = None

    @staticmethod
    def make(
        mesh: Optional[Mesh] = None, overrides: Optional[Mapping[str, AxisVal]] = None
    ) -> "ShardingRules":
        m = dict(DEFAULT_RULES)
        if overrides:
            m.update(overrides)
        # drop mesh axes that don't exist on this mesh (e.g. "pod" single-pod)
        if mesh is not None:
            def filt(v: AxisVal) -> AxisVal:
                if v is None:
                    return None
                if isinstance(v, str):
                    return v if v in mesh.axis_names else None
                kept = tuple(a for a in v if a in mesh.axis_names)
                return kept if kept else None

            m = {k: filt(v) for k, v in m.items()}
        return ShardingRules(m, mesh)

    def pspec(self, *logical: Optional[str]) -> P:
        return logical_pspec(self.mapping, *logical)

    def sharding(self, *logical: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(*logical))

    def constrain(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        return shard_activation(x, self, *logical)

    def axis_size(self, logical: str) -> int:
        """Product of mesh-axis sizes a logical axis maps to (1 if unmapped)."""
        if self.mesh is None:
            return 1
        v = self.mapping.get(logical)
        if v is None:
            return 1
        axes = (v,) if isinstance(v, str) else v
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


def logical_pspec(rules: Mapping[str, AxisVal], *logical: Optional[str]) -> P:
    """('vocab','fsdp') -> P('model','data') under the default rules."""
    axes = []
    used: set[str] = set()

    def resolve(name: Optional[str]) -> AxisVal:
        if name is None:
            return None
        v = rules.get(name)
        if v is None:
            return None
        # a physical mesh axis may be used at most once in a PartitionSpec
        if isinstance(v, str):
            return None if v in used else (used.add(v) or v)
        kept = tuple(a for a in v if a not in used)
        used.update(kept)
        return kept if kept else None

    for name in logical:
        axes.append(resolve(name))
    return P(*axes)


def shard_activation(x: jax.Array, rules: ShardingRules, *logical) -> jax.Array:
    """with_sharding_constraint if a mesh is active; no-op otherwise."""
    if rules.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.pspec(*logical))
    )
