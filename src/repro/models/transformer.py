"""Periodic decoder LM: one module covering dense / MoE / hybrid / SSM / VLM.

The layer stack is `n_periods` repetitions of a heterogeneous *period*
(cfg.period()).  Parameters are stacked over periods and the stack runs
under `jax.lax.scan` with the pattern unrolled inside the body — an
80-layer model lowers to a compact HLO while still expressing gemma3's
5:1 local:global, jamba's 1:7 attn:mamba + MoE, llama4's interleaved
chunked attention, etc.

Three entry points:
  loss_fn     — training forward + chunked softmax cross-entropy
  prefill     — full-sequence forward that also fills KV/SSM caches
  decode_step — one-token serve step against the caches

All activations carry logical sharding constraints; caches for long-context
decode can shard their sequence axis (context parallelism) via
`ShardingRules` overrides.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (
    ParamDef,
    Schema,
    apply_rope,
    blockwise_attention,
    cp_decode_attention,
    decode_attention,
    init_from_schema,
    load_weight,
    mlp_apply,
    mlp_schema,
    pspecs_from_schema,
    rmsnorm,
    stack_schema,
)
from repro.models.moe import moe_apply, moe_schema
from repro.models.ssm import (
    mamba_apply,
    mamba_decode_step,
    mamba_schema,
    ssm_dims,
)

# ---------------------------------------------------------------------------
# Schemas


def attn_schema(cfg: ModelConfig) -> Schema:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": ParamDef((d, h * hd), ("fsdp", "heads")),
        "wk": ParamDef((d, k * hd), ("fsdp", "kv_heads")),
        "wv": ParamDef((d, k * hd), ("fsdp", "kv_heads")),
        "wo": ParamDef((h * hd, d), ("heads", "fsdp")),
    }


def layer_schema(cfg: ModelConfig, spec: LayerSpec) -> Schema:
    d = cfg.d_model
    s: Schema = {"ln1": ParamDef((d,), (None,), init="zeros")}
    if spec.kind == "attn":
        s["attn"] = attn_schema(cfg)
    else:
        s["mamba"] = mamba_schema(cfg)
    if cfg.d_ff > 0:
        s["ln2"] = ParamDef((d,), (None,), init="zeros")
        if spec.mlp_kind == "moe":
            s["mlp"] = moe_schema(cfg)
        else:
            s["mlp"] = mlp_schema(cfg, spec.mlp_kind)
    return s


def model_schema(cfg: ModelConfig) -> Schema:
    d, v = cfg.d_model, cfg.padded_vocab
    period = {
        f"p{i}": layer_schema(cfg, spec) for i, spec in enumerate(cfg.period())
    }
    s: Schema = {
        # NOTE: vocab-only sharding — a (vocab, fsdp) 2D-sharded table makes
        # the SPMD partitioner fully rematerialize the gather (observed on
        # XLA CPU+TPU); the all-gather of a vocab-sharded table is cheap and
        # overlapped. See EXPERIMENTS.md §Perf.
        "embed": ParamDef((v, d), ("vocab", None), scale=1.0),
        "final_ln": ParamDef((d,), (None,), init="zeros"),
        "layers": stack_schema(period, cfg.n_periods),
    }
    if not cfg.tie_embeddings:
        s["head"] = ParamDef((d, v), ("fsdp", "vocab"))
    return s


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    return init_from_schema(rng, model_schema(cfg), jnp.dtype(cfg.param_dtype))


def param_pspecs(cfg: ModelConfig, rules: ShardingRules) -> Dict[str, Any]:
    return pspecs_from_schema(model_schema(cfg), rules)


# ---------------------------------------------------------------------------
# Layer application


def _kv_axis(cfg: ModelConfig, rules: ShardingRules):
    """KV projections head-shard only when kv heads divide the TP size;
    otherwise REPLICATE the (small) kv activations.  A 16-way constraint on
    K*hd with K=2-8 splits head_dim across shards, and attention then
    contracts a sharded hd -> per-block partial-sum all-reduces inside the
    q/kv scans (measured 85 GB/step on glm4).  §Perf iteration 2."""
    return "kv_heads" if cfg.n_kv_heads % max(rules.axis_size("kv_heads"), 1) == 0 else None


def _attn_apply_train(
    p,
    x: jax.Array,
    positions: jax.Array,
    spec: LayerSpec,
    cfg: ModelConfig,
    rules: ShardingRules,
    segment_ids: Optional[jax.Array],
) -> jax.Array:
    b, s, d = x.shape
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype
    xn = rmsnorm(x, p["ln1"], cfg.norm_eps)
    # constrain the FLATTENED projections (always evenly divisible); 4D
    # constraints on (.., K, hd) force uneven shardings when K < TP size
    # and trigger SPMD full-rematerialization copies.
    wq = load_weight(p["attn"]["wq"], rules, None, "heads", dtype=dt)
    wk = load_weight(p["attn"]["wk"], rules, None, "kv_heads", dtype=dt)
    wv = load_weight(p["attn"]["wv"], rules, None, "kv_heads", dtype=dt)
    kv_ax = _kv_axis(cfg, rules)
    q2 = rules.constrain(xn @ wq, "batch", "seq", "heads")
    k2 = rules.constrain(xn @ wk, "batch", "seq", kv_ax)
    v2 = rules.constrain(xn @ wv, "batch", "seq", kv_ax)
    q = q2.reshape(b, s, h, hd)
    kk = k2.reshape(b, s, k, hd)
    vv = v2.reshape(b, s, k, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    kk = apply_rope(kk, positions, cfg.rope_theta)
    out = blockwise_attention(
        q,
        kk,
        vv,
        pattern=spec.attn_pattern,
        window=cfg.window,
        chunk=cfg.chunk_size,
        causal=True,
        segment_ids_q=segment_ids,
        segment_ids_kv=segment_ids,
    )
    wo = load_weight(p["attn"]["wo"], rules, "heads", None, dtype=dt)
    out = out.reshape(b, s, h * hd) @ wo
    return x + rules.constrain(out, "batch", "seq", "embed")


def _mlp_or_moe(p, x, spec, cfg, rules) -> Tuple[jax.Array, jax.Array]:
    if cfg.d_ff == 0:
        return x, jnp.zeros((), jnp.float32)
    xn = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if spec.mlp_kind == "moe":
        out, aux = moe_apply(p["mlp"], xn, cfg, rules)
    else:
        out, aux = mlp_apply(p["mlp"], xn, spec.mlp_kind, rules), jnp.zeros(
            (), jnp.float32
        )
    return x + out, aux


def _period_apply_train(
    pparams,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    rules: ShardingRules,
    segment_ids: Optional[jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(cfg.period()):
        lp = pparams[f"p{i}"]
        if spec.kind == "attn":
            x = _attn_apply_train(lp, x, positions, spec, cfg, rules, segment_ids)
        else:
            xn = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            x = x + mamba_apply(lp["mamba"], xn, cfg, rules)
        x, aux = _mlp_or_moe(lp, x, spec, cfg, rules)
        aux_total = aux_total + aux
    return x, aux_total


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


# ---------------------------------------------------------------------------
# Forward passes


def _embed_tokens(params, tokens: jax.Array, cfg: ModelConfig, rules) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    return rules.constrain(x, "batch", "seq", "embed")


def _backbone(
    params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    rules: ShardingRules,
    segment_ids: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Runs the scanned layer stack. Returns (hidden, moe_aux)."""

    def body(carry, pparams):
        h, aux = carry
        h, aux_p = _period_apply_train(pparams, h, positions, cfg, rules, segment_ids)
        return (h, aux + aux_p), None

    body_fn = body
    policy = _remat_policy(cfg)
    if policy is not None:
        body_fn = jax.checkpoint(body, policy=policy)
    (h, aux), _ = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    return rmsnorm(h, params["final_ln"], cfg.norm_eps), aux


def _logits_head(params, h: jax.Array, cfg: ModelConfig, rules) -> jax.Array:
    dt = h.dtype
    if cfg.tie_embeddings:
        w = params["embed"].T.astype(dt)
    else:
        w = load_weight(params["head"], rules, None, "vocab", dtype=dt)
    logits = h @ w
    if cfg.padded_vocab != cfg.vocab_size:  # mask padding rows
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    return rules.constrain(logits, "batch", "seq", "vocab")


def chunked_xent(
    params,
    h: jax.Array,  # (B, S, d) final hidden
    labels: jax.Array,  # (B, S)
    mask: jax.Array,  # (B, S) float/bool
    cfg: ModelConfig,
    rules: ShardingRules,
    block: int = 1024,
) -> jax.Array:
    """Cross-entropy without materializing (B,S,V): scan over seq blocks."""
    b, s, d = h.shape
    block = min(block, s)
    while s % block:  # largest divisor of s not exceeding the target block
        block -= 1
    nb = s // block
    hb = h.reshape(b, nb, block, d).transpose(1, 0, 2, 3)
    lb = labels.reshape(b, nb, block).transpose(1, 0, 2)
    mb = mask.reshape(b, nb, block).transpose(1, 0, 2).astype(jnp.float32)

    def blk(carry, xs):
        tot, cnt = carry
        hx, lx, mx = xs
        logits = _logits_head(params, hx, cfg, rules).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mx
        return (tot + nll.sum(), cnt + mx.sum()), None

    blk_fn = jax.checkpoint(blk) if cfg.remat != "none" else blk
    zero = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if nb == 1:
        # single block: skip the loop (also keeps scans un-nested, which old
        # XLA requires inside partial-manual shard_map regions)
        (tot, cnt), _ = blk_fn(zero, (hb[0], lb[0], mb[0]))
    else:
        (tot, cnt), _ = jax.lax.scan(blk_fn, zero, (hb, lb, mb))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(
    params,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    rules: ShardingRules,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Training loss. batch: tokens (B,S), labels (B,S), mask (B,S);
    optional prefix_embeds (B,P,d) for VLM/audio frontends (stubbed)."""
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens, cfg, rules)
    prefix = batch.get("prefix_embeds")
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    seg = batch.get("segment_ids")
    if seg is not None and prefix is not None:
        seg = jnp.concatenate(
            [jnp.zeros((b, prefix.shape[1]), seg.dtype), seg], axis=1
        )
    h, aux = _backbone(params, x, positions, cfg, rules, seg)
    if prefix is not None:
        h = h[:, prefix.shape[1] :, :]
    xent = chunked_xent(params, h, batch["labels"], batch["mask"], cfg, rules)
    loss = xent + 0.01 * aux
    return loss, {"loss": loss, "xent": xent, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving: cache structure, prefill, decode


def cache_spec(
    cfg: ModelConfig, batch: int, max_seq: int
) -> Dict[str, Any]:
    """ShapeDtypeStructs of the decode cache pytree."""
    np_, hd, k = cfg.n_periods, cfg.hd, cfg.n_kv_heads
    dt = jnp.dtype(cfg.dtype)
    d_in, h, p, n = ssm_dims(cfg) if any(
        s.kind == "mamba" for s in cfg.period()
    ) else (0, 0, 0, 0)
    out: Dict[str, Any] = {}
    for i, spec in enumerate(cfg.period()):
        if spec.kind == "attn":
            out[f"p{i}"] = {
                "k": jax.ShapeDtypeStruct((np_, batch, max_seq, k, hd), dt),
                "v": jax.ShapeDtypeStruct((np_, batch, max_seq, k, hd), dt),
            }
        else:
            ch = d_in + 2 * cfg.ssm_state
            out[f"p{i}"] = {
                "h": jax.ShapeDtypeStruct((np_, batch, h, p, n), jnp.float32),
                "conv": jax.ShapeDtypeStruct(
                    (np_, batch, cfg.conv_width - 1, ch), dt
                ),
            }
    return out


def cache_pspecs(cfg: ModelConfig, rules: ShardingRules) -> Dict[str, Any]:
    # kv_heads shard over 'model' only when divisible (GQA kv counts are
    # usually < the 16-way TP axis; jit in_shardings demand divisibility)
    model_n = rules.mesh.shape.get("model", 1) if rules.mesh else 1
    kv_ax = "kv_heads" if cfg.n_kv_heads % max(model_n, 1) == 0 else None
    out: Dict[str, Any] = {}
    for i, spec in enumerate(cfg.period()):
        if spec.kind == "attn":
            p = rules.pspec("layers", "batch", "kv_seq", kv_ax, None)
            out[f"p{i}"] = {"k": p, "v": p}
        else:
            out[f"p{i}"] = {
                "h": rules.pspec("layers", "batch", "ssm_heads", None, None),
                "conv": rules.pspec("layers", "batch", None, None),
            }
    return out


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, max_seq)
    )


def _attn_decode(
    p,
    x: jax.Array,  # (B,1,d)
    lcache: Dict[str, jax.Array],
    cache_len: jax.Array,  # scalar: tokens already in cache
    spec: LayerSpec,
    cfg: ModelConfig,
    rules: ShardingRules,
    mesh,
    shard_kv_seq: bool,
):
    b = x.shape[0]
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype
    xn = rmsnorm(x, p["ln1"], cfg.norm_eps)
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    wq = load_weight(p["attn"]["wq"], rules, None, "heads", dtype=dt)
    wk = load_weight(p["attn"]["wk"], rules, None, "kv_heads", dtype=dt)
    wv = load_weight(p["attn"]["wv"], rules, None, "kv_heads", dtype=dt)
    q = apply_rope((xn @ wq).reshape(b, 1, h, hd), pos, cfg.rope_theta)
    kt = apply_rope((xn @ wk).reshape(b, 1, k, hd), pos, cfg.rope_theta)
    vt = (xn @ wv).reshape(b, 1, k, hd)
    kc = jax.lax.dynamic_update_slice(lcache["k"], kt, (0, cache_len, 0, 0))
    vc = jax.lax.dynamic_update_slice(lcache["v"], vt, (0, cache_len, 0, 0))
    valid = jnp.full((b,), cache_len + 1, jnp.int32)
    if shard_kv_seq and mesh is not None and "data" in mesh.axis_names:
        out = cp_decode_attention(
            q, kc, vc, valid, mesh=mesh, axis="data",
            pattern=spec.attn_pattern, window=cfg.window, chunk=cfg.chunk_size,
        )
    else:
        out = decode_attention(
            q, kc, vc, valid,
            pattern=spec.attn_pattern, window=cfg.window, chunk=cfg.chunk_size,
        )
    wo = load_weight(p["attn"]["wo"], rules, "heads", None, dtype=dt)
    out = out.reshape(b, 1, h * hd) @ wo
    return x + out, {"k": kc, "v": vc}


def decode_step(
    params,
    token: jax.Array,  # (B, 1) int32
    caches: Dict[str, Any],
    cache_len: jax.Array,  # scalar int32
    cfg: ModelConfig,
    rules: ShardingRules,
    *,
    mesh=None,
    shard_kv_seq: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One serve step: next-token logits + updated caches."""
    x = _embed_tokens(params, token, cfg, rules)

    def body(h, xs):
        pparams, pcache = xs
        new_cache = {}
        for i, spec in enumerate(cfg.period()):
            lp, lc = pparams[f"p{i}"], pcache[f"p{i}"]
            if spec.kind == "attn":
                h, nc = _attn_decode(
                    lp, h, lc, cache_len, spec, cfg, rules, mesh, shard_kv_seq
                )
            else:
                xn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
                dh, nc = mamba_decode_step(lp["mamba"], xn, cfg, rules, lc)
                h = h + dh
            h, _ = _mlp_or_moe(lp, h, spec, cfg, rules)
            new_cache[f"p{i}"] = nc
        return h, new_cache

    h, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    logits = _logits_head(params, h, cfg, rules)
    return logits, new_caches


def prefill(
    params,
    tokens: jax.Array,  # (B, S)
    cfg: ModelConfig,
    rules: ShardingRules,
    max_seq: int,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Full forward that fills caches up to S; returns last-position logits.

    Cache tensors are allocated at max_seq; positions [0, S) are written."""
    b, s = tokens.shape
    x = _embed_tokens(params, tokens, cfg, rules)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h_dim, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)

    def body(h, pparams):
        new_cache = {}
        for i, spec in enumerate(cfg.period()):
            lp = pparams[f"p{i}"]
            if spec.kind == "attn":
                xn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
                wq = load_weight(lp["attn"]["wq"], rules, None, "heads", dtype=dt)
                wk = load_weight(lp["attn"]["wk"], rules, None, "kv_heads", dtype=dt)
                wv = load_weight(lp["attn"]["wv"], rules, None, "kv_heads", dtype=dt)
                q = apply_rope(
                    (xn @ wq).reshape(b, s, h_dim, hd), positions, cfg.rope_theta
                )
                kk = apply_rope(
                    (xn @ wk).reshape(b, s, k, hd), positions, cfg.rope_theta
                )
                vv = (xn @ wv).reshape(b, s, k, hd)
                out = blockwise_attention(
                    q, kk, vv,
                    pattern=spec.attn_pattern, window=cfg.window,
                    chunk=cfg.chunk_size, causal=True,
                )
                wo = load_weight(lp["attn"]["wo"], rules, "heads", None, dtype=dt)
                out = out.reshape(b, s, h_dim * hd) @ wo
                h = h + out
                pad = max_seq - s
                kc = jnp.pad(kk, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(vv, ((0, 0), (0, pad), (0, 0), (0, 0)))
                new_cache[f"p{i}"] = {"k": kc, "v": vc}
            else:
                xn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
                dh, hT = mamba_apply(
                    lp["mamba"], xn, cfg, rules, return_state=True
                )
                h = h + dh
                # conv window: last W-1 pre-conv channels — recompute cheaply
                d_in, _, _, n = ssm_dims(cfg)
                zx = xn @ lp["mamba"]["zx_proj"].astype(dt)
                bcdt = xn @ lp["mamba"]["bcdt_proj"].astype(dt)
                cur = jnp.concatenate(
                    [zx[..., d_in:], bcdt[..., : 2 * n]], axis=-1
                )
                w = cfg.conv_width
                new_cache[f"p{i}"] = {
                    "h": hT,
                    "conv": cur[:, s - (w - 1) :, :],
                }
            h, _ = _mlp_or_moe(lp, h, cfg.period()[i], cfg, rules)
        return h, new_cache

    h, caches = jax.lax.scan(body, x, params["layers"])
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    logits = _logits_head(params, h[:, -1:, :], cfg, rules)
    return logits, caches
