"""Foundational neural layers: schema-driven params, norms, RoPE, attention.

Parameters are declared via ParamDef schemas — a single source of truth that
yields (a) initialized pytrees, (b) PartitionSpec pytrees for pjit, so init
and sharding can never drift apart.

Attention is blockwise with online softmax (an XLA-level flash attention):
memory stays O(q_block x kv_block) regardless of sequence length, which is
what makes prefill_32k and long_500k lowerable.  Patterns (causal, sliding
window, chunked) are expressed as per-block masks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map

from repro.distributed.sharding import ShardingRules

# ---------------------------------------------------------------------------
# Param schemas


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple  # logical axis names (len == ndim)
    init: str = "normal"  # normal | zeros | ones
    scale: Optional[float] = None  # None -> 1/sqrt(fan_in)

    def fan_in(self) -> int:
        # second-minor dim: correct for (d_in, d_out), stacked (L, d_in,
        # d_out), and expert (E, d_in, d_out) layouts alike
        return self.shape[-2] if len(self.shape) > 1 else self.shape[-1]


Schema = Dict[str, Any]  # nested dict of ParamDef


def _path_seed(path: str) -> int:
    import zlib

    return zlib.crc32(path.encode())


def init_from_schema(rng: jax.Array, schema: Schema, dtype) -> Dict[str, Any]:
    def walk(node, path):
        if isinstance(node, ParamDef):
            key = jax.random.fold_in(rng, _path_seed(path))
            if node.init == "zeros":
                return jnp.zeros(node.shape, dtype)
            if node.init == "ones":
                return jnp.ones(node.shape, dtype)
            scale = node.scale if node.scale is not None else 1.0 / math.sqrt(
                max(node.fan_in(), 1)
            )
            return (jax.random.normal(key, node.shape, jnp.float32) * scale).astype(
                dtype
            )
        return {k: walk(v, f"{path}/{k}") for k, v in node.items()}

    return walk(schema, "")


def pspecs_from_schema(schema: Schema, rules: ShardingRules) -> Dict[str, Any]:
    def walk(node):
        if isinstance(node, ParamDef):
            return rules.pspec(*node.axes)
        return {k: walk(v) for k, v in node.items()}

    return walk(schema)


def shapes_from_schema(schema: Schema, dtype) -> Dict[str, Any]:
    def walk(node):
        if isinstance(node, ParamDef):
            return jax.ShapeDtypeStruct(node.shape, dtype)
        return {k: walk(v) for k, v in node.items()}

    return walk(schema)


def stack_schema(schema: Schema, n: int) -> Schema:
    """Prepend a scan ('layers') axis of length n to every leaf."""

    def walk(node):
        if isinstance(node, ParamDef):
            return ParamDef(
                (n,) + node.shape, ("layers",) + node.axes, node.init, node.scale
            )
        return {k: walk(v) for k, v in node.items()}

    return walk(schema)


def load_weight(p: jax.Array, rules: ShardingRules, *axes, dtype) -> jax.Array:
    """FSDP weight load: cast to the compute dtype and constrain WITHOUT the
    fsdp axis — an explicit bf16 all-gather of the weight shard.

    Without this, XLA's SPMD partitioner may instead reshard the
    ACTIVATIONS to contract against the fsdp-sharded weight: measured on
    glm4-9b train, that choice moves f32 activation tensors ~8x per layer
    per microbatch (345 GB/step/device of all-gather alone) versus ~46 GB
    for bf16 weight-gathering.  §Perf iteration 1."""
    return rules.constrain(p.astype(dtype), *axes)


# ---------------------------------------------------------------------------
# Norms


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D), positions (..., S) -> rotated x (half-split RoPE)."""
    d = x.shape[-1]
    half = d // 2
    freq = (theta ** (-np.arange(0, half, dtype=np.float32) / half)).astype(np.float32)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (train/prefill)


def _pattern_mask(
    qpos: jax.Array, kpos: jax.Array, pattern: str, window: int, chunk: int, causal: bool
) -> jax.Array:
    """(Qb, KVb) bool mask from positions."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if pattern == "swa" and window > 0:
        m &= (qpos[:, None] - kpos[None, :]) < window
    if pattern == "chunked" and chunk > 0:
        m &= (qpos[:, None] // chunk) == (kpos[None, :] // chunk)
    return m


def blockwise_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, K, D)
    v: jax.Array,  # (B, Skv, K, D)
    *,
    pattern: str = "full",
    window: int = 0,
    chunk: int = 0,
    causal: bool = True,
    q_offset: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    segment_ids_q: Optional[jax.Array] = None,
    segment_ids_kv: Optional[jax.Array] = None,
) -> jax.Array:
    """Online-softmax attention, O(q_block*kv_block) memory. GQA via groups."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0, (Sq, q_block, Skv, kv_block)
    nq, nk = Sq // q_block, Skv // kv_block
    scale = 1.0 / math.sqrt(D)

    qr = q.reshape(B, nq, q_block, K, G, D).transpose(1, 0, 3, 4, 2, 5)
    # qr: (nq, B, K, G, Qb, D)
    kr = k.reshape(B, nk, kv_block, K, D).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, kv_block, K, D).transpose(1, 0, 3, 2, 4)
    # kr/vr: (nk, B, K, KVb, D)
    segq = (
        segment_ids_q.reshape(B, nq, q_block).transpose(1, 0, 2)
        if segment_ids_q is not None
        else None
    )
    segk = (
        segment_ids_kv.reshape(B, nk, kv_block).transpose(1, 0, 2)
        if segment_ids_kv is not None
        else None
    )

    def q_step(_, qi):
        qb, iq, sq = qi
        qpos = q_offset + iq * q_block + jnp.arange(q_block)

        def kv_step(carry, kj):
            m_run, l_run, acc = carry
            kb, vb, jk, sk = kj
            kpos = jk * kv_block + jnp.arange(kv_block)
            logits = (
                jnp.einsum(
                    "bkgqd,bkcd->bkgqc", qb.astype(jnp.float32), kb.astype(jnp.float32)
                )
                * scale
            )  # (B,K,G,Qb,KVb)
            mask = _pattern_mask(qpos, kpos, pattern, window, chunk, causal)
            if sq is not None:
                mask = mask & (sq[:, None, None, :, None] == sk[:, None, None, None, :])
                logits = jnp.where(mask, logits, -1e30)
            else:
                logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m_run, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_block), -1e30, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_block, D), jnp.float32)
        # single-block KV: no loop — avoids while-loop overhead AND nested
        # scans, which old XLA cannot partition in partial-manual regions
        if nk == 1:
            (m, l, acc), _ = kv_step(
                (m0, l0, a0),
                (kr[0], vr[0], jnp.int32(0), segk[0] if segk is not None else None),
            )
        elif segk is not None:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), (kr, vr, jnp.arange(nk), segk)
            )
        else:
            (m, l, acc), _ = jax.lax.scan(
                lambda c, x: kv_step(c, (x[0], x[1], x[2], None)),
                (m0, l0, a0),
                (kr, vr, jnp.arange(nk)),
            )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    if nq == 1:
        _, out1 = q_step(None, (qr[0], jnp.int32(0), segq[0] if segq is not None else None))
        outs = out1[None]
    elif segq is not None:
        _, outs = jax.lax.scan(q_step, None, (qr, jnp.arange(nq), segq))
    else:
        _, outs = jax.lax.scan(
            lambda c, x: q_step(c, (x[0], x[1], None)), None, (qr, jnp.arange(nq))
        )
    # outs: (nq, B, K, G, Qb, D) -> (B, Sq, H, D)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, K * G, D)


# ---------------------------------------------------------------------------
# Decode attention (single new token against a KV cache)


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, K, D)
    v_cache: jax.Array,  # (B, S, K, D)
    cache_len: jax.Array,  # (B,) valid prefix length (new token included)
    *,
    pattern: str = "full",
    window: int = 0,
    chunk: int = 0,
) -> jax.Array:
    B, S, K, D = k_cache.shape
    H = q.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, K, G, D)
    logits = (
        jnp.einsum("bkgd,bskd->bkgs", qr.astype(jnp.float32), k_cache.astype(jnp.float32))
        * scale
    )
    kpos = jnp.arange(S)[None, :]  # (1, S)
    qpos = cache_len[:, None] - 1  # (B, 1) position of the new token
    m = kpos < cache_len[:, None]
    if pattern == "swa" and window > 0:
        m &= (qpos - kpos) < window
    if pattern == "chunked" and chunk > 0:
        m &= (qpos // chunk) == (kpos // chunk)
    logits = jnp.where(m[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def cp_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    mesh,
    axis: str = "data",
    pattern: str = "full",
    window: int = 0,
    chunk: int = 0,
):
    """Context-parallel decode: KV cache sharded over `axis` along seq.

    Flash-decoding combine: each shard computes a partial (max, denom,
    weighted sum) over its local KV slice; partials merge with a psum-style
    logsumexp.  Used for long_500k where batch=1 cannot shard."""
    B, S, K, D = k_cache.shape
    H = q.shape[2]
    G = H // K
    n_shards = mesh.shape[axis]
    scale = 1.0 / math.sqrt(D)

    def body(q, kc, vc, clen):
        shard = jax.lax.axis_index(axis)
        s_local = kc.shape[1]
        qr = q.reshape(B, K, G, D)
        logits = (
            jnp.einsum("bkgd,bskd->bkgs", qr.astype(jnp.float32), kc.astype(jnp.float32))
            * scale
        )
        kpos = shard * s_local + jnp.arange(s_local)[None, :]
        qpos = clen[:, None] - 1
        m = kpos < clen[:, None]
        if pattern == "swa" and window > 0:
            m &= (qpos - kpos) < window
        if pattern == "chunked" and chunk > 0:
            m &= (qpos // chunk) == (kpos // chunk)
        logits = jnp.where(m[:, None, None, :], logits, -1e30)
        m_loc = logits.max(axis=-1)  # (B,K,G)
        p = jnp.exp(logits - m_loc[..., None])
        l_loc = p.sum(axis=-1)
        acc = jnp.einsum("bkgs,bskd->bkgd", p, vc.astype(jnp.float32))
        # combine partials across shards
        m_glob = jax.lax.pmax(m_loc, axis)
        corr = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * corr, axis)
        acc_glob = jax.lax.psum(acc * corr[..., None], axis)
        out = acc_glob / jnp.maximum(l_glob[..., None], 1e-30)
        return out.reshape(B, 1, H, D).astype(q.dtype)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None), P()),
        out_specs=P(),
        check_vma=False,
    )(q, k_cache, v_cache, cache_len)


# ---------------------------------------------------------------------------
# MLPs


def mlp_schema(cfg, kind: str) -> Schema:
    d, f = cfg.d_model, cfg.d_ff
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": ParamDef((d, f), ("fsdp", "ff")),
            "w_up": ParamDef((d, f), ("fsdp", "ff")),
            "w_down": ParamDef((f, d), ("ff", "fsdp")),
        }
    return {
        "w_in": ParamDef((d, f), ("fsdp", "ff")),
        "w_out": ParamDef((f, d), ("ff", "fsdp")),
    }


def mlp_apply(params, x: jax.Array, kind: str, rules: ShardingRules) -> jax.Array:
    dt = x.dtype
    if kind in ("swiglu", "geglu"):
        w_gate = load_weight(params["w_gate"], rules, None, "ff", dtype=dt)
        w_up = load_weight(params["w_up"], rules, None, "ff", dtype=dt)
        w_down = load_weight(params["w_down"], rules, "ff", None, dtype=dt)
        g = x @ w_gate
        u = x @ w_up
        g = rules.constrain(g, "batch", "seq", "ff")
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = act * u
        out = h @ w_down
    else:
        w_in = load_weight(params["w_in"], rules, None, "ff", dtype=dt)
        w_out = load_weight(params["w_out"], rules, "ff", None, dtype=dt)
        h = jax.nn.gelu(x @ w_in, approximate=True)
        h = rules.constrain(h, "batch", "seq", "ff")
        out = h @ w_out
    return rules.constrain(out, "batch", "seq", "embed")
