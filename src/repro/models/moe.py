"""Mixture-of-Experts FFN with GShard-style grouped capacity routing.

Groups = batch rows (so the group axis carries the batch sharding and every
rank participates); within a group, tokens are routed in sequence blocks of
`MOE_BLOCK_SEQ` with per-block expert capacity C = tb*k/E*cf.  All routing
math (cumsum positions, one-hot dispatch) is group-local: no cross-shard
dependencies, so pjit partitions the whole layer cleanly:

    dispatch  (G, tb, E, C) x (G, tb, d)  -> (G, E, C, d)     [batch-sharded]
    experts   (G, E, C, d)  x (E, d, f)   -> (G, E, C, f)     [EP/TP-sharded]
    combine   (G, tb, E, C) x (G, E, C, d)-> (G, tb, d)

Dispatch/combine overhead = 2*tb*k*cf*d flops/token — ~1% of expert compute
at tb=512.  Capacity drops are per (group, block), standard GShard dropping;
decode blocks (tb=1) never drop.  The einsum formulation renders the
token<->expert movement as XLA collectives on the expert buffers;
EXPERIMENTS.md §Perf compares it against a shard_map all-to-all dispatch.

Baseline-vs-history note: the first implementation scanned over flattened
token blocks; with batch-sharded activations the scan axis absorbed the
sharding and XLA replicated ALL routing compute per device (20x flops).
Group-blocked routing is the fix — kept as the paper-faithful baseline.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.compat import shard_map

from repro.distributed.sharding import ShardingRules
from repro.models.layers import ParamDef, Schema, load_weight

# Tokens routed per scan step, per group.  4096 makes train (seq 4k after
# microbatching) and decode single-block — critical because every scan step
# re-all-gathers the FSDP-sharded expert weights; only prefill_32k pays the
# multi-block cost (8 blocks), which §Perf attacks separately.
MOE_BLOCK_SEQ = 4096


def moe_schema(cfg) -> Schema:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    # 'ff' resolves to None when 'experts' already claims the model axis
    # (llama4, jamba: EP).  When experts replicate (grok: 8 experts < 16-way
    # axis, per-arch override), 'ff' claims model and each expert is TP'd.
    return {
        "router": ParamDef((d, e), (None, None)),
        "w_gate": ParamDef((e, d, f), ("experts", "fsdp", "ff")),
        "w_up": ParamDef((e, d, f), ("experts", "fsdp", "ff")),
        "w_down": ParamDef((e, f, d), ("experts", "ff", "fsdp")),
    }


def _route_block(
    xb: jax.Array, router: jax.Array, k: int, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xb (G, tb, d) -> (dispatch (G,tb,E,C), gates (G,tb,E), aux scalar)."""
    e = router.shape[1]
    logits = xb.astype(jnp.float32) @ router.astype(jnp.float32)  # (G,tb,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (G,tb,k)
    sel = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(axis=2)  # (G,tb,E)
    gates = sel * probs
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # capacity position within (group, block) — cumsum over the token axis
    pos = jnp.cumsum(sel, axis=1) - sel
    keep = sel * (pos < capacity)
    dispatch = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
    dispatch = dispatch * keep[..., None]  # (G,tb,E,C)
    frac_tokens = sel.mean(axis=(0, 1))
    frac_probs = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) / max(k, 1)
    return dispatch, gates, aux


def _moe_apply_a2a(params, x: jax.Array, cfg, rules: ShardingRules,
                   tb: int, nb: int, capacity: int, axis: str = "data"):
    """EP-over-data via explicit all-to-alls (shard_map, manual over 'data').

    §Perf iteration L3: with experts sharded over `data`, auto-SPMD renders
    the batch->expert reshard as a FULL all-gather of the microbatch
    activations per MoE layer (measured 1.5 TB/step/device on llama4).  The
    textbook EP exchange moves only the dispatched expert buffers:
    per-device a2a payload = |xe_local| = E*C*d/nd, ~20x smaller.  Dense
    token compute + routing stay local; expert FFNs run on all-to-all'd
    buffers; a reverse a2a returns outputs.  'model'-axis TP inside each
    expert stays on auto (partial-manual shard_map)."""
    import jax.numpy as jnp  # local alias for clarity

    mesh = rules.mesh
    b, s, d = x.shape
    k, e = cfg.top_k, cfg.n_experts
    nd = mesh.shape[axis]
    e_local = e // nd
    dt = x.dtype
    from jax.sharding import PartitionSpec as P

    def body(xb, router, w_gate, w_up, w_down):
        bl = xb.shape[0]

        def block(aux, xt):  # xt (bl, tb, d) local tokens
            dispatch, gates, aux_b = _route_block(xt, router, k, capacity)
            disp = dispatch.astype(dt)
            xe = jnp.einsum("gtec,gtd->gecd", disp, xt)  # (bl, E, C, d)
            xe = xe.reshape(bl, nd, e_local, capacity, d)
            xe = jax.lax.all_to_all(xe, axis, 1, 0, tiled=True)
            xe = xe.reshape(bl * nd, e_local, capacity, d)  # all groups, local experts
            g = jnp.einsum("gecd,edf->gecf", xe, w_gate)
            u = jnp.einsum("gecd,edf->gecf", xe, w_up)
            h = jax.nn.silu(g) * u
            ye = jnp.einsum("gecf,efd->gecd", h, w_down)
            ye = jax.lax.all_to_all(
                ye.reshape(bl * nd, 1, e_local, capacity, d), axis, 0, 1,
                tiled=True,
            )  # (bl, nd, e_local, C, d)
            ye = ye.reshape(bl, e, capacity, d)
            out = jnp.einsum("gtec,gecd->gtd", disp * gates[..., None].astype(dt), ye)
            return aux + aux_b, out

        if nb == 1:
            aux, out = block(jnp.zeros((), jnp.float32), xb)
        else:
            xs = xb.reshape(bl, nb, tb, d).transpose(1, 0, 2, 3)
            aux, outs = jax.lax.scan(block, jnp.zeros((), jnp.float32), xs)
            out = outs.transpose(1, 0, 2, 3).reshape(bl, s, d)
            aux = aux / nb
        return out, jax.lax.pmean(aux, axis)

    w3 = P(axis, None, None)
    out, aux = shard_map(
        body,
        mesh=mesh,
        axis_names={axis},
        in_specs=(P(axis, None, None), P(), w3, w3, w3),
        out_specs=(P(axis, None, None), P()),
        check_vma=False,
    )(
        x,
        params["router"].astype(jnp.float32),
        params["w_gate"].astype(dt),
        params["w_up"].astype(dt),
        params["w_down"].astype(dt),
    )
    return rules.constrain(out, "batch", "seq", "embed"), aux


def moe_apply(
    params, x: jax.Array, cfg, rules: ShardingRules
) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss)."""
    b, s, d = x.shape
    k, e = cfg.top_k, cfg.n_experts
    tb = min(MOE_BLOCK_SEQ, s)
    while s % tb:  # largest divisor of s not exceeding the target block
        tb -= 1
    nb = s // tb
    capacity = min(tb * k, max(int(tb * k / e * cfg.capacity_factor), 1))
    dt = x.dtype

    # EP placement: when 'experts' maps to a batch mesh axis (llama4: data),
    # the expert buffers reshard batch->expert (the all-to-all of EP) and the
    # expert weights never move.  Otherwise (EP over model, or replicated
    # experts) the buffers keep their batch sharding.
    exp_ax = rules.mapping.get("experts")
    batch_axes = rules.mapping.get("batch") or ()
    if not isinstance(batch_axes, tuple):
        batch_axes = (batch_axes,)
    ep_over_batch = isinstance(exp_ax, str) and exp_ax in batch_axes
    if (
        ep_over_batch
        and rules.mesh is not None
        and exp_ax in rules.mesh.axis_names
        and e % rules.mesh.shape[exp_ax] == 0
        and b % rules.mesh.shape[exp_ax] == 0
    ):
        return _moe_apply_a2a(params, x, cfg, rules, tb, nb, capacity, axis=exp_ax)
    lead = None if ep_over_batch else "batch"

    def block(aux, xb):  # xb (B, tb, d)
        dispatch, gates, aux_b = _route_block(xb, params["router"], k, capacity)
        disp = dispatch.astype(dt)
        xe = jnp.einsum("gtec,gtd->gecd", disp, xb)  # (B, E, C, d)
        xe = rules.constrain(xe, lead, "experts", None, None)
        w_gate = load_weight(params["w_gate"], rules, "experts", None, "ff", dtype=dt)
        w_up = load_weight(params["w_up"], rules, "experts", None, "ff", dtype=dt)
        w_down = load_weight(params["w_down"], rules, "experts", "ff", None, dtype=dt)
        g = jnp.einsum("gecd,edf->gecf", xe, w_gate)
        u = jnp.einsum("gecd,edf->gecf", xe, w_up)
        g = rules.constrain(g, lead, "experts", None, "ff")
        h = jax.nn.silu(g) * u
        ye = jnp.einsum("gecf,efd->gecd", h, w_down)
        ye = rules.constrain(ye, lead, "experts", None, None)
        out_b = jnp.einsum(
            "gtec,gecd->gtd", disp * gates[..., None].astype(dt), ye
        )
        return aux + aux_b, out_b

    if nb == 1:
        aux, out = block(jnp.zeros((), jnp.float32), x[:, :s, :])
        out = out.reshape(b, s, d)
    else:
        xs = x.reshape(b, nb, tb, d).transpose(1, 0, 2, 3)  # (nb, B, tb, d)
        aux, outs = jax.lax.scan(block, jnp.zeros((), jnp.float32), xs)
        out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
        aux = aux / nb
    return rules.constrain(out, "batch", "seq", "embed"), aux
