"""DLRM-style RecSys model (the paper's training stage, Table I).

Embedding tables (row-sharded over `model`), bottom MLP over dense features,
pairwise-dot feature interaction (batched GEMM), top MLP -> CTR logit.
Consumes the train-ready mini-batch produced by `repro.core.preprocess`
(dense + multi-hot SigridHashed ids + generated one-hot ids + labels).

Row-sharded embedding lookup runs in shard_map: each `model` shard gathers
ids that fall in its row range, mean-pools locally, and a single psum
combines — the standard row-wise sharding used by TorchRec/RecNMP-class
systems (one (B, T, D) all-reduce per batch).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map

from repro.data.synth import RMDataConfig
from repro.distributed.sharding import ShardingRules
from repro.models.layers import (
    ParamDef,
    Schema,
    init_from_schema,
    pspecs_from_schema,
)


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    data: RMDataConfig
    emb_dim: int = 128
    bottom_mlp: tuple = (512, 256, 128)
    top_mlp: tuple = (1024, 1024, 512, 256, 1)
    dtype: str = "float32"
    param_dtype: str = "float32"

    @property
    def n_tables(self) -> int:
        return self.data.n_tables

    @property
    def family(self) -> str:
        return "recsys"


def model_schema(cfg: RecSysConfig) -> Schema:
    nd = cfg.data.n_dense
    rows = cfg.data.embedding_rows
    s: Schema = {
        "tables": ParamDef(
            (cfg.n_tables, rows, cfg.emb_dim), (None, "vocab", None), scale=0.01
        ),
    }
    dims = (nd,) + cfg.bottom_mlp
    s["bottom"] = {
        f"w{i}": ParamDef((dims[i], dims[i + 1]), ("fsdp", None))
        for i in range(len(dims) - 1)
    }
    s["bottom_b"] = {
        f"b{i}": ParamDef((dims[i + 1],), (None,), init="zeros")
        for i in range(len(dims) - 1)
    }
    n_int = cfg.n_tables + 1
    top_in = n_int * (n_int - 1) // 2 + cfg.bottom_mlp[-1]
    tdims = (top_in,) + cfg.top_mlp
    s["top"] = {
        f"w{i}": ParamDef((tdims[i], tdims[i + 1]), ("fsdp", None))
        for i in range(len(tdims) - 1)
    }
    s["top_b"] = {
        f"b{i}": ParamDef((tdims[i + 1],), (None,), init="zeros")
        for i in range(len(tdims) - 1)
    }
    return s


def init_params(rng, cfg: RecSysConfig):
    return init_from_schema(rng, model_schema(cfg), jnp.dtype(cfg.param_dtype))


def param_pspecs(cfg: RecSysConfig, rules: ShardingRules):
    return pspecs_from_schema(model_schema(cfg), rules)


# ---------------------------------------------------------------------------
# Row-sharded embedding bag


def _local_bag(tables, ids, mask):
    """tables (T, R_local, D); ids (B, T, L) LOCAL row ids (may be invalid);
    mask (B, T, L) validity. Returns sum-pooled (B, T, D) + counts (B, T)."""
    r_local = tables.shape[1]
    valid = mask & (ids >= 0) & (ids < r_local)
    safe = jnp.clip(ids, 0, r_local - 1)

    def per_table(tab, idx, val):
        e = tab[idx]  # (B, L, D)
        return (e * val[..., None].astype(e.dtype)).sum(axis=1), val.sum(axis=1)

    pooled, counts = jax.vmap(per_table, in_axes=(0, 1, 1), out_axes=(1, 1))(
        tables, safe, valid
    )
    return pooled, counts  # (B, T, D), (B, T)


def embedding_bag(
    params_tables: jax.Array,  # (T, R, D) possibly row-sharded over model
    multi_ids: jax.Array,  # (B, S_tables, L)
    lengths: jax.Array,  # (B, S_tables)
    one_ids: jax.Array,  # (B, G_tables)
    cfg: RecSysConfig,
    rules: ShardingRules,
) -> jax.Array:
    """Mean-pooled embeddings for all tables -> (B, T, D)."""
    s_t = cfg.data.n_sparse
    L = cfg.data.max_sparse_len
    mask = jnp.arange(L)[None, None, :] < lengths[..., None]
    mesh = rules.mesh

    def bag(tables, mids, msk, oids):
        if mesh is not None and "model" in mesh.axis_names:
            shard = jax.lax.axis_index("model")
            r_local = tables.shape[1]
            offset = shard * r_local
        else:
            offset = 0
        pooled_m, cnt_m = _local_bag(tables[:s_t], mids - offset, msk)
        pooled_o, cnt_o = _local_bag(
            tables[s_t:], (oids - offset)[..., None], jnp.ones_like(oids[..., None], bool)
        )
        pooled = jnp.concatenate([pooled_m, pooled_o], axis=1)
        cnt = jnp.concatenate([cnt_m, cnt_o], axis=1)
        if mesh is not None and "model" in mesh.axis_names:
            pooled = jax.lax.psum(pooled, "model")
            cnt = jax.lax.psum(cnt, "model")
        return pooled / jnp.maximum(cnt[..., None], 1.0).astype(pooled.dtype)

    if mesh is None:
        return bag(params_tables, multi_ids, mask, one_ids)
    batch_axes = rules.mapping.get("batch")
    return shard_map(
        bag,
        mesh=mesh,
        in_specs=(
            P(None, rules.mapping.get("vocab"), None),
            P(batch_axes, None, None),
            P(batch_axes, None, None),
            P(batch_axes, None),
        ),
        out_specs=P(batch_axes, None, None),
        check_vma=False,
    )(params_tables, multi_ids, mask, one_ids)


def _mlp(ws, bs, x, n):
    for i in range(n):
        x = x @ ws[f"w{i}"] + bs[f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def forward(params, minibatch: Dict[str, jax.Array], cfg: RecSysConfig,
            rules: ShardingRules) -> jax.Array:
    """Mini-batch -> CTR logits (B,)."""
    dense = rules.constrain(minibatch["dense"], "batch", None)
    bot = _mlp(params["bottom"], params["bottom_b"], dense, len(cfg.bottom_mlp))
    emb = embedding_bag(
        params["tables"],
        minibatch["multi_hot_ids"],
        minibatch["lengths"],
        minibatch["one_hot_ids"],
        cfg,
        rules,
    )  # (B, T, D)
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, T+1, D)
    inter = jnp.einsum("bnd,bmd->bnm", z, z)  # batched GEMM interaction
    n_int = cfg.n_tables + 1
    iu = jnp.triu_indices(n_int, k=1)
    flat = inter[:, iu[0], iu[1]]  # (B, n_int*(n_int-1)/2)
    top_in = jnp.concatenate([bot, flat], axis=1)
    logit = _mlp(params["top"], params["top_b"], top_in, len(cfg.top_mlp))
    return logit[:, 0]


def loss_fn(params, minibatch, cfg: RecSysConfig, rules: ShardingRules
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = forward(params, minibatch, cfg, rules)
    labels = minibatch["labels"]
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    acc = jnp.mean((logits > 0) == (labels > 0.5))
    return loss, {"loss": loss, "accuracy": acc}
