"""Encoder-decoder transformer (seamless-m4t backbone).

The audio/text modality frontend is a STUB per the assignment: the encoder
consumes precomputed frame embeddings (B, S_enc, d) from `input_specs()`.
Decoder = causal self-attention + cross-attention to the encoder output.
Serving caches: decoder self-attn KV + precomputed cross-attn K/V.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules
from repro.models.config import ModelConfig
from repro.models.layers import (
    ParamDef,
    Schema,
    apply_rope,
    blockwise_attention,
    decode_attention,
    init_from_schema,
    load_weight,
    mlp_apply,
    mlp_schema,
    pspecs_from_schema,
    rmsnorm,
    stack_schema,
)
from repro.models.transformer import attn_schema, chunked_xent


def _xattn_schema(cfg: ModelConfig) -> Schema:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": ParamDef((d, h * hd), ("fsdp", "heads")),
        "wk": ParamDef((d, k * hd), ("fsdp", "kv_heads")),
        "wv": ParamDef((d, k * hd), ("fsdp", "kv_heads")),
        "wo": ParamDef((h * hd, d), ("heads", "fsdp")),
    }


def enc_layer_schema(cfg: ModelConfig) -> Schema:
    d = cfg.d_model
    return {
        "ln1": ParamDef((d,), (None,), init="zeros"),
        "attn": attn_schema(cfg),
        "ln2": ParamDef((d,), (None,), init="zeros"),
        "mlp": mlp_schema(cfg, cfg.mlp_kind),
    }


def dec_layer_schema(cfg: ModelConfig) -> Schema:
    d = cfg.d_model
    return {
        "ln1": ParamDef((d,), (None,), init="zeros"),
        "attn": attn_schema(cfg),
        "lnx": ParamDef((d,), (None,), init="zeros"),
        "xattn": _xattn_schema(cfg),
        "ln2": ParamDef((d,), (None,), init="zeros"),
        "mlp": mlp_schema(cfg, cfg.mlp_kind),
    }


def model_schema(cfg: ModelConfig) -> Schema:
    d, v = cfg.d_model, cfg.padded_vocab
    return {
        "embed": ParamDef((v, d), ("vocab", None), scale=1.0),
        "enc_layers": stack_schema(enc_layer_schema(cfg), cfg.enc_layers),
        "enc_ln": ParamDef((d,), (None,), init="zeros"),
        "dec_layers": stack_schema(dec_layer_schema(cfg), cfg.n_layers),
        "final_ln": ParamDef((d,), (None,), init="zeros"),
        "head": ParamDef((d, v), ("fsdp", "vocab")),
    }


def init_params(rng, cfg: ModelConfig):
    return init_from_schema(rng, model_schema(cfg), jnp.dtype(cfg.param_dtype))


def param_pspecs(cfg: ModelConfig, rules: ShardingRules):
    return pspecs_from_schema(model_schema(cfg), rules)


def _mha(p, xq, xkv, positions_q, positions_kv, cfg, rules, causal):
    b, sq, _ = xq.shape
    skv = xkv.shape[1]
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = xq.dtype
    wq = load_weight(p["wq"], rules, None, "heads", dtype=dt)
    wk = load_weight(p["wk"], rules, None, "kv_heads", dtype=dt)
    wv = load_weight(p["wv"], rules, None, "kv_heads", dtype=dt)
    kv_ax = "kv_heads" if cfg.n_kv_heads % max(rules.axis_size("kv_heads"), 1) == 0 else None
    q = rules.constrain(xq @ wq, "batch", "seq", "heads").reshape(b, sq, h, hd)
    kk = rules.constrain(xkv @ wk, "batch", "seq", kv_ax).reshape(b, skv, k, hd)
    vv = rules.constrain(xkv @ wv, "batch", "seq", kv_ax).reshape(b, skv, k, hd)
    if positions_q is not None:
        q = apply_rope(q, positions_q, cfg.rope_theta)
        kk = apply_rope(kk, positions_kv, cfg.rope_theta)
    out = blockwise_attention(q, kk, vv, causal=causal)
    wo = load_weight(p["wo"], rules, "heads", None, dtype=dt)
    return out.reshape(b, sq, h * hd) @ wo


def encode(params, frames: jax.Array, cfg: ModelConfig, rules: ShardingRules):
    """frames (B, S_enc, d) stub embeddings -> encoder hidden states."""
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = rules.constrain(x, "batch", "seq", "embed")

    def body(h, lp):
        a = _mha(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps),
                 rmsnorm(h, lp["ln1"], cfg.norm_eps), pos, pos, cfg, rules, False)
        h = h + a
        h = h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps),
                          cfg.mlp_kind, rules)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat != "none" else body
    h, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return rmsnorm(h, params["enc_ln"], cfg.norm_eps)


def loss_fn(params, batch, cfg: ModelConfig, rules: ShardingRules):
    """batch: frames (B,S_enc,d), tokens (B,S_dec), labels, mask."""
    enc_out = encode(params, batch["frames"], cfg, rules)
    tokens = batch["tokens"]
    b, s = tokens.shape
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    pos_enc = jnp.broadcast_to(jnp.arange(enc_out.shape[1]), (b, enc_out.shape[1]))

    def body(h, lp):
        h = h + _mha(lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps),
                     rmsnorm(h, lp["ln1"], cfg.norm_eps), pos, pos, cfg, rules, True)
        h = h + _mha(lp["xattn"], rmsnorm(h, lp["lnx"], cfg.norm_eps),
                     enc_out, None, None, cfg, rules, False)
        h = h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps),
                          cfg.mlp_kind, rules)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat != "none" else body
    h, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    xent = chunked_xent(params, h, batch["labels"], batch["mask"], cfg, rules)
    return xent, {"loss": xent, "xent": xent}


# -- serving -----------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    k, hd = cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    nl = cfg.n_layers
    return {
        "self_k": jax.ShapeDtypeStruct((nl, batch, max_seq, k, hd), dt),
        "self_v": jax.ShapeDtypeStruct((nl, batch, max_seq, k, hd), dt),
        "cross_k": jax.ShapeDtypeStruct((nl, batch, max_seq, k, hd), dt),
        "cross_v": jax.ShapeDtypeStruct((nl, batch, max_seq, k, hd), dt),
    }


def cache_pspecs(cfg: ModelConfig, rules: ShardingRules) -> Dict[str, Any]:
    model_n = rules.mesh.shape.get("model", 1) if rules.mesh else 1
    kv_ax = "kv_heads" if cfg.n_kv_heads % max(model_n, 1) == 0 else None
    p = rules.pspec("layers", "batch", "kv_seq", kv_ax, None)
    return {"self_k": p, "self_v": p, "cross_k": p, "cross_v": p}


def decode_step(params, token, caches, cache_len, cfg: ModelConfig,
                rules: ShardingRules, *, mesh=None, shard_kv_seq=False):
    """One decoder token against self- and cross-attn caches."""
    b = token.shape[0]
    h_, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(dt)
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    enc_len = caches["cross_k"].shape[2]

    def body(h, xs):
        lp, ck, cv, sk, sv = xs
        xn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q = apply_rope((xn @ lp["attn"]["wq"].astype(dt)).reshape(b, 1, h_, hd),
                       pos, cfg.rope_theta)
        kt = apply_rope((xn @ lp["attn"]["wk"].astype(dt)).reshape(b, 1, k, hd),
                        pos, cfg.rope_theta)
        vt = (xn @ lp["attn"]["wv"].astype(dt)).reshape(b, 1, k, hd)
        sk = jax.lax.dynamic_update_slice(sk, kt, (0, cache_len, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, vt, (0, cache_len, 0, 0))
        valid = jnp.full((b,), cache_len + 1, jnp.int32)
        a = decode_attention(q, sk, sv, valid)
        h = h + a.reshape(b, 1, h_ * hd) @ lp["attn"]["wo"].astype(dt)
        # cross attention against the precomputed encoder K/V
        xq = rmsnorm(h, lp["lnx"], cfg.norm_eps)
        qx = (xq @ lp["xattn"]["wq"].astype(dt)).reshape(b, 1, h_, hd)
        ax = decode_attention(qx, ck, cv, jnp.full((b,), enc_len, jnp.int32))
        h = h + ax.reshape(b, 1, h_ * hd) @ lp["xattn"]["wo"].astype(dt)
        h = h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps),
                          cfg.mlp_kind, rules)
        return h, (sk, sv)

    h, (new_sk, new_sv) = jax.lax.scan(
        body,
        x,
        (params["dec_layers"], caches["cross_k"], caches["cross_v"],
         caches["self_k"], caches["self_v"]),
    )
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    logits = h @ load_weight(params["head"], rules, None, "vocab", dtype=dt)
    if cfg.padded_vocab != cfg.vocab_size:
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    logits = rules.constrain(logits, "batch", "seq", "vocab")
    new_caches = dict(caches, self_k=new_sk, self_v=new_sv)
    return logits, new_caches
