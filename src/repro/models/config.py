"""Universal model configuration covering all assigned architecture families.

A model is a stack of `n_layers` decoder (or encoder) layers following a
repeating *period pattern*: e.g. gemma3's 5 local + 1 global attention, or
jamba's 7 mamba + 1 attention with MoE on odd layers.  Periods make
heterogeneous stacks scannable: parameters are stacked over periods and the
pattern is unrolled inside the scan body, keeping the compiled HLO small for
80-layer models.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position inside a period."""

    kind: str = "attn"  # 'attn' | 'mamba'
    attn_pattern: str = "full"  # 'full' | 'swa' | 'chunked'
    mlp_kind: str = "swiglu"  # 'swiglu' | 'geglu' | 'gelu' | 'moe'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | audio | recsys
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention pattern knobs
    attention: str = "full"  # full | swa | local_global | chunked
    window: int = 0  # swa / local window size
    local_global_period: int = 0  # gemma3: 5 local + 1 global -> 6
    chunk_size: int = 0  # llama4 chunked attention
    rope_theta: float = 10_000.0

    # MLP / MoE
    mlp_kind: str = "swiglu"
    n_experts: int = 0
    top_k: int = 0
    moe_period: int = 1  # MoE layer every `moe_period` layers
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    attn_period: int = 0  # jamba: one attn layer per `attn_period` layers

    # encoder-decoder
    enc_layers: int = 0

    # modality frontend stub
    frontend: Optional[str] = None  # 'vision' | 'audio'
    frontend_positions: int = 0  # patch/frame embeddings per sample

    # numerics / memory
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"  # 'full' | 'dots' | 'none'
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    optimizer: str = "adamw"  # 'adamw' | 'adafactor'
    # per-arch sharding-rule overrides (e.g. grok-1 has 8 experts < 16-way
    # model axis, so experts replicate and the expert FFN is TP over 'ff')
    sharding_overrides: tuple = ()  # of (logical_axis, mesh_axis|None) pairs
    # gradient-accumulation microbatches for training (0 = auto: sized so one
    # microbatch's activations fit HBM — per-device microbatch <= ~8k tokens)
    microbatches: int = 0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head rows padded to a 256 multiple: TPU lane alignment
        AND divisibility for the 16-way vocab sharding.  Logits beyond
        vocab_size are masked to -inf in the head."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    # -- layer period pattern -------------------------------------------------
    def period(self) -> tuple[LayerSpec, ...]:
        """The repeating layer pattern; len divides n_layers."""
        if self.family == "ssm":
            return (LayerSpec(kind="mamba"),)

        if self.family == "hybrid":
            # jamba: 1 attn per attn_period layers, MoE every moe_period
            p = self.attn_period or 8
            specs = []
            for i in range(p):
                kind = "attn" if i == p // 2 else "mamba"
                mlp = "moe" if (self.n_experts and i % self.moe_period == 1) else self.mlp_kind
                specs.append(LayerSpec(kind=kind, mlp_kind=mlp))
            return tuple(specs)

        # attention-pattern period
        if self.attention == "local_global" and self.local_global_period > 1:
            pat = ["swa"] * (self.local_global_period - 1) + ["full"]
        elif self.attention == "swa":
            pat = ["swa"]
        elif self.attention == "chunked":
            # iRoPE-style: 3 chunked + 1 full per period of 4
            pat = ["chunked", "chunked", "chunked", "full"]
        else:
            pat = ["full"]

        # MoE period
        if self.n_experts and self.moe_period > 1:
            mlps = ["moe" if i % self.moe_period == self.moe_period - 1 else self.mlp_kind
                    for i in range(self.moe_period)]
        elif self.n_experts:
            mlps = ["moe"]
        else:
            mlps = [self.mlp_kind]

        import math

        plen = math.lcm(len(pat), len(mlps))
        specs = tuple(
            LayerSpec(kind="attn", attn_pattern=pat[i % len(pat)], mlp_kind=mlps[i % len(mlps)])
            for i in range(plen)
        )
        return specs

    @property
    def n_periods(self) -> int:
        plen = len(self.period())
        assert self.n_layers % plen == 0, (self.name, self.n_layers, plen)
        return self.n_layers // plen


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'
    shard_kv_seq: bool = False  # context-parallel KV for tiny-batch decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode", shard_kv_seq=True),
}
