"""Mamba-2 SSD (state-space duality) blocks — chunked, MXU-friendly.

The SSD algorithm computes the selective-SSM recurrence as chunked matmuls:
within a chunk of Q timesteps everything is dense (C B^T ⊙ decay) X — MXU
work; across chunks a tiny lax.scan carries the (H, P, N) state.  This is
the TPU-native rendering of mamba2 (arXiv:2405.21060): quadratic-in-Q local
blocks + linear global recurrence, no per-step gathers.

Tensor-parallel layout: the z/x projection (per-head channels) is sharded
over `model`; B/C/dt projections are small and replicated; heads follow the
channel sharding implicitly.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules
from repro.models.layers import ParamDef, Schema, load_weight, rmsnorm


def ssm_dims(cfg):
    d_in = 2 * cfg.d_model
    p = cfg.ssm_head_dim
    h = d_in // p
    n = cfg.ssm_state
    return d_in, h, p, n


def mamba_schema(cfg) -> Schema:
    d = cfg.d_model
    d_in, h, p, n = ssm_dims(cfg)
    w = cfg.conv_width
    return {
        "zx_proj": ParamDef((d, 2 * d_in), ("fsdp", "ff")),
        "bcdt_proj": ParamDef((d, 2 * n + h), ("fsdp", None)),
        "conv_x": ParamDef((w, d_in), (None, "ff"), scale=0.5),
        "conv_bc": ParamDef((w, 2 * n), (None, None), scale=0.5),
        "A_log": ParamDef((h,), (None,), init="zeros"),
        "D": ParamDef((h,), (None,), init="zeros"),
        "dt_bias": ParamDef((h,), (None,), init="zeros"),
        "norm_w": ParamDef((d_in,), ("ff",), init="zeros"),
        "out_proj": ParamDef((d_in, d), ("ff", "fsdp")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out


def _ssd_chunked(
    xh: jax.Array,  # (B, S, H, P)
    bmat: jax.Array,  # (B, S, N)
    cmat: jax.Array,  # (B, S, N)
    dt: jax.Array,  # (B, S, H)  (softplus'd)
    a: jax.Array,  # (H,) negative decay rates
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    while s % q:  # largest divisor of s not exceeding the chunk target
        q -= 1
    nc = s // q

    xc = xh.reshape(b, nc, q, h, p)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)
    dtc = dt.reshape(b, nc, q, h)

    cdt = xh.dtype  # compute dtype for the MXU-heavy quadratic terms (bf16
    # in production configs; f32 in unit tests).  Decay accumulations stay f32.

    # log-decay within chunk: l[t] = sum_{u<=t} a*dt_u   (B,nc,Q,H)
    ldec = jnp.cumsum(dtc * a[None, None, None, :], axis=2)
    ltot = ldec[:, :, -1, :]  # (B,nc,H) total chunk decay

    # intra-chunk (dual/attention form): Y_in[t] = sum_{u<=t} C_t.B_u e^{l_t-l_u} dt_u x_u
    cb = jnp.einsum("bcqn,bcun->bcqu", cc.astype(cdt), bc.astype(cdt))  # (B,nc,Q,Q)
    rel = ldec[:, :, :, None, :] - ldec[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    att = (cb[..., None] * decay * dtc[:, :, None, :, :]).astype(cdt)  # (B,nc,Q,Q,H)
    y_in = jnp.einsum("bcquh,bcuhp->bcqhp", att, xc.astype(cdt)).astype(jnp.float32)

    # chunk boundary states: S_c = sum_u B_u (dt_u x_u) e^{ltot - l_u}
    wgt = (jnp.exp(ltot[:, :, None, :] - ldec) * dtc).astype(cdt)  # (B,nc,Q,H)
    s_c = jnp.einsum(
        "bcun,bcuh,bcuhp->bchpn", bc.astype(cdt), wgt, xc.astype(cdt)
    ).astype(jnp.float32)  # (B,nc,H,P,N)

    # recurrence over chunks
    def step(hprev, inputs):
        s_chunk, lt = inputs  # (B,H,P,N), (B,H)
        hstate = hprev * jnp.exp(lt)[:, :, None, None] + s_chunk
        return hstate, hprev

    init = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    hT, hprevs = jax.lax.scan(
        step,
        init,
        (s_c.transpose(1, 0, 2, 3, 4), ltot.transpose(1, 0, 2)),
    )
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N) state entering chunk

    # inter-chunk contribution: Y_out[t] = C_t . h_in e^{l_t}
    y_out = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp",
        cc.astype(cdt),
        jnp.exp(ldec).astype(cdt),
        hprevs.astype(cdt),
    ).astype(jnp.float32)
    y = (y_in + y_out).reshape(b, s, h, p)
    return y, hT


def mamba_apply(
    params,
    x: jax.Array,  # (B, S, d)
    cfg,
    rules: ShardingRules,
    *,
    initial_state: jax.Array | None = None,
    return_state: bool = False,
):
    """Full-sequence mamba2 block (train / prefill)."""
    b, s, d = x.shape
    d_in, h, p, n = ssm_dims(cfg)
    dt_ = x.dtype

    zx = x @ load_weight(params["zx_proj"], rules, None, "ff", dtype=dt_)
    zx = rules.constrain(zx, "batch", "seq", "ff")
    z, xin = zx[..., :d_in], zx[..., d_in:]
    bcdt = x @ load_weight(params["bcdt_proj"], rules, None, None, dtype=dt_)
    bmat, cmat, dtr = (
        bcdt[..., :n],
        bcdt[..., n : 2 * n],
        bcdt[..., 2 * n :],
    )

    xin = jax.nn.silu(_causal_conv(xin, params["conv_x"].astype(dt_)))
    bc = jax.nn.silu(
        _causal_conv(jnp.concatenate([bmat, cmat], -1), params["conv_bc"].astype(dt_))
    )
    bmat, cmat = bc[..., :n], bc[..., n:]

    dt_act = jax.nn.softplus(
        dtr.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,S,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,)

    xh = xin.reshape(b, s, h, p)  # compute dtype (bf16 in production)
    y, hT = _ssd_chunked(
        xh,
        bmat,
        cmat,
        dt_act,
        a,
        cfg.ssm_chunk,
        h0=initial_state,
    )
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(
        jnp.float32
    )
    y = y.reshape(b, s, d_in).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_w"], cfg.norm_eps)
    out = y @ load_weight(params["out_proj"], rules, "ff", None, dtype=dt_)
    out = rules.constrain(out, "batch", "seq", "embed")
    if return_state:
        return out, hT
    return out


def mamba_decode_step(
    params,
    x_t: jax.Array,  # (B, 1, d)
    cfg,
    rules: ShardingRules,
    state: dict,  # {"h": (B,H,P,N), "conv": (B, W-1, d_in + 2N)}
):
    """Single-token recurrent update. Returns (out (B,1,d), new_state)."""
    b, _, d = x_t.shape
    d_in, h, p, n = ssm_dims(cfg)
    w = cfg.conv_width
    dt_ = x_t.dtype
    xt = x_t[:, 0, :]

    zx = xt @ params["zx_proj"].astype(dt_)
    z, xin = zx[..., :d_in], zx[..., d_in:]
    bcdt = xt @ params["bcdt_proj"].astype(dt_)
    bmat, cmat, dtr = bcdt[..., :n], bcdt[..., n : 2 * n], bcdt[..., 2 * n :]

    # conv state: (B, W-1, d_in + 2N) rolling window of pre-conv activations
    cur = jnp.concatenate([xin, bmat, cmat], -1)  # (B, d_in+2N)
    window = jnp.concatenate([state["conv"], cur[:, None, :]], axis=1)  # (B,W,ch)
    conv_w = jnp.concatenate(
        [params["conv_x"], params["conv_bc"]], axis=1
    ).astype(dt_)  # (W, ch)
    convd = jnp.einsum("bwc,wc->bc", window, conv_w)
    convd = jax.nn.silu(convd)
    xin_c, bc_c = convd[..., :d_in], convd[..., d_in:]
    bmat_c, cmat_c = bc_c[..., :n], bc_c[..., n:]

    dt_act = jax.nn.softplus(
        dtr.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt_act * a[None, :])  # (B,H)

    xh = xin_c.reshape(b, h, p).astype(jnp.float32)
    dbx = jnp.einsum(
        "bh,bn,bhp->bhpn", dt_act, bmat_c.astype(jnp.float32), xh
    )
    h_new = state["h"] * decay[:, :, None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", h_new, cmat_c.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, d_in).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_w"], cfg.norm_eps)
    out = (y @ params["out_proj"].astype(dt_))[:, None, :]
    return out, {"h": h_new, "conv": window[:, 1:, :]}
