from repro.models.config import SHAPES, LayerSpec, ModelConfig, ShapeConfig

__all__ = ["SHAPES", "LayerSpec", "ModelConfig", "ShapeConfig"]
