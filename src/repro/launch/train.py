"""Training driver: end-to-end RecSys (PreSto-fed) or LM training.

RecSys mode runs the paper's full Fig. 1 pipeline: the PartitionedStore
serves encoded columnar partitions, the PreStoEngine transforms them (fused
ISP kernels, presto or disagg placement), and the DLRM trains on the
resulting mini-batches — with checkpointing and elastic restart.

LM mode trains any --arch on synthetic token shards.

Examples (CPU-sized):
  PYTHONPATH=src python -m repro.launch.train --mode recsys --rm rm1 \
      --reduced --steps 50 --rows 512
  PYTHONPATH=src python -m repro.launch.train --mode lm \
      --arch mamba2-1.3b --reduced --steps 20 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
import time


def train_recsys(args) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_recsys
    from repro.core.pipeline import TrainingPipeline
    from repro.core.presto import PreStoEngine
    from repro.core.service import JobSpec, PreprocessingService
    from repro.core.spec import TransformSpec
    from repro.data.storage import PartitionedStore
    from repro.data.synth import SyntheticRecSysSource
    from repro.distributed.sharding import ShardingRules
    from repro.models import recsys as RS
    from repro.train import CheckpointManager, adamw, make_train_step, warmup_cosine

    rcfg = get_recsys(args.rm, reduced=args.reduced)
    src = SyntheticRecSysSource(rcfg.data, rows=args.rows or None)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(args.partitions, num_devices=8, source=src,
                             root=args.store_root)
    rules = ShardingRules.make(None)
    engine = PreStoEngine(spec, mesh=None, placement=args.placement)

    opt = adamw(warmup_cosine(args.lr, 20, max(args.steps, 100)))
    loss_fn = lambda p, b: RS.loss_fn(p, b, rcfg, rules)
    step = jax.jit(make_train_step(loss_fn, opt))

    params = RS.init_params(jax.random.PRNGKey(args.seed), rcfg)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    pipeline = TrainingPipeline(train_step=step)
    t0 = time.time()
    with PreprocessingService(num_workers=args.workers) as service:
        session = service.submit(JobSpec(
            name=f"{rcfg.name}-{args.placement}", engine=engine, store=store,
            partitions=range(args.partitions), units=args.workers))
        state, stats, metrics = pipeline.run_session(
            state, session, max_steps=args.steps
        )
    wall = time.time() - t0
    if ckpt:
        ckpt.save(int(state["step"]), state)
        ckpt.wait()
    first, last = metrics[0]["loss"], metrics[-1]["loss"]
    print(f"recsys {rcfg.name} [{args.placement}]: {stats.steps} steps in "
          f"{wall:.1f}s, loss {first:.4f} -> {last:.4f}, "
          f"consumer-util {stats.utilization:.2f}, reissues {stats.reissues}")
    return {"first_loss": first, "last_loss": last, "steps": stats.steps}


def train_lm(args) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_arch
    from repro.data.tokens import TokenSynthesizer
    from repro.distributed.sharding import ShardingRules
    from repro.launch.specs import make_optimizer_for, _model_module
    from repro.train import make_train_step

    entry = get_arch(args.arch)
    cfg = entry.reduced if args.reduced else entry.config
    mod = _model_module(cfg)
    rules = ShardingRules.make(None)
    opt = make_optimizer_for(cfg)
    loss_fn = lambda p, b: mod.loss_fn(p, b, cfg, rules)
    step = jax.jit(make_train_step(loss_fn, opt))

    params = mod.init_params(jax.random.PRNGKey(args.seed), cfg)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    synth = TokenSynthesizer(cfg.vocab_size, args.seq, seed=args.seed)
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        raw = synth.shard_batch(0, i, args.batch)
        batch = {
            "tokens": jnp.asarray(raw["tokens"]),
            "labels": jnp.asarray(raw["labels"]),
            "mask": jnp.asarray(raw["mask"], jnp.float32),
        }
        if cfg.is_encdec:
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, args.seq, cfg.d_model)
            ).astype(cfg.dtype)
        if cfg.family == "vlm" and cfg.frontend_positions:
            batch["prefix_embeds"] = jax.random.normal(
                jax.random.PRNGKey(i),
                (args.batch, cfg.frontend_positions, cfg.d_model),
            ).astype(cfg.dtype)
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    wall = time.time() - t0
    print(f"lm {cfg.name}: {args.steps} steps in {wall:.1f}s, "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"first_loss": losses[0], "last_loss": losses[-1]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["recsys", "lm"], default="recsys")
    ap.add_argument("--rm", default="rm1")
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--placement", choices=["presto", "disagg", "hybrid"],
                    default="presto")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--partitions", type=int, default=64)
    ap.add_argument("--rows", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--store-root", default=None)
    args = ap.parse_args()
    if args.mode == "recsys":
        train_recsys(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
