import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first backend init).  For each cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the cell's LoweringSpec (function + ShapeDtypeStruct inputs +
     explicit shardings) — zero device allocation,
  3. jit(...).lower(...).compile(),
  4. records memory_analysis() (fits-in-HBM proof), cost_analysis()
     (FLOPs/bytes), and the HLO-parsed collective bytes for §Roofline.

Results append to a JSON-lines file consumed by EXPERIMENTS.md and
benchmarks/.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.jsonl
"""

import argparse
import json
import time
import traceback


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_path: str,
             reduced: bool = False) -> dict:
    import jax

    from repro.configs.registry import get_arch
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.models.config import SHAPES

    entry = get_arch(arch_id)
    cfg = entry.reduced if reduced else entry.config
    mesh_name = "multi" if multi_pod else "single"
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "status": "skip", "reason": "",
    }
    if shape_name in entry.skip_shapes:
        rec["reason"] = "inapplicable (see DESIGN.md SArch-applicability)"
        _emit(rec, out_path)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        spec = build_cell(cfg, shape_name, mesh)
        with mesh:
            jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings)
            lowered = jitted.lower(*spec.args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        terms = rl.derive(cost, hlo, cfg, SHAPES[shape_name], chips)
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            chips=chips,
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes,
            },
            roofline=terms.to_json(),
        )
        print(
            f"[ok] {arch_id} x {shape_name} x {mesh_name}: "
            f"compile {rec['compile_s']}s, "
            f"temp/dev {mem.temp_size_in_bytes/2**30:.2f} GiB, "
            f"args/dev {mem.argument_size_in_bytes/2**30:.2f} GiB, "
            f"dominant={terms.dominant}, "
            f"terms(c/m/x)=({terms.compute_s*1e3:.2f}/"
            f"{terms.memory_s*1e3:.2f}/{terms.collective_s*1e3:.2f})ms, "
            f"rf={terms.roofline_fraction:.3f}",
            flush=True,
        )
    except Exception as e:  # noqa: BLE001 — record, continue sweep
        rec.update(status="error", reason=f"{type(e).__name__}: {e}",
                   compile_s=round(time.time() - t0, 1))
        print(f"[ERR] {arch_id} x {shape_name} x {mesh_name}: {rec['reason']}",
              flush=True)
        traceback.print_exc()
    _emit(rec, out_path)
    return rec


def _emit(rec: dict, out_path: str) -> None:
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")


def main() -> None:
    from repro.configs.registry import ARCH_IDS
    from repro.models.config import SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--reduced", action="store_true", help="smoke-size configs")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already ok in --out")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r["status"] in ("ok", "skip"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    n_ok = n_err = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                key = (arch, shape, "multi" if multi else "single")
                if key in done:
                    continue
                rec = run_cell(arch, shape, multi, args.out, reduced=args.reduced)
                n_ok += rec["status"] in ("ok", "skip")
                n_err += rec["status"] == "error"
    print(f"dryrun complete: {n_ok} ok/skip, {n_err} errors", flush=True)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
