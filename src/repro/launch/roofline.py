"""Roofline-term derivation from compiled dry-run artifacts.

Terms per (arch x shape x mesh), exactly as specified:

    compute_s    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory_s     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective_s = collective_bytes / (chips x 50e9 B/s per ICI link)

`compiled.cost_analysis()` reports the PER-DEVICE SPMD program, so dividing
by chips is implicit: compute_s = flops_per_dev / peak, etc.  Collective
bytes are not in cost_analysis; we parse the compiled HLO text and sum the
RESULT-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (all-reduce counted once — ring cost is
~2x payload but payload is what the spec formula asks for; the factor is
constant across candidates so optimization deltas are unaffected).

MODEL_FLOPS uses 6·N·D (train) / 2·N·D (inference) with N_active for MoE;
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip (v5e)
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]{1,9})\[([0-9,]*)\]")


def _shape_bytes(tok_type: str, dims: str) -> int:
    if tok_type not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[tok_type]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from compiled HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        rhs = rhs.strip()
        # op name is the token right before the first '('
        par = rhs.find("(")
        if par < 0:
            continue
        # rhs looks like: "f32[128,64]{1,0} all-gather(f32[16,64]{1,0} %p), ..."
        head = rhs[:par]
        kind = None
        for k in _COLLECTIVES:
            # match op name, including -start/-done variants; count -start only
            if re.search(rf"(?:^|\s){k}(?:-start)?$", head.rstrip()):
                if head.rstrip().endswith("-done"):
                    break
                kind = k
                break
        if kind is None:
            continue
        shapes = _SHAPE_RE.findall(head)
        out[kind] += sum(_shape_bytes(t, d) for t, d in shapes)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: int
    coll_breakdown: Dict[str, int]
    model_flops_global: float
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def dominant(self) -> str:
        vals = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — remat/redundancy waste."""
        hlo_global = self.flops_per_dev * self.chips
        return self.model_flops_global / max(hlo_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """model-compute-time / step-time lower bound — the MFU-style score."""
        model_s = self.model_flops_global / (self.chips * PEAK_FLOPS)
        return model_s / max(self.bound_s, 1e-30)

    def to_json(self) -> dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "hbm_bytes_per_dev": self.hbm_bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "coll_breakdown": self.coll_breakdown,
            "model_flops_global": self.model_flops_global,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


# ---------------------------------------------------------------------------
# MODEL_FLOPS


def param_counts(cfg) -> tuple:
    """(total, active) parameter counts from the model schema."""
    from repro.models.layers import ParamDef
    mod = None
    if getattr(cfg, "is_encdec", False):
        from repro.models import encdec as mod
    else:
        from repro.models import transformer as mod
    schema = mod.model_schema(cfg)
    total = active = 0

    def walk(node):
        nonlocal total, active
        if isinstance(node, ParamDef):
            n = int(np.prod(node.shape))
            total += n
            # expert tensors: (..., E, d, f) stacked under 'layers' may have
            # leading layer axis; detect by the 'experts' logical axis.
            if "experts" in node.axes and cfg.n_experts:
                active += n * cfg.top_k / cfg.n_experts
            else:
                active += n
            return
        for v in node.values():
            walk(v)

    walk(schema)
    return int(total), int(active)


def model_flops(cfg, shape, kind: Optional[str] = None) -> float:
    """6·N_active·D (train) or 2·N_active·D (prefill/decode)."""
    total, active = param_counts(cfg)
    kind = kind or shape.kind
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence per step
    return 2.0 * active * shape.global_batch


def derive(cost: dict, hlo_text: str, cfg, shape, chips: int) -> RooflineTerms:
    """Derive terms from the compiled HLO via the trip-count-aware cost
    model (launch.hlo_cost).  `compiled.cost_analysis()` counts while-loop
    bodies once — useless for scanned layer stacks — so `cost` is recorded
    for reference but the terms come from hlo_cost.analyze."""
    from repro.launch import hlo_cost

    c = hlo_cost.analyze(hlo_text)
    return RooflineTerms(
        flops_per_dev=float(c.flops),
        hbm_bytes_per_dev=float(c.hbm_bytes),
        coll_bytes_per_dev=int(c.coll_bytes),
        coll_breakdown={k: int(v) for k, v in c.coll_breakdown.items()},
        model_flops_global=model_flops(cfg, shape),
        chips=chips,
    )
