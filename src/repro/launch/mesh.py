"""Production mesh construction.

Single pod : (16, 16) = 256 v5e chips, axes (data, model)
Multi pod  : (2, 16, 16) = 512 chips, axes (pod, data, model); `pod` is the
             outer DCN-connected pure-DP axis.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        if n >= 8:
            shape, axes = (2, 2, n // 4), ("pod", "data", "model")
        elif n >= 4:
            shape, axes = (2, n // 2), ("data", "model")
        else:
            shape, axes = (1, n), ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
