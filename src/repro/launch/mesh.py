"""Production mesh construction.

Single pod : (16, 16) = 256 v5e chips, axes (data, model)
Multi pod  : (2, 16, 16) = 512 chips, axes (pod, data, model); `pod` is the
             outer DCN-connected pure-DP axis.

FUNCTIONS, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax init).

``make_mesh`` is the version-compat constructor every mesh in the repo goes
through: newer jax wants explicit ``axis_types`` (all Auto here), older jax
(<= 0.4.x) has neither ``jax.sharding.AxisType`` nor the kwarg.
"""

from __future__ import annotations

import inspect

import jax


def make_mesh(shape, axes, *, devices=None):
    """Build a Mesh, passing ``axis_types`` only where the install supports it."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if hasattr(jax.sharding, "AxisType") and (
        "axis_types" in inspect.signature(jax.make_mesh).parameters
    ):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        if n >= 8:
            shape, axes = (2, 2, n // 4), ("pod", "data", "model")
        elif n >= 4:
            shape, axes = (2, n // 2), ("data", "model")
        else:
            shape, axes = (1, n), ("data", "model")
    return make_mesh(shape, axes)
