"""Trip-count-aware HLO cost model.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE, which makes
it useless for scanned programs (a 48-layer scan + 8-step microbatch scan is
undercounted ~400x).  This module re-derives FLOPs / HBM bytes / collective
bytes by walking the compiled HLO text from ENTRY, multiplying loop bodies
by their `known_trip_count` backend config.

Cost conventions (per instruction, recursively scaled by loop trips):
  flops       : dot = 2 * numel(result) * contraction_size (matmul flops
                only — the MFU convention); transcendentals tracked apart.
  hbm bytes   : operands + result for MATERIALIZING ops only — dot,
                gather/scatter, dynamic-(update-)slice, reduce(+window),
                sort, concatenate, custom-call — plus result bytes for
                layout ops (transpose/copy/pad/slice).  Pure elementwise
                chains are free: this models TPU fusion, where they fold
                into the neighboring dot/reduce (whose operand bytes
                already account for the read).  The CPU backend wraps every
                op in its own kLoop fusion, so counting at raw fusion
                boundaries would overcount a TPU roofline ~5-10x; a fusion
                is charged boundary bytes only if its body contains a
                materializing op.
  collectives : payload bytes per kind = max(operand bytes, result bytes)
                (robust across AG/RS conventions), *-start counted,
                *-done free.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]{1,9})\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-done",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
    "send-done", "recv-done", "copy-start",
}
_TRANS_OPS = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
              "logistic", "sine", "cosine", "log-plus-one",
              "exponential-minus-one"}
_MATERIALIZING = {
    "convolution", "reduce", "reduce-window", "sort", "concatenate",
    "select-and-scatter", "custom-call", "rng", "rng-bit-generator",
    "triangular-solve", "cholesky",
}
_LAYOUT_OPS = {"transpose", "copy", "pad", "slice", "reverse"}


def _type_numel_bytes(type_str: str) -> int:
    total = 0
    for ty, dims in _SHAPE_RE.findall(type_str):
        if ty not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[ty]
    return total


def _type_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]  # name -> type string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    trans: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )

    def add(self, other: "Cost", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.trans += other.trans * scale
        self.hbm_bytes += other.hbm_bytes * scale
        self.coll_bytes += other.coll_bytes * scale
        for k, v in other.coll_breakdown.items():
            self.coll_breakdown[k] += v * scale


_COMP_HEADER = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((?P<params>.*)\)\s*->\s*(?P<ret>.*?)\s*\{\s*$"
)
_PARAM_RE = re.compile(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\))|[^,()]+)")


def _split_type_op(rhs: str) -> Tuple[str, str, str]:
    """rhs of '=' -> (type_str, op, rest_after_open_paren)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str = rhs[: i + 1]
        rest = rhs[i + 1 :].strip()
    else:
        sp = rhs.find(" ")
        type_str, rest = rhs[:sp], rhs[sp + 1 :].strip()
    par = rest.find("(")
    op = rest[:par].strip()
    return type_str, op, rest[par + 1 :]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER.match(stripped)
            if m:
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry_name = m.group(2)
                for pname, ptype in _PARAM_RE.findall(m.group("params")):
                    cur.symbols[pname] = ptype.strip()
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        if "=" not in stripped or not stripped.lstrip("ROOT ").startswith("%"):
            continue
        body = stripped
        if body.startswith("ROOT "):
            body = body[5:]
        name, _, rhs = body.partition("=")
        name = name.strip().lstrip("%")
        try:
            type_str, op, rest = _split_type_op(rhs)
        except Exception:
            continue
        # operands: %names inside the top-level arg parens
        depth = 1
        argstr = []
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            argstr.append(ch)
        argstr = "".join(argstr)
        attrs = rest[len(argstr) + 1 :]
        operands = re.findall(r"%([\w\.\-]+)", argstr)
        cur.symbols[name] = type_str
        cur.instrs.append(Instr(name, type_str, op, operands, attrs))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_LCD_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _operand_bytes(instr: Instr, comp: Computation) -> float:
    total = 0.0
    seen = set()
    for o in instr.operands:
        if o in seen:
            continue
        seen.add(o)
        t = comp.symbols.get(o)
        if t:
            total += _type_numel_bytes(t)
    return total


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_bytes_dims = _type_dims(instr.type_str) or []
    out_numel = 1
    for d in out_bytes_dims:
        out_numel *= d
    lhs_t = comp.symbols.get(instr.operands[0]) if instr.operands else None
    contraction = 1
    if lhs_t:
        dims = _type_dims(lhs_t) or []
        m = _LCD_RE.search(instr.attrs)
        if m and m.group(1):
            for i in m.group(1).split(","):
                idx = int(i)
                if idx < len(dims):
                    contraction *= dims[idx]
    return 2.0 * out_numel * contraction


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, Cost] = {}

    def total(self) -> Cost:
        return self._cost("__entry__")

    def _cost(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        out = Cost()
        if comp is None:
            return out
        self._memo[comp_name] = out  # guard cycles
        for ins in comp.instrs:
            if ins.op in _FREE_OPS:
                continue
            is_coll = None
            for k in _COLLECTIVES:
                if ins.op == k or ins.op == k + "-start":
                    is_coll = k
                    break
            if is_coll:
                payload = max(
                    _operand_bytes(ins, comp), _type_numel_bytes(ins.type_str)
                )
                out.coll_bytes += payload
                out.coll_breakdown[is_coll] += payload
                out.hbm_bytes += payload  # collectives also touch HBM
                continue
            if ins.op == "while":
                trips = 1
                m = _TRIP_RE.search(ins.attrs)
                if m:
                    trips = int(m.group(1))
                b = _BODY_RE.search(ins.attrs)
                c = _COND_RE.search(ins.attrs)
                if b:
                    out.add(self._cost(b.group(1)), trips)
                if c:
                    out.add(self._cost(c.group(1)), trips)
                continue
            if ins.op in ("call", "conditional", "async-start"):
                m = _CALLS_RE.search(ins.attrs)
                if m:
                    out.add(self._cost(m.group(1)))
                out.hbm_bytes += 0.0
                continue
            if ins.op == "fusion":
                m = _CALLS_RE.search(ins.attrs)
                if m:
                    inner = self._cost(m.group(1))
                    # fused internals: count compute always; charge boundary
                    # bytes only when the body materializes (dot/reduce/...)
                    add = Cost(flops=inner.flops, trans=inner.trans,
                               coll_bytes=inner.coll_bytes,
                               coll_breakdown=inner.coll_breakdown)
                    out.add(add)
                    if self._materializes(m.group(1)):
                        out.hbm_bytes += _operand_bytes(
                            ins, comp
                        ) + _type_numel_bytes(ins.type_str)
                continue
            if ins.op == "dot":
                out.flops += _dot_flops(ins, comp)
                out.hbm_bytes += _operand_bytes(ins, comp) + _type_numel_bytes(
                    ins.type_str
                )
                continue
            if ins.op == "dynamic-slice":
                # reads only the slice (= result), not the whole operand
                out.hbm_bytes += 2 * _type_numel_bytes(ins.type_str)
                continue
            if ins.op == "dynamic-update-slice":
                # read-modify-write of the slice region (operand 1), in place
                upd = (
                    comp.symbols.get(ins.operands[1])
                    if len(ins.operands) > 1
                    else None
                )
                out.hbm_bytes += 2 * _type_numel_bytes(upd or "")
                continue
            if ins.op == "gather":
                idx = (
                    comp.symbols.get(ins.operands[1])
                    if len(ins.operands) > 1
                    else None
                )
                out.hbm_bytes += 2 * _type_numel_bytes(ins.type_str)
                out.hbm_bytes += _type_numel_bytes(idx or "")
                continue
            if ins.op == "scatter":
                upd = (
                    comp.symbols.get(ins.operands[2])
                    if len(ins.operands) > 2
                    else None
                )
                out.hbm_bytes += 3 * _type_numel_bytes(upd or "")
                continue
            if ins.op in _MATERIALIZING:
                out.hbm_bytes += _operand_bytes(ins, comp) + _type_numel_bytes(
                    ins.type_str
                )
                continue
            if ins.op in _LAYOUT_OPS:
                out.hbm_bytes += 2 * _type_numel_bytes(ins.type_str)
                continue
            if ins.op in _TRANS_OPS:
                dims = _type_dims(ins.type_str) or []
                n = 1
                for d in dims:
                    n *= d
                out.trans += n
                continue
            # remaining elementwise ops: assumed fused away on TPU
        return out

    def _materializes(self, comp_name: str) -> bool:
        comp = self.comps.get(comp_name)
        if comp is None:
            return False
        for ins in comp.instrs:
            if ins.op == "dot" or ins.op in _MATERIALIZING:
                return True
            if ins.op == "fusion":
                m = _CALLS_RE.search(ins.attrs)
                if m and self._materializes(m.group(1)):
                    return True
        return False


def analyze(text: str) -> Cost:
    return HloCostModel(text).total()
