"""Serving driver: prefill + batched greedy decode for any --arch.

Runs the real serving path (prefill fills KV/SSM caches, then token-by-token
decode with batched requests).  CPU-sized with --reduced; the full configs
are exercised shape-wise by the dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.distributed.sharding import ShardingRules
    from repro.launch.specs import _model_module
    from repro.models import transformer as tfm
    from repro.train import make_serve_step

    entry = get_arch(args.arch)
    cfg = entry.reduced if args.reduced else entry.config
    assert not cfg.is_encdec, "use examples/serve_lm.py for enc-dec serving"
    rules = ShardingRules.make(None)
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)

    rng = np.random.default_rng(args.seed)
    max_seq = args.prompt_len + args.gen
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )

    t0 = time.time()
    prefill_fn = jax.jit(lambda p, t: tfm.prefill(p, t, cfg, rules, max_seq))
    logits, caches = prefill_fn(params, prompts)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0

    serve = jax.jit(
        make_serve_step(lambda p, t, c, n: tfm.decode_step(p, t, c, n, cfg, rules))
    )
    token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [token]
    t0 = time.time()
    for i in range(args.gen - 1):
        token, logits, caches = serve(
            params, token, caches, jnp.int32(args.prompt_len + i)
        )
        out_tokens.append(token)
    jax.block_until_ready(token)
    decode_s = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    tok_s = args.batch * (args.gen - 1) / max(decode_s, 1e-9)
    print(f"{cfg.name}: prefill({args.batch}x{args.prompt_len}) {prefill_s:.2f}s, "
          f"decode {args.gen-1} steps {decode_s:.2f}s ({tok_s:.1f} tok/s)")
    print("sample token ids:", np.asarray(gen[0, :16]).tolist())
    assert int(gen.max()) < cfg.vocab_size and int(gen.min()) >= 0


if __name__ == "__main__":
    main()
