"""serve_preprocess: N concurrent synthetic jobs on one shared ISP pool.

Drives the preprocessing-as-a-service surface end to end: a
``PreprocessingService`` pool serves N tenants, each a synthetic RM job with
its own partition range, placement, and (optional) QoS target; every tenant
is drained by its own consumer thread that simulates a trainer (a fixed
per-batch train time).  Prints the paper's Fig. 3 accounting per job —
utilization, starvation, straggler re-issues, feature-cache hits — plus the
pool's unit shares.

With ``--cache`` the pool carries a shared content-addressed feature cache
(``core.featcache``): tenants of the same RM generate identical partition
content (deterministic synthetic sources), so overlapping work deduplicates
across tenants even though every job builds its own store object.

``--dup-factor D`` makes every tenant's dataset sample-level deduped
(RecD): each session's sparse feature block repeats D times, partitions are
stored and staged as unique blocks + per-sample refs (the stores charge
only unique bytes — watch the dedup summary line), and with ``--cache`` the
shared block tier assembles repeat partitions from other tenants' published
blocks (the blk column, hits/published; ``--dup-pool`` sizes the shared
dataset-level block pool that gives tenants real overlap).

The pool's units are bound to a shared ``data.storage.DeviceFleet`` of
``--devices`` simulated ISP devices: every tenant's partitions live on (and
charge) those devices, claims are locality-aware, and skewed ownership
(``--skew``) drives hot devices past the fallback threshold.  A per-device
utilization table (occupancy, queue depth, fallbacks) prints after the
per-job table.

The pool is ELASTIC (``core.ctrlplane``): ``--kill WID@N`` crash-simulates
pool workers mid-job (their claims re-issue through the straggler path),
``--restart-after N`` checkpoints every half-drained session, tears the
whole service down, and resumes bitwise-identically on a fresh one,
``--autoscale MIN:MAX`` runs the backlog-driven policy loop, and
``--verify`` recomputes every delivered batch solo and asserts the chaos
run's output is bitwise identical and complete.  Every membership change,
re-issue, checkpoint, and scale decision lands in the structured event
stream (summarized at exit; ``--events-out`` writes the JSON artifact).

The STORAGE fault domain is drillable too (``data.storage.IoFaultInjector``):
``--io-faults SPEC`` seeds deterministic I/O chaos into every tenant's store
— transient read errors, torn (bit-flipped) blocks caught by end-to-end
content digests, slow reads, spill-block corruption, and a whole device
knocked offline mid-run.  Sessions absorb the faults through bounded
retry/backoff, device failover, and per-partition quarantine; with
``--verify`` the drill asserts the faulted run's output is still bitwise
identical to a fault-free solo recompute.  The exit code is non-zero when
verification fails or any session ends with a quarantined partition.

    PYTHONPATH=src python -m repro.launch.serve_preprocess --jobs 2 --reduced
"""

from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import os
import tempfile
import threading
import time

import numpy as np

from repro.configs.registry import get_recsys
from repro.core.costmodel import ContentionAwareCostModel
from repro.core.ctrlplane import Autoscaler, AutoscalePolicy, parse_kill_spec
from repro.core.featcache import FeatureCache, default_spill_store
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.storage import (
    DeviceFleet,
    PartitionedStore,
    parse_iofault_spec,
    zipf_owner_map,
)
from repro.data.synth import SyntheticRecSysSource

EPILOG = """\
multi-tenant flags:
  --jobs N --workers M       N tenants share a pool of M units (admission
                             guarantees each tenant 1 unit or rejects it)
  --qos S                    per-job QoS target in samples/s; demand is
                             re-estimated as ceil(target / measured P)
device flags:
  --devices N                shared fleet of N simulated ISP devices; pool
                             units bind to devices round-robin and claims
                             prefer the partition's owning device (0 = the
                             legacy fungible pool, no device table)
  --skew ALPHA               Zipf(ALPHA)-skewed partition->device ownership
                             shared by every tenant: hot devices queue past
                             the fallback threshold and shed work to the
                             host (watch the fallback column; 0 = uniform)
cache flags:
  --cache                    shared content-addressed feature cache across
                             tenants (keys: partition fingerprint x lowered
                             opgraph hash x placement)
  --cache-mb MB              in-memory LRU tier bound (default 256 MB)
  --spill-devices K          add a spill tier on K simulated storage devices
                             (evictions land there; 0 = no spill tier; K ==
                             --devices reuses the shared fleet's ledgers)
dedup flags:
  --dup-factor D             sample-level dedup (RecD): every session's
                             sparse block repeats D times; partitions stage
                             as unique blocks + refs, stores charge unique
                             bytes only (D=1 = classic layout; rows/D must
                             be a multiple of 32)
  --dup-pool P               dataset-level shared block pool (default 16):
                             blocks repeat ACROSS partitions and tenants,
                             so the shared cache's block tier can assemble
                             one tenant's partitions from another's blocks
pipeline flags:
  --megabatch K              pool workers coalesce up to K same-job claims
                             into ONE megabatched kernel launch (bitwise
                             identical to solo launches, one dispatch)
  --autotune                 let the online MegabatchTuner pick K per job:
                             seeded from the cost model, hill-climbed from
                             measured launch timings (--megabatch becomes
                             the K cap; watch the tunedK column)
  --lookahead D              stage up to D chunks of future claims behind
                             the in-flight kernel (byte-budgeted; D=1 is
                             the classic double buffer) and pre-warm cache
                             leases over the peek window
  --no-prewarm               keep the lookahead window but skip issuing
                             cache pre-warm leases ahead of the cursor
  --no-pipeline              legacy serial worker loop: no megabatching, no
                             read/compute overlap (A/B baseline)
control-plane flags (core.ctrlplane):
  --kill WID@N               crash-simulate pool worker WID once N total
                             batches have been delivered (repeatable); its
                             in-flight claims re-issue via the straggler
                             path — output stays bitwise identical
  --restart-after N          after N total delivered batches: checkpoint
                             every unfinished session, close the service,
                             rebuild it, and resume from the checkpoints
  --autoscale MIN:MAX        run the backlog-driven autoscaler between MIN
                             and MAX workers (scale decisions land in the
                             event stream)
  --autoscale-interval S     policy evaluation period in seconds (0.05)
  --io-faults SPEC           seeded I/O fault injection into every store:
                             comma-joined knobs out of transient=P
                             (retryable read errors), corrupt=P (torn
                             blocks, caught by content digests), spill=P
                             (spill-block corruption), slow=P[:SECONDS],
                             offline=DEV@N (device DEV dies after N reads),
                             seed=K — e.g.
                             transient=0.2,corrupt=0.1,offline=1@8,seed=7
  --io-retries N             per-partition retry budget before quarantine
                             (default 3); --io-backoff-ms is the base of
                             the exponential backoff (default 10)
  --verify                   recompute every delivered batch solo; assert
                             the (chaos) run delivered every partition,
                             bitwise identical
  --events-out PATH          dump the structured event stream (all service
                             incarnations, JSON) for CI artifact upload

examples:
  PYTHONPATH=src python -m repro.launch.serve_preprocess --jobs 2 --reduced
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 2 --reduced --autotune --lookahead 4
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 3 --reduced --cache --cache-mb 64 --spill-devices 4
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 2 --reduced --devices 4 --skew 1.1
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 2 --reduced --kill 1@3 --restart-after 8 --verify \\
      --events-out EVENTS_chaos.json
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 2 --reduced --workers 2 --units 3 --autoscale 2:6
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 2 --reduced --cache --spill-devices 4 --verify \\
      --io-faults transient=0.2,corrupt=0.1,spill=0.3,offline=1@8,seed=7 \\
      --events-out EVENTS_iofaults.json
"""


class _Counter:
    """Total delivered batches across every tenant (the chaos thresholds)."""

    def __init__(self):
        self.n = 0
        self.cond = threading.Condition()

    def bump(self) -> None:
        with self.cond:
            self.n += 1
            self.cond.notify_all()


def _consume(session, consume_s: float, result: dict, got: dict,
             counter: _Counter) -> None:
    """A tenant's trainer: drain the session, spending consume_s per batch.

    Accumulates across service incarnations (the restart drill re-enters
    with the resumed session).  A RuntimeError is the service being torn
    down mid-stream — recorded, not raised; main() re-raises unless a
    restart was actually requested."""
    busy = 0.0
    batches = 0
    t0 = time.perf_counter()
    try:
        for pid, mb in session:
            s0 = time.perf_counter()
            if consume_s > 0:
                time.sleep(consume_s)  # stand-in for the accelerator step
            busy += time.perf_counter() - s0
            batches += 1
            got[pid] = mb
            counter.bump()
    except RuntimeError as e:
        result["interrupted"] = repr(e)
    result["busy_s"] = result.get("busy_s", 0.0) + busy
    result["batches"] = result.get("batches", 0) + batches
    result["wall_s"] = result.get("wall_s", 0.0) + (time.perf_counter() - t0)


def _chaos_monitor(service, counter: _Counter, kills, restart_after,
                   do_restart) -> None:
    """Applies --kill / --restart-after directives as the global delivered
    count crosses their thresholds."""
    pending = sorted(kills)
    while pending or restart_after is not None:
        with counter.cond:
            counter.cond.wait(timeout=0.1)
            n = counter.n
        while pending and n >= pending[0][0]:
            after, wid = pending.pop(0)
            ok = service.kill_worker(wid)
            print(f"chaos: killed worker {wid} after {after} delivered "
                  f"batch(es)" if ok else
                  f"chaos: worker {wid} already gone at {after} batches")
        if restart_after is not None and n >= restart_after:
            print(f"chaos: restarting the service after {restart_after} "
                  f"delivered batch(es)")
            do_restart()
            return
        if service.closed:
            return


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, epilog=EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--jobs", type=int, default=2, help="concurrent tenants")
    ap.add_argument("--workers", type=int, default=None,
                    help="pool size (default: jobs + 1)")
    ap.add_argument("--rm", nargs="+", default=["rm1"],
                    help="RM configs, assigned round-robin to jobs")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced RM geometries (CI-sized)")
    ap.add_argument("--rows", type=int, default=256, help="rows per partition")
    ap.add_argument("--partitions", type=int, default=6, help="partitions per job")
    ap.add_argument("--placement", default="presto",
                    choices=("presto", "disagg", "hybrid"))
    ap.add_argument("--qos", type=float, default=None,
                    help="per-job QoS target (samples/s); default best-effort")
    ap.add_argument("--units", type=int, default=None,
                    help="explicit per-job demand units (the autoscaler's "
                         "demand cap; default: estimated)")
    ap.add_argument("--consume-ms", type=float, default=5.0,
                    help="simulated train-step time per batch")
    ap.add_argument("--devices", type=int, default=4,
                    help="shared fleet of N simulated ISP devices the pool "
                         "binds to (0 = legacy fungible pool)")
    ap.add_argument("--skew", type=float, default=0.0, metavar="ALPHA",
                    help="Zipf(ALPHA)-skewed partition->device ownership "
                         "(0 = uniform round-robin)")
    ap.add_argument("--cache", action="store_true",
                    help="shared content-addressed feature cache")
    ap.add_argument("--cache-mb", type=int, default=256,
                    help="cache memory-tier bound in MB (default 256)")
    ap.add_argument("--spill-devices", type=int, default=0,
                    help="spill tier on K simulated devices (0 = none)")
    ap.add_argument("--dup-factor", type=int, default=1, metavar="D",
                    help="sample-level dedup: each session's sparse block "
                         "repeats D times; partitions stage as unique "
                         "blocks + refs (default 1 = classic layout)")
    ap.add_argument("--dup-pool", type=int, default=16, metavar="P",
                    help="dataset-level shared block pool size under "
                         "--dup-factor (cross-partition/tenant overlap; "
                         "default 16)")
    ap.add_argument("--megabatch", type=int, default=1, metavar="K",
                    help="coalesce up to K same-job claims into one "
                         "megabatched kernel launch (default 1)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune megabatch K online per job (--megabatch "
                         "caps the ladder)")
    ap.add_argument("--lookahead", type=int, default=1, metavar="D",
                    help="staged-chunk lookahead window depth (default 1 = "
                         "classic double buffer)")
    ap.add_argument("--no-prewarm", action="store_true",
                    help="disable cache pre-warm leases over the lookahead "
                         "peek window")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="disable the zero-stall worker path (megabatching "
                         "+ read/compute overlap); legacy serial produces")
    ap.add_argument("--kill", action="append", metavar="WID@N",
                    help="crash-simulate pool worker WID after N total "
                         "delivered batches (repeatable)")
    ap.add_argument("--restart-after", type=int, default=None, metavar="N",
                    help="checkpoint + tear down + resume the whole service "
                         "after N total delivered batches")
    ap.add_argument("--autoscale", default=None, metavar="MIN:MAX",
                    help="run the backlog-driven autoscaler between MIN and "
                         "MAX workers")
    ap.add_argument("--autoscale-interval", type=float, default=0.05,
                    metavar="S", help="autoscaler evaluation period (s)")
    ap.add_argument("--io-faults", default=None, metavar="SPEC",
                    help="seeded I/O fault injection into every store "
                         "(transient=P,corrupt=P,spill=P,slow=P[:S],"
                         "offline=DEV@N,seed=K)")
    ap.add_argument("--io-retries", type=int, default=3, metavar="N",
                    help="per-partition retry budget before quarantine "
                         "(default 3)")
    ap.add_argument("--io-backoff-ms", type=float, default=10.0, metavar="MS",
                    help="base retry backoff in ms, doubled per attempt "
                         "(default 10)")
    ap.add_argument("--verify", action="store_true",
                    help="recompute every delivered batch solo and assert "
                         "bitwise-identical, complete output")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the structured event stream as JSON")
    args = ap.parse_args(argv)

    workers = args.workers if args.workers is not None else args.jobs + 1
    kills = [parse_kill_spec(s) for s in (args.kill or [])]
    scale_bounds = None
    if args.autoscale:
        lo, _, hi = args.autoscale.partition(":")
        scale_bounds = (int(lo), int(hi))
    chaos = bool(kills) or args.restart_after is not None
    cost_model = ContentionAwareCostModel()
    fleet = (DeviceFleet.from_cost_model(args.devices, cost_model)
             if args.devices > 0 else None)
    # ONE seeded injector shared by every tenant's store: the offline
    # trigger counts reads pool-wide, exactly like a real device dying
    # under everyone at once
    injector = parse_iofault_spec(args.io_faults) if args.io_faults else None
    owner_map = None
    if fleet is not None and args.skew > 0:
        # one shared map: every tenant's partition p lives on the same hot
        # device, so skew compounds across tenants instead of averaging out
        owner_map = zipf_owner_map(args.partitions, args.devices, args.skew)
    cache = None
    if args.cache:
        spill_fleet = (fleet if fleet is not None
                       and args.spill_devices == len(fleet) else None)
        spill = (default_spill_store(args.spill_devices, fleet=spill_fleet)
                 if args.spill_devices > 0 else None)
        cache = FeatureCache(args.cache_mb << 20, spill=spill)

    ckpt_dir = tempfile.mkdtemp(prefix="presto-ckpt-") if chaos else None
    jobspecs, job_specs_ts, stores = [], {}, {}
    rms = itertools.cycle(args.rm)
    if args.dup_factor > 1:
        assert args.rows % args.dup_factor == 0 and (
            args.rows // args.dup_factor) % 32 == 0, (
            f"--dup-factor {args.dup_factor}: rows/D must be a multiple of "
            f"32 (got {args.rows} rows)")
    for j in range(args.jobs):
        rm = next(rms)
        rcfg = get_recsys(rm, reduced=args.reduced)
        data_cfg = rcfg.data
        if args.dup_factor > 1:
            data_cfg = dataclasses.replace(
                data_cfg, dup_factor=args.dup_factor, dup_pool=args.dup_pool)
        src = SyntheticRecSysSource(data_cfg, rows=args.rows)
        spec = TransformSpec.from_source(src)
        store = PartitionedStore(
            args.partitions, num_devices=args.devices or 4, source=src,
            fleet=fleet, owner_map=owner_map, fault_injector=injector)
        name = f"{rm}-job{j}"
        job = JobSpec(
            name=name,
            partitions=range(args.partitions),
            spec=spec,
            store=store,
            placement=args.placement,
            target_samples_per_s=args.qos,
            units=args.units,
            megabatch=args.megabatch,
            autotune=args.autotune,
            lookahead=args.lookahead,
            prewarm=not args.no_prewarm,
            checkpoint_path=(os.path.join(ckpt_dir, f"{name}.json")
                             if ckpt_dir else None),
            checkpoint_every=4,
            io_retries=args.io_retries,
            io_backoff_s=args.io_backoff_ms / 1e3,
        )
        jobspecs.append(job)
        job_specs_ts[name] = spec
        stores[name] = store

    def make_service():
        return PreprocessingService(
            num_workers=workers, cache=cache, devices=fleet,
            cost_model=cost_model, pipeline=not args.no_pipeline)

    print(f"pool: {workers} workers serving {args.jobs} jobs "
          f"({args.partitions} x {args.rows}-row partitions each, "
          f"placement={args.placement})")
    if chaos:
        directives = [f"kill {w}@{n}" for n, w in kills]
        if args.restart_after is not None:
            directives.append(f"restart@{args.restart_after}")
        print(f"chaos: {', '.join(directives)}")
    if injector is not None:
        print(f"io-faults: {args.io_faults} (retry budget "
              f"{args.io_retries}, backoff {args.io_backoff_ms}ms)")

    counter = _Counter()
    results = {job.name: {} for job in jobspecs}
    gots = {job.name: {} for job in jobspecs}
    final_sessions = {}
    ckpts = {}
    all_events, event_counts = [], {}
    restart_pending = args.restart_after
    wall0 = time.perf_counter()
    phase = 0
    while True:
        phase += 1
        service = make_service()
        if injector is not None:
            # each incarnation gets the injected-fault events in ITS stream
            injector.events = service.events
        scaler = None
        if scale_bounds is not None:
            scaler = Autoscaler(service, AutoscalePolicy(
                min_workers=scale_bounds[0], max_workers=scale_bounds[1]))
        sessions, threads = {}, []
        for job in jobspecs:
            if job.name in final_sessions:
                continue  # finished in an earlier incarnation
            session = service.submit(job, resume_from=ckpts.pop(job.name, None))
            sessions[job.name] = session
            threads.append(threading.Thread(
                target=_consume,
                args=(session, args.consume_ms / 1e3, results[job.name],
                      gots[job.name], counter)))

        restart_requested = threading.Event()

        def do_restart(sessions=sessions, service=service):
            # exact frontier at teardown: anything delivered after this
            # snapshot is simply re-produced on resume (bitwise identical)
            for name, session in sessions.items():
                if not session.stats().done:
                    ckpts[name] = session.checkpoint()
            restart_requested.set()
            service.close()

        monitor = None
        if (kills and phase == 1) or restart_pending is not None:
            monitor = threading.Thread(
                target=_chaos_monitor,
                args=(service, counter, kills if phase == 1 else [],
                      restart_pending, do_restart),
                daemon=True)
        for t in threads:
            t.start()
        if scaler is not None:
            scaler.start(args.autoscale_interval)
        if monitor is not None:
            monitor.start()
        for t in threads:
            t.join()
        if scaler is not None:
            scaler.stop()
        for name, session in sessions.items():
            st = session.stats()
            if st.done:
                final_sessions[name] = session
            elif not restart_requested.is_set():
                quarantined = (f" ({st.quarantined} partition(s) "
                               f"quarantined)" if st.quarantined else "")
                raise RuntimeError(
                    f"job {name} interrupted without a requested restart"
                    f"{quarantined}: {results[name].get('interrupted')}")
        if not service.closed:
            service.close()
        all_events.extend(service.events.to_dicts())
        for kind, n in service.events.counts().items():
            event_counts[kind] = event_counts.get(kind, 0) + n
        if restart_requested.is_set():
            restart_pending = None  # the drill restarts at most once
            remaining = [j.name for j in jobspecs
                         if j.name not in final_sessions]
            print(f"chaos: resuming {len(remaining)} checkpointed job(s) on "
                  f"a fresh service")
            continue
        break
    wall = time.perf_counter() - wall0

    print(f"\n{'job':<12} {'batches':>7} {'rows/s':>9} {'util':>6} "
          f"{'starve':>7} {'reissue':>7} {'dupes':>6} {'hits':>5} "
          f"{'blk':>7} {'fallbk':>6} {'tunedK':>6} {'staged':>8} "
          f"{'prewrm':>6} {'share/demand':>13}")
    for job in jobspecs:
        st = final_sessions[job.name].stats()
        result = results[job.name]
        util = result["busy_s"] / max(result["wall_s"], 1e-9)
        assert st.done and not st.cancelled, f"job {st.job} did not drain"
        if not chaos:
            assert result["batches"] == st.total
        staged = (f"{st.staged_bytes_peak / 1e6:.1f}M"
                  if st.staged_bytes_peak else "-")
        # blk: batches assembled from the shared block tier / unique blocks
        # this tenant published into it (only dedup'd cacheable jobs move it)
        blk = (f"{st.block_hits}/{st.blocks_published}"
               if args.dup_factor > 1 else "-")
        print(f"{st.job:<12} {result['batches']:>7} "
              f"{st.achieved_samples_per_s:>9.0f} "
              f"{util:>6.2f} {st.starvation:>7.2f} {st.reissues:>7} "
              f"{st.duplicates_dropped:>6} {st.cache_hits:>5} "
              f"{blk:>7} {st.host_fallbacks:>6} {st.tuned_k:>6} "
              f"{staged:>8} {st.prewarm_hits:>6} "
              f"{st.share:>7}/{st.effective_demand_units}")
    total_rows = sum(s.stats().rows_delivered for s in final_sessions.values())
    print(f"\naggregate: {total_rows} rows in {wall:.1f}s "
          f"({total_rows / max(wall, 1e-9):.0f} rows/s across tenants)")
    if args.dup_factor > 1:
        moved = sum(s.bytes_read for s in stores.values())
        logical = sum(s.logical_bytes_read for s in stores.values())
        if logical:
            print(f"dedup: moved {moved / 1e6:.2f}MB of "
                  f"{logical / 1e6:.2f}MB logical "
                  f"({(logical - moved) / logical * 100:.1f}% stayed on "
                  f"storage at dup-factor {args.dup_factor})")

    if args.verify:
        # the chaos acceptance gate: every partition delivered exactly once
        # per tenant's output map, bitwise identical to a solo recompute
        # (reads go clean — the injector must not fault the reference)
        for store in stores.values():
            store.fault_injector = None
        for job in jobspecs:
            got = gots[job.name]
            missing = sorted(set(range(args.partitions)) - set(got))
            assert not missing, f"job {job.name} missing partitions {missing}"
            engine = PreStoEngine(job_specs_ts[job.name],
                                  placement=args.placement)
            for pid, mb in sorted(got.items()):
                want = engine.produce_batch(stores[job.name], pid)
                assert sorted(mb) == sorted(want)
                for key in want:
                    np.testing.assert_array_equal(
                        np.asarray(mb[key]), np.asarray(want[key]))
        print(f"verify: {args.jobs} job(s) x {args.partitions} partitions "
              f"bitwise identical to solo recompute")

    if fleet is not None:
        print(f"\n{'device':<9} {'claims':>7} {'queue':>6} {'max-infl':>9} "
              f"{'fallback':>9} {'stream MB':>10} {'spill MB':>9} "
              f"{'busy ms':>8}")
        for snap in fleet.utilization():
            print(f"dev{snap['device']:03d}   {snap['isp_claims']:>7} "
                  f"{snap['queue_depth']:>6} {snap['max_inflight']:>9} "
                  f"{snap['host_fallbacks']:>9} "
                  f"{snap['bytes_streamed'] / 1e6:>10.2f} "
                  f"{snap['spill_bytes'] / 1e6:>9.2f} "
                  f"{snap['busy_s'] * 1e3:>8.2f}")
        print(f"{'host':<9} {fleet.host_produces:>7} {'-':>6} {'-':>9} "
              f"{'-':>9} {fleet.host_link_bytes / 1e6:>10.2f} {'-':>9} "
              f"{fleet.host_busy_s * 1e3:>8.2f}")
        if args.skew > 0:
            total_fallbacks = sum(d.host_fallbacks for d in fleet)
            print(f"skew={args.skew}: {total_fallbacks} claim(s) fell back "
                  f"to the host path")
    if cache is not None:
        cs = cache.stats()
        print(f"cache: hits={cs.hits} follows={cs.follows} misses={cs.misses} "
              f"hit_rate={cs.hit_rate:.2f} entries={cs.entries} "
              f"resident={cs.resident_bytes / 1e6:.1f}MB "
              f"spilled={cs.spilled_entries} ({cs.spilled_bytes / 1e6:.1f}MB, "
              f"{cs.spill_io_s * 1e3:.2f}ms modeled I/O)")

    if injector is not None:
        stats = [s.stats() for s in final_sessions.values()]
        tot_r = sum(s.retries for s in stats)
        tot_f = sum(s.failovers for s in stats)
        tot_q = sum(s.quarantined for s in stats)
        injected = " ".join(
            f"{k}={n}" for k, n in sorted(injector.summary().items()) if n)
        print(f"io-faults: injected[{injected or 'none'}] "
              f"retries={tot_r} failovers={tot_f} quarantined={tot_q}")
        if tot_q:
            raise SystemExit(
                f"io-faults: {tot_q} partition(s) ended quarantined")

    if event_counts:
        summary = " ".join(f"{k}={n}" for k, n in sorted(event_counts.items()))
        print(f"\nevents: {summary}")
        for ev in all_events[-8:]:
            data = " ".join(f"{k}={v}" for k, v in ev["data"].items())
            print(f"  [{ev['seq']:>4}] {ev['kind']:<14} {data}")
    if args.events_out:
        with open(args.events_out, "w") as f:
            json.dump(all_events, f, indent=2, default=str)
        print(f"events: wrote {len(all_events)} event(s) to {args.events_out}")


if __name__ == "__main__":
    main()
