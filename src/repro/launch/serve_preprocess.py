"""serve_preprocess: N concurrent synthetic jobs on one shared ISP pool.

Drives the preprocessing-as-a-service surface end to end: a
``PreprocessingService`` pool serves N tenants, each a synthetic RM job with
its own partition range, placement, and (optional) QoS target; every tenant
is drained by its own consumer thread that simulates a trainer (a fixed
per-batch train time).  Prints the paper's Fig. 3 accounting per job —
utilization, starvation, straggler re-issues, feature-cache hits — plus the
pool's unit shares.

With ``--cache`` the pool carries a shared content-addressed feature cache
(``core.featcache``): tenants of the same RM generate identical partition
content (deterministic synthetic sources), so overlapping work deduplicates
across tenants even though every job builds its own store object.

The pool's units are bound to a shared ``data.storage.DeviceFleet`` of
``--devices`` simulated ISP devices: every tenant's partitions live on (and
charge) those devices, claims are locality-aware, and skewed ownership
(``--skew``) drives hot devices past the fallback threshold.  A per-device
utilization table (occupancy, queue depth, fallbacks) prints after the
per-job table.

    PYTHONPATH=src python -m repro.launch.serve_preprocess --jobs 2 --reduced
"""

from __future__ import annotations

import argparse
import itertools
import threading
import time

from repro.configs.registry import get_recsys
from repro.core.costmodel import ContentionAwareCostModel
from repro.core.featcache import FeatureCache, default_spill_store
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.storage import DeviceFleet, PartitionedStore, zipf_owner_map
from repro.data.synth import SyntheticRecSysSource

EPILOG = """\
multi-tenant flags:
  --jobs N --workers M       N tenants share a pool of M units (admission
                             guarantees each tenant 1 unit or rejects it)
  --qos S                    per-job QoS target in samples/s; demand is
                             re-estimated as ceil(target / measured P)
device flags:
  --devices N                shared fleet of N simulated ISP devices; pool
                             units bind to devices round-robin and claims
                             prefer the partition's owning device (0 = the
                             legacy fungible pool, no device table)
  --skew ALPHA               Zipf(ALPHA)-skewed partition->device ownership
                             shared by every tenant: hot devices queue past
                             the fallback threshold and shed work to the
                             host (watch the fallback column; 0 = uniform)
cache flags:
  --cache                    shared content-addressed feature cache across
                             tenants (keys: partition fingerprint x lowered
                             opgraph hash x placement)
  --cache-mb MB              in-memory LRU tier bound (default 256 MB)
  --spill-devices K          add a spill tier on K simulated storage devices
                             (evictions land there; 0 = no spill tier; K ==
                             --devices reuses the shared fleet's ledgers)
pipeline flags:
  --megabatch K              pool workers coalesce up to K same-job claims
                             into ONE megabatched kernel launch (bitwise
                             identical to solo launches, one dispatch)
  --autotune                 let the online MegabatchTuner pick K per job:
                             seeded from the cost model, hill-climbed from
                             measured launch timings (--megabatch becomes
                             the K cap; watch the tunedK column)
  --lookahead D              stage up to D chunks of future claims behind
                             the in-flight kernel (byte-budgeted; D=1 is
                             the classic double buffer) and pre-warm cache
                             leases over the peek window
  --no-prewarm               keep the lookahead window but skip issuing
                             cache pre-warm leases ahead of the cursor
  --no-pipeline              legacy serial worker loop: no megabatching, no
                             read/compute overlap (A/B baseline)

examples:
  PYTHONPATH=src python -m repro.launch.serve_preprocess --jobs 2 --reduced
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 2 --reduced --megabatch 4
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 2 --reduced --autotune --lookahead 4
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 3 --reduced --cache --cache-mb 64 --spill-devices 4
  PYTHONPATH=src python -m repro.launch.serve_preprocess \\
      --jobs 2 --reduced --devices 4 --skew 1.1
"""


def _consume(session, consume_s: float, result: dict) -> None:
    """A tenant's trainer: drain the session, spending consume_s per batch."""
    busy = 0.0
    batches = 0
    t0 = time.perf_counter()
    for _pid, _mb in session:
        s0 = time.perf_counter()
        if consume_s > 0:
            time.sleep(consume_s)  # stand-in for the accelerator step
        busy += time.perf_counter() - s0
        batches += 1
    result["busy_s"] = busy
    result["batches"] = batches
    result["wall_s"] = time.perf_counter() - t0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, epilog=EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--jobs", type=int, default=2, help="concurrent tenants")
    ap.add_argument("--workers", type=int, default=None,
                    help="pool size (default: jobs + 1)")
    ap.add_argument("--rm", nargs="+", default=["rm1"],
                    help="RM configs, assigned round-robin to jobs")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced RM geometries (CI-sized)")
    ap.add_argument("--rows", type=int, default=256, help="rows per partition")
    ap.add_argument("--partitions", type=int, default=6, help="partitions per job")
    ap.add_argument("--placement", default="presto",
                    choices=("presto", "disagg", "hybrid"))
    ap.add_argument("--qos", type=float, default=None,
                    help="per-job QoS target (samples/s); default best-effort")
    ap.add_argument("--consume-ms", type=float, default=5.0,
                    help="simulated train-step time per batch")
    ap.add_argument("--devices", type=int, default=4,
                    help="shared fleet of N simulated ISP devices the pool "
                         "binds to (0 = legacy fungible pool)")
    ap.add_argument("--skew", type=float, default=0.0, metavar="ALPHA",
                    help="Zipf(ALPHA)-skewed partition->device ownership "
                         "(0 = uniform round-robin)")
    ap.add_argument("--cache", action="store_true",
                    help="shared content-addressed feature cache")
    ap.add_argument("--cache-mb", type=int, default=256,
                    help="cache memory-tier bound in MB (default 256)")
    ap.add_argument("--spill-devices", type=int, default=0,
                    help="spill tier on K simulated devices (0 = none)")
    ap.add_argument("--megabatch", type=int, default=1, metavar="K",
                    help="coalesce up to K same-job claims into one "
                         "megabatched kernel launch (default 1)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune megabatch K online per job (--megabatch "
                         "caps the ladder)")
    ap.add_argument("--lookahead", type=int, default=1, metavar="D",
                    help="staged-chunk lookahead window depth (default 1 = "
                         "classic double buffer)")
    ap.add_argument("--no-prewarm", action="store_true",
                    help="disable cache pre-warm leases over the lookahead "
                         "peek window")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="disable the zero-stall worker path (megabatching "
                         "+ read/compute overlap); legacy serial produces")
    args = ap.parse_args(argv)

    workers = args.workers if args.workers is not None else args.jobs + 1
    cost_model = ContentionAwareCostModel()
    fleet = (DeviceFleet.from_cost_model(args.devices, cost_model)
             if args.devices > 0 else None)
    owner_map = None
    if fleet is not None and args.skew > 0:
        # one shared map: every tenant's partition p lives on the same hot
        # device, so skew compounds across tenants instead of averaging out
        owner_map = zipf_owner_map(args.partitions, args.devices, args.skew)
    cache = None
    if args.cache:
        spill_fleet = (fleet if fleet is not None
                       and args.spill_devices == len(fleet) else None)
        spill = (default_spill_store(args.spill_devices, fleet=spill_fleet)
                 if args.spill_devices > 0 else None)
        cache = FeatureCache(args.cache_mb << 20, spill=spill)
    service = PreprocessingService(
        num_workers=workers, cache=cache, devices=fleet,
        cost_model=cost_model, pipeline=not args.no_pipeline)
    sessions, results, threads = [], [], []
    rms = itertools.cycle(args.rm)
    for j in range(args.jobs):
        rm = next(rms)
        rcfg = get_recsys(rm, reduced=args.reduced)
        src = SyntheticRecSysSource(rcfg.data, rows=args.rows)
        spec = TransformSpec.from_source(src)
        store = PartitionedStore(
            args.partitions, num_devices=args.devices or 4, source=src,
            fleet=fleet, owner_map=owner_map)
        session = service.submit(JobSpec(
            name=f"{rm}-job{j}",
            partitions=range(args.partitions),
            spec=spec,
            store=store,
            placement=args.placement,
            target_samples_per_s=args.qos,
            megabatch=args.megabatch,
            autotune=args.autotune,
            lookahead=args.lookahead,
            prewarm=not args.no_prewarm,
        ))
        result: dict = {}
        t = threading.Thread(target=_consume,
                             args=(session, args.consume_ms / 1e3, result))
        sessions.append(session)
        results.append(result)
        threads.append(t)

    print(f"pool: {workers} workers serving {args.jobs} jobs "
          f"({args.partitions} x {args.rows}-row partitions each, "
          f"placement={args.placement})")
    wall0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - wall0

    print(f"\n{'job':<12} {'batches':>7} {'rows/s':>9} {'util':>6} "
          f"{'starve':>7} {'reissue':>7} {'dupes':>6} {'hits':>5} "
          f"{'fallbk':>6} {'tunedK':>6} {'staged':>8} {'prewrm':>6} "
          f"{'share/demand':>13}")
    for session, result in zip(sessions, results):
        st = session.stats()
        util = result["busy_s"] / max(result["wall_s"], 1e-9)
        assert st.done and not st.cancelled, f"job {st.job} did not drain"
        assert result["batches"] == st.total
        staged = (f"{st.staged_bytes_peak / 1e6:.1f}M"
                  if st.staged_bytes_peak else "-")
        print(f"{st.job:<12} {st.delivered:>7} {st.achieved_samples_per_s:>9.0f} "
              f"{util:>6.2f} {st.starvation:>7.2f} {st.reissues:>7} "
              f"{st.duplicates_dropped:>6} {st.cache_hits:>5} "
              f"{st.host_fallbacks:>6} {st.tuned_k:>6} {staged:>8} "
              f"{st.prewarm_hits:>6} "
              f"{st.share:>7}/{st.effective_demand_units}")
    service.close()
    total_rows = sum(s.stats().rows_delivered for s in sessions)
    print(f"\naggregate: {total_rows} rows in {wall:.1f}s "
          f"({total_rows / max(wall, 1e-9):.0f} rows/s across tenants)")
    if fleet is not None:
        print(f"\n{'device':<9} {'claims':>7} {'queue':>6} {'max-infl':>9} "
              f"{'fallback':>9} {'stream MB':>10} {'spill MB':>9} "
              f"{'busy ms':>8}")
        for snap in fleet.utilization():
            print(f"dev{snap['device']:03d}   {snap['isp_claims']:>7} "
                  f"{snap['queue_depth']:>6} {snap['max_inflight']:>9} "
                  f"{snap['host_fallbacks']:>9} "
                  f"{snap['bytes_streamed'] / 1e6:>10.2f} "
                  f"{snap['spill_bytes'] / 1e6:>9.2f} "
                  f"{snap['busy_s'] * 1e3:>8.2f}")
        print(f"{'host':<9} {fleet.host_produces:>7} {'-':>6} {'-':>9} "
              f"{'-':>9} {fleet.host_link_bytes / 1e6:>10.2f} {'-':>9} "
              f"{fleet.host_busy_s * 1e3:>8.2f}")
        if args.skew > 0:
            total_fallbacks = sum(d.host_fallbacks for d in fleet)
            print(f"skew={args.skew}: {total_fallbacks} claim(s) fell back "
                  f"to the host path")
    if cache is not None:
        cs = cache.stats()
        print(f"cache: hits={cs.hits} follows={cs.follows} misses={cs.misses} "
              f"hit_rate={cs.hit_rate:.2f} entries={cs.entries} "
              f"resident={cs.resident_bytes / 1e6:.1f}MB "
              f"spilled={cs.spilled_entries} ({cs.spilled_bytes / 1e6:.1f}MB, "
              f"{cs.spill_io_s * 1e3:.2f}ms modeled I/O)")


if __name__ == "__main__":
    main()
