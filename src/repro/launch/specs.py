"""Per-(arch x shape) lowering specs: function + ShapeDtypeStruct inputs +
explicit shardings.  This is what both the dry-run and the roofline read.

`input_specs()` follows the assignment contract: weak-type-correct,
shardable, zero device allocation.  Modality frontends are stubs — the VLM
cell receives precomputed patch embeddings, the audio cell precomputed frame
embeddings.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ShardingRules
from repro.models import encdec
from repro.models import transformer as tfm
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.train import optimizer as opt_lib
from repro.train.step import make_train_step, opt_state_pspecs


@dataclasses.dataclass
class LoweringSpec:
    name: str
    fn: Callable
    args: tuple  # pytrees of ShapeDtypeStruct
    in_shardings: Any
    rules: ShardingRules


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def shape_rules(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh]) -> ShardingRules:
    overrides = dict(cfg.sharding_overrides)
    if shape.kind == "decode" and shape.shard_kv_seq:
        overrides.update({"batch": None, "kv_seq": "data"})
    elif shape.kind == "decode":
        # GQA kv-head counts (8) don't divide the 16-way model axis, so KV
        # caches cannot head-shard; shard the cache SEQUENCE over 'model'
        # instead (flash-decoding style: XLA reduces the partial softmax
        # across shards).  Without this, a 32k cache replicates over the TP
        # axis and decode states don't fit HBM (e.g. gemma3: 96 GiB/dev).
        overrides.setdefault("kv_seq", "model")
    return ShardingRules.make(mesh, overrides)


def make_optimizer_for(cfg: ModelConfig):
    lr = opt_lib.warmup_cosine(3e-4, 100, 10_000)
    return opt_lib.make_optimizer(cfg.optimizer, lr)


def _model_module(cfg: ModelConfig):
    return encdec if cfg.is_encdec else tfm


def params_struct_and_specs(cfg: ModelConfig, rules: ShardingRules):
    mod = _model_module(cfg)
    struct = jax.eval_shape(lambda r: mod.init_params(r, cfg), jax.random.PRNGKey(0))
    pspecs = mod.param_pspecs(cfg, rules)
    return struct, pspecs


def _shard(mesh, spec_tree):
    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# input_specs per family x shape


def train_batch_struct(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.is_encdec:
        return {
            "frames": _sds((b, s, cfg.d_model), dt),
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
            "mask": _sds((b, s), jnp.float32),
        }
    if cfg.family == "vlm" and cfg.frontend_positions:
        p = cfg.frontend_positions
        return {
            "tokens": _sds((b, s - p), jnp.int32),
            "labels": _sds((b, s - p), jnp.int32),
            "mask": _sds((b, s - p), jnp.float32),
            "prefix_embeds": _sds((b, p, cfg.d_model), dt),
        }
    return {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
        "mask": _sds((b, s), jnp.float32),
    }


def train_batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules):
    batch = rules.pspec("batch")
    b2 = P(*(list(batch) + [None]))
    b3 = P(*(list(batch) + [None, None]))
    if cfg.is_encdec:
        return {"frames": b3, "tokens": b2, "labels": b2, "mask": b2}
    if cfg.family == "vlm" and cfg.frontend_positions:
        return {"tokens": b2, "labels": b2, "mask": b2, "prefix_embeds": b3}
    return {"tokens": b2, "labels": b2, "mask": b2}


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Public helper: ShapeDtypeStruct stand-ins for every model input."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_batch_struct(cfg, shape)
    mod = _model_module(cfg)
    if shape.kind == "prefill":
        if cfg.is_encdec:
            return {"frames": _sds((shape.global_batch, shape.seq_len, cfg.d_model),
                                   jnp.dtype(cfg.dtype))}
        return {"tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32)}
    return {
        "token": _sds((shape.global_batch, 1), jnp.int32),
        "caches": mod.cache_spec(cfg, shape.global_batch, shape.seq_len),
        "cache_len": _sds((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Lowering builders


def auto_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Pick grad-accumulation depth so one microbatch's activations fit HBM.

    Napkin math: the layer-scan saves the residual carry (B_dev, S, d_model)
    per layer for backward (~2 copies with remat boundaries), so activation
    HBM ~ 4·B_dev·S·d_model·n_layers bytes.  Targeting <=4 GiB of carries
    gives per-device microbatch tokens <= 8-16k for the assigned configs —
    the same operating point production frameworks use."""
    if cfg.microbatches:
        return cfg.microbatches
    if mesh is None:
        return 1
    batch_shards = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            batch_shards *= mesh.shape[ax]
    per_dev_batch = max(shape.global_batch // batch_shards, 1)
    carry_bytes_per_tok = 4.0 * cfg.d_model * max(cfg.n_layers, 1)
    budget = 4 * 2**30
    target_tokens = max(int(budget / carry_bytes_per_tok), 1024)
    k = 1
    while (
        per_dev_batch * shape.seq_len / k > target_tokens
        and k < per_dev_batch
        and shape.global_batch % (k * 2) == 0
    ):
        k *= 2
    return k


def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh) -> LoweringSpec:
    rules = shape_rules(cfg, shape, mesh)
    mod = _model_module(cfg)
    optimizer = make_optimizer_for(cfg)
    loss_fn = lambda p, b: mod.loss_fn(p, b, cfg, rules)
    step = make_train_step(
        loss_fn, optimizer, microbatches=auto_microbatches(cfg, shape, mesh)
    )

    pstruct, pspecs = params_struct_and_specs(cfg, rules)
    ostate = jax.eval_shape(optimizer.init, pstruct)
    ospecs = opt_state_pspecs(optimizer, pstruct, pspecs)
    state_struct = {"params": pstruct, "opt": ostate, "step": _sds((), jnp.int32)}
    state_specs = {"params": pspecs, "opt": ospecs, "step": P()}
    batch_struct = train_batch_struct(cfg, shape)
    batch_specs = train_batch_pspecs(cfg, shape, rules)
    return LoweringSpec(
        name=f"{cfg.name}:{shape.name}",
        fn=step,
        args=(state_struct, batch_struct),
        in_shardings=(_shard(mesh, state_specs), _shard(mesh, batch_specs)),
        rules=rules,
    )


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh) -> LoweringSpec:
    rules = shape_rules(cfg, shape, mesh)
    mod = _model_module(cfg)
    pstruct, pspecs = params_struct_and_specs(cfg, rules)
    b, s = shape.global_batch, shape.seq_len
    batch = rules.pspec("batch")
    if cfg.is_encdec:
        # prefill for enc-dec = encode the source (cross-KV derive happens in
        # the decode cell; encoding dominates prefill cost)
        fn = lambda params, frames: encdec.encode(params, frames, cfg, rules)
        args = (pstruct, _sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype)))
        in_sh = (_shard(mesh, pspecs), _shard(mesh, P(*(list(batch) + [None, None]))))
    else:
        fn = lambda params, tokens: tfm.prefill(params, tokens, cfg, rules, s)
        args = (pstruct, _sds((b, s), jnp.int32))
        in_sh = (_shard(mesh, pspecs), _shard(mesh, P(*(list(batch) + [None]))))
    return LoweringSpec(f"{cfg.name}:{shape.name}", fn, args, in_sh, rules)


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh) -> LoweringSpec:
    rules = shape_rules(cfg, shape, mesh)
    mod = _model_module(cfg)
    pstruct, pspecs = params_struct_and_specs(cfg, rules)
    b, s = shape.global_batch, shape.seq_len
    cache_struct = mod.cache_spec(cfg, b, s)
    cache_specs = mod.cache_pspecs(cfg, rules)
    batch = rules.pspec("batch")

    if cfg.is_encdec:
        fn = lambda params, token, caches, n: encdec.decode_step(
            params, token, caches, n, cfg, rules,
            mesh=mesh, shard_kv_seq=shape.shard_kv_seq,
        )
    else:
        fn = lambda params, token, caches, n: tfm.decode_step(
            params, token, caches, n, cfg, rules,
            mesh=mesh, shard_kv_seq=shape.shard_kv_seq,
        )
    args = (pstruct, _sds((b, 1), jnp.int32), cache_struct, _sds((), jnp.int32))
    in_sh = (
        _shard(mesh, pspecs),
        _shard(mesh, P(*(list(batch) + [None]))),
        _shard(mesh, cache_specs),
        _shard(mesh, P()),
    )
    return LoweringSpec(f"{cfg.name}:{shape.name}", fn, args, in_sh, rules)


def build_cell(cfg: ModelConfig, shape_name: str, mesh) -> LoweringSpec:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh)
    return build_decode_cell(cfg, shape, mesh)
