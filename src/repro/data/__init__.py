from repro.data.encoding import (
    bitpack,
    bitunpack,
    bytesplit_encode,
    bytesplit_decode,
    dict_encode,
    dict_decode,
    pack_words_needed,
)
from repro.data.columnar import (
    ColumnSchema,
    EncodedColumn,
    Partition,
    PartitionSchema,
    decode_partition_numpy,
    encode_partition,
    inflate_partition,
    partition_refs,
)
from repro.data.synth import RawBatch, SyntheticRecSysSource, make_rm_source
from repro.data.storage import (
    CacheSpillStore,
    DeviceFleet,
    IspDevice,
    PartitionedStore,
    zipf_owner_map,
)
from repro.data.loader import PrefetchLoader, SessionQueue, WorkQueue
from repro.data.tokens import TokenSynthesizer, lm_input_batch

__all__ = [
    "CacheSpillStore",
    "ColumnSchema",
    "DeviceFleet",
    "EncodedColumn",
    "IspDevice",
    "Partition",
    "PartitionSchema",
    "PartitionedStore",
    "PrefetchLoader",
    "RawBatch",
    "SessionQueue",
    "SyntheticRecSysSource",
    "TokenSynthesizer",
    "WorkQueue",
    "zipf_owner_map",
    "bitpack",
    "bitunpack",
    "bytesplit_decode",
    "bytesplit_encode",
    "decode_partition_numpy",
    "dict_decode",
    "dict_encode",
    "encode_partition",
    "inflate_partition",
    "lm_input_batch",
    "make_rm_source",
    "pack_words_needed",
    "partition_refs",
]
