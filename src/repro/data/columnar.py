"""Partitioned columnar file format (Parquet-lite) for raw RecSys features.

A *partition* is a self-contained group of rows (one training mini-batch in
the paper: 8,192 rows).  Partitions are mutually independent — the property
PreSto exploits: all transforms for a mini-batch touch exactly one partition,
so preprocessing can run wherever that partition lives with zero cross-shard
communication.

On-disk layout (one file per partition):
    [8B magic 'RPRESTO1'][4B header_len][header JSON][page words...]
Each column's pages are contiguous uint32 word arrays whose sizes are fully
determined by the dataset-level schema, so a partition can be decoded by a
single pre-compiled XLA program.

Column kinds
------------
dense : float32 per row.  encodings: 'plain' | 'bytesplit'
sparse: variable-length list of int32 ids per row, stored ragged:
        lengths  bitpacked at `len_width` bits   (per-row list lengths)
        values   bitpacked at `id_width` bits or dictionary-encoded
refs  : per-sample unique-block references (dedup form only, see below)

Sample-level dedup (RecD)
-------------------------
Production RecSys datasets repeat the same sparse-feature block across many
samples of a session (RecD; Meta's ingestion characterization).  A schema
with ``dup_factor = d > 1`` stores each partition in *dedup form*: every
sparse column's lengths/values pages are encoded at ``unique_rows = rows/d``
geometry (one copy per block), and one partition-wide ``__refs__`` page maps
each of the ``rows`` logical samples to its unique block.  Dense columns and
labels stay per-sample.  ``dup_factor`` is a DATASET-level constant, so page
sizes remain fully determined by the schema and one compiled program still
decodes every partition.  ``dup_factor == 1`` is bit-for-bit the classic
layout (no refs page).
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import struct
from typing import Dict, List, Mapping

import numpy as np

from repro.data import encoding as enc

_MAGIC = b"RPRESTO1"

# partition-wide pseudo-column holding the per-sample block references of a
# dedup-form partition (kind "refs"; exactly one per schema when dup_factor>1)
REFS_COLUMN = "__refs__"


def refs_column() -> "ColumnSchema":
    return ColumnSchema(REFS_COLUMN, "refs", "plain")


@dataclasses.dataclass(frozen=True)
class ColumnSchema:
    name: str
    kind: str  # 'dense' | 'sparse'
    encoding: str  # dense: 'plain'|'bytesplit'; sparse: 'bitpack'|'dict'
    # sparse-only static parameters (dataset-level, fixed across partitions):
    max_len: int = 1  # padded list length after decode
    id_width: int = 32  # bit width of raw ids ('bitpack')
    len_width: int = 8  # bit width of per-row lengths
    dict_size: int = 0  # >0 for 'dict' encoding (fixed dictionary capacity)

    @property
    def code_width(self) -> int:
        return enc.width_for(max(self.dict_size - 1, 1))


@dataclasses.dataclass(frozen=True)
class PartitionSchema:
    """Dataset-level schema: identical for every partition of a dataset."""

    rows: int
    columns: tuple[ColumnSchema, ...]
    # sample-level dedup: every ``dup_factor`` consecutive rows of a session
    # share ONE stored sparse-feature block.  1 = classic per-sample layout.
    dup_factor: int = 1

    def __post_init__(self):
        assert self.dup_factor >= 1, self.dup_factor
        if self.dup_factor > 1:
            assert self.rows % self.dup_factor == 0, (
                f"rows={self.rows} not divisible by dup_factor={self.dup_factor}"
            )
            assert any(c.kind == "refs" for c in self.columns), (
                "dedup schema (dup_factor > 1) needs a refs column "
                "(columnar.refs_column())"
            )

    @property
    def unique_rows(self) -> int:
        """Stored sparse-block count per partition (== rows when dup 1)."""
        return self.rows // self.dup_factor

    def dense_columns(self) -> List[ColumnSchema]:
        return [c for c in self.columns if c.kind == "dense"]

    def sparse_columns(self) -> List[ColumnSchema]:
        return [c for c in self.columns if c.kind == "sparse"]

    def page_sizes(self, col: ColumnSchema) -> Dict[str, int]:
        """Word counts of each page of `col` — static given the schema."""
        r = self.rows
        if col.kind == "dense":
            return {"data": r}  # 1 word per float (plain and bytesplit alike)
        if col.kind == "refs":
            return {"refs": r}  # 1 uint32 block index per logical sample
        u = self.unique_rows  # sparse pages live at unique-block geometry
        total_vals = u * col.max_len  # ragged values stored padded-capacity
        sizes = {"lengths": enc.pack_words_needed(u, col.len_width)}
        if col.encoding == "dict":
            sizes["dict"] = col.dict_size
            sizes["values"] = enc.pack_words_needed(total_vals, col.code_width)
        else:
            sizes["values"] = enc.pack_words_needed(total_vals, col.id_width)
        return sizes

    def encoded_words(self) -> int:
        return sum(sum(self.page_sizes(c).values()) for c in self.columns)

    def logical_schema(self) -> "PartitionSchema":
        """The undeduped (dup_factor 1, no refs column) view of this schema —
        the layout the same logical rows would occupy without dedup."""
        if self.dup_factor == 1:
            return self
        return PartitionSchema(
            rows=self.rows,
            columns=tuple(c for c in self.columns if c.kind != "refs"),
            dup_factor=1,
        )

    def to_json(self) -> str:
        d = {
            "rows": self.rows,
            "columns": [dataclasses.asdict(c) for c in self.columns],
        }
        if self.dup_factor != 1:  # dup-1 headers stay byte-identical to old
            d["dup_factor"] = self.dup_factor
        return json.dumps(d)

    @staticmethod
    def from_json(s: str) -> "PartitionSchema":
        d = json.loads(s)
        return PartitionSchema(
            rows=d["rows"],
            columns=tuple(ColumnSchema(**c) for c in d["columns"]),
            dup_factor=d.get("dup_factor", 1),
        )


@dataclasses.dataclass
class EncodedColumn:
    schema: ColumnSchema
    pages: Dict[str, np.ndarray]  # page name -> uint32 words


@dataclasses.dataclass
class Partition:
    """One encoded partition: the unit of in-storage preprocessing."""

    partition_id: int
    schema: PartitionSchema
    columns: Dict[str, EncodedColumn]

    def nbytes(self) -> int:
        """Actual stored bytes — UNIQUE block bytes for a dedup partition.

        This is what every ledger charges (``PartitionedStore.read`` streams
        exactly these bytes off the owning device); compare against
        ``logical_nbytes()`` for the dedup saving."""
        return sum(
            int(p.nbytes) for c in self.columns.values() for p in c.pages.values()
        )

    def logical_nbytes(self) -> int:
        """Bytes the same logical rows would occupy undeduped (dup_factor 1).
        Equal to ``nbytes()`` for classic partitions."""
        if self.schema.dup_factor == 1:
            return self.nbytes()
        return self.schema.logical_schema().encoded_words() * 4

    def page_arrays(self) -> Dict[str, np.ndarray]:
        """Flat dict 'col/page' -> words, the kernel-side input layout."""
        out = {}
        for cname, col in self.columns.items():
            for pname, words in col.pages.items():
                out[f"{cname}/{pname}"] = words
        return out


def encode_partition(
    partition_id: int,
    schema: PartitionSchema,
    dense: Mapping[str, np.ndarray],
    sparse_values: Mapping[str, np.ndarray],
    sparse_lengths: Mapping[str, np.ndarray],
    sparse_refs: np.ndarray | None = None,
) -> Partition:
    """Encode raw host arrays into a Partition.

    dense[name]         : (rows,) float
    sparse_values[name] : (rows, max_len) int — entries beyond length are 0
    sparse_lengths[name]: (rows,) int, each <= max_len
    sparse_refs         : (rows,) int in [0, unique_rows) — dedup schemas
                          only; row r's sparse block is unique block refs[r].
                          Defaults to contiguous sessions (r // dup_factor).
                          Every block must be referenced, and all rows of a
                          block must carry IDENTICAL sparse values/lengths
                          (asserted: dedup is lossless by construction).
    """
    d = schema.dup_factor
    first_rows = None  # logical row defining each unique block, dedup only
    if d > 1:
        if sparse_refs is None:
            sparse_refs = np.arange(schema.rows, dtype=np.int64) // d
        refs = np.asarray(sparse_refs, dtype=np.int64)
        u = schema.unique_rows
        assert refs.shape == (schema.rows,), refs.shape
        assert refs.min(initial=0) >= 0 and refs.max(initial=0) < u
        # first occurrence of each block defines its stored content
        first_rows = np.full(u, -1, dtype=np.int64)
        rev = np.arange(schema.rows - 1, -1, -1)
        first_rows[refs[rev]] = rev  # walk reversed: lowest row index wins
        assert (first_rows >= 0).all(), "unreferenced unique block(s)"
    else:
        assert sparse_refs is None or np.array_equal(
            np.asarray(sparse_refs), np.arange(schema.rows)
        ), "sparse_refs is meaningless on a dup_factor-1 schema"
    cols: Dict[str, EncodedColumn] = {}
    for cs in schema.columns:
        if cs.kind == "refs":
            cols[cs.name] = EncodedColumn(
                cs, {"refs": refs.astype(np.uint32)}
            )
        elif cs.kind == "dense":
            v = np.asarray(dense[cs.name], dtype=np.float32)
            assert v.shape == (schema.rows,), (cs.name, v.shape)
            if cs.encoding == "bytesplit":
                words, _ = enc.bytesplit_encode(v)
            else:
                words = enc.plain_f32_encode(v)
            cols[cs.name] = EncodedColumn(cs, {"data": words})
        else:
            vals = np.asarray(sparse_values[cs.name], dtype=np.int64)
            lens = np.asarray(sparse_lengths[cs.name], dtype=np.int64)
            assert vals.shape == (schema.rows, cs.max_len), (cs.name, vals.shape)
            assert lens.max(initial=0) <= cs.max_len
            if first_rows is not None:
                # dedup: store one copy per unique block, losslessly —
                # every row must equal its block's defining row
                assert np.array_equal(vals, vals[first_rows][refs]) and (
                    np.array_equal(lens, lens[first_rows][refs])
                ), f"{cs.name}: rows referencing one block differ in content"
                vals, lens = vals[first_rows], lens[first_rows]
            flat = vals.reshape(-1)
            pages = {"lengths": enc.bitpack(lens, cs.len_width)}
            if cs.encoding == "dict":
                # fixed-capacity dictionary: ids are already < dict_size by
                # construction (dataset-level id space); dictionary is the
                # identity-ish mapping table generated at dataset build time.
                dictionary = np.arange(cs.dict_size, dtype=np.int32)
                pages["dict"] = dictionary.view(np.uint32)
                pages["values"] = enc.bitpack(flat, cs.code_width)
            else:
                pages["values"] = enc.bitpack(flat, cs.id_width)
            cols[cs.name] = EncodedColumn(cs, pages)
    return Partition(partition_id, schema, cols)


def decode_partition_numpy(part: Partition) -> dict:
    """Numpy decode oracle: Partition -> raw feature arrays.

    Returns {'dense': {name: (rows,) f32},
             'sparse_values': {name: (rows, max_len) i32},
             'sparse_lengths': {name: (rows,) i32}}
    (+ 'sparse_refs': (rows,) i64 for dedup partitions)

    Dedup partitions decode their unique blocks once and expand through the
    refs page, so the returned LOGICAL arrays are bitwise identical to
    decoding the same rows from an undeduped partition.
    """
    schema = part.schema
    out = {"dense": {}, "sparse_values": {}, "sparse_lengths": {}}
    refs = partition_refs(part)
    if schema.dup_factor > 1:
        out["sparse_refs"] = refs
    u = schema.unique_rows
    for cs in schema.columns:
        if cs.kind == "refs":
            continue
        col = part.columns[cs.name]
        if cs.kind == "dense":
            if cs.encoding == "bytesplit":
                out["dense"][cs.name] = enc.bytesplit_decode(
                    col.pages["data"], schema.rows
                )
            else:
                out["dense"][cs.name] = enc.plain_f32_decode(
                    col.pages["data"], schema.rows
                )
        else:
            total = u * cs.max_len
            lens = enc.bitunpack(col.pages["lengths"], u, cs.len_width)
            if cs.encoding == "dict":
                dictionary = col.pages["dict"].view(np.int32)
                vals = enc.dict_decode(
                    dictionary, col.pages["values"], total, cs.code_width
                )
            else:
                vals = enc.bitunpack(col.pages["values"], total, cs.id_width).astype(
                    np.int32
                )
            vals = vals.reshape(u, cs.max_len)
            lens = lens.astype(np.int32)
            if refs is not None:
                vals, lens = vals[refs], lens[refs]  # expand to logical rows
            out["sparse_values"][cs.name] = vals
            out["sparse_lengths"][cs.name] = lens
    return out


def partition_refs(part: Partition) -> np.ndarray | None:
    """The (rows,) block-reference vector of a dedup partition, else None."""
    if part.schema.dup_factor == 1:
        return None
    return part.columns[REFS_COLUMN].pages["refs"].astype(np.int64)


def inflate_partition(part: Partition) -> Partition:
    """Dedup form -> classic per-sample layout, bitwise faithful.

    Decodes the unique sparse blocks, expands them through the refs page and
    re-encodes at logical geometry under ``schema.logical_schema()`` — the
    partition an undeduped source would have produced for the same rows
    (bitpack(bitunpack(x)) is exact for in-width values).  Dense pages are
    reused as-is.  The compatibility path for consumers that need the
    per-sample layout (e.g. mesh-sharded staging)."""
    schema = part.schema
    if schema.dup_factor == 1:
        return part
    dec = decode_partition_numpy(part)
    logical = schema.logical_schema()
    cols: Dict[str, EncodedColumn] = {}
    for cs in logical.columns:
        if cs.kind == "dense":
            cols[cs.name] = EncodedColumn(cs, dict(part.columns[cs.name].pages))
        else:
            lens = dec["sparse_lengths"][cs.name].astype(np.int64)
            flat = dec["sparse_values"][cs.name].astype(np.int64).reshape(-1)
            pages = {"lengths": enc.bitpack(lens, cs.len_width)}
            if cs.encoding == "dict":
                pages["dict"] = np.arange(cs.dict_size, dtype=np.int32).view(
                    np.uint32
                )
                pages["values"] = enc.bitpack(flat, cs.code_width)
            else:
                pages["values"] = enc.bitpack(flat, cs.id_width)
            cols[cs.name] = EncodedColumn(cs, pages)
    return Partition(part.partition_id, logical, cols)


def block_fingerprints(part: Partition) -> List[str] | None:
    """Content digest of each unique sparse block (dedup partitions only).

    Block b's digest covers every sparse column's decoded values + length for
    that block, so two blocks hash alike iff their decoded content is equal —
    across partitions, datasets and tenants.  These are the block-granularity
    components of feature-cache keys (``core.featcache.BlockKey``)."""
    schema = part.schema
    if schema.dup_factor == 1:
        return None
    u = schema.unique_rows
    payload = []  # per sparse column: (u, max_len) vals and (u,) lens
    for cs in schema.sparse_columns():
        col = part.columns[cs.name]
        total = u * cs.max_len
        if cs.encoding == "dict":
            vals = enc.dict_decode(
                col.pages["dict"].view(np.int32), col.pages["values"], total,
                cs.code_width,
            ).astype(np.int32)
        else:
            vals = enc.bitunpack(col.pages["values"], total, cs.id_width).astype(
                np.int32
            )
        payload.append(vals.reshape(u, cs.max_len))
        payload.append(
            enc.bitunpack(col.pages["lengths"], u, cs.len_width)
            .astype(np.int32).reshape(u, 1)
        )
    stacked = np.ascontiguousarray(np.concatenate(payload, axis=1))
    return [
        hashlib.sha256(stacked[b].tobytes()).hexdigest()[:16] for b in range(u)
    ]


def partition_digest(part: Partition) -> str:
    """Content digest of one partition's DECODED page words.

    Unlike ``PartitionedStore.partition_fingerprint`` (which hashes file
    bytes or source identity — a cache *key*), this hashes the in-memory
    page arrays themselves in a canonical order, so it can compare a
    just-read partition against a trusted reference regardless of where the
    bytes came from (file, source, or a torn read).  Equal digest ⇔ equal
    page words ⇔ bitwise-equal decoded batch.  This is the end-to-end
    integrity check the storage fault domain verifies reads against."""
    h = hashlib.sha256()
    h.update(part.schema.to_json().encode())
    for cname in sorted(part.columns):
        col = part.columns[cname]
        for pname in sorted(col.pages):
            words = np.ascontiguousarray(col.pages[pname], dtype=np.uint32)
            h.update(f"{cname}/{pname}/{words.shape[0]}".encode())
            h.update(words.tobytes())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# File round-trip


class CorruptPartitionFile(ValueError):
    """A partition file failed structural validation or checksum on decode.

    Raised instead of silently mis-decoding: a truncated payload, a torn
    header, a wrong magic, or a checksum mismatch all land here, so callers
    (and the fault-injection retry path) can treat the read as failed rather
    than serve short/garbage arrays."""


def write_partition(path: str, part: Partition) -> None:
    header = {
        "partition_id": part.partition_id,
        "schema": json.loads(part.schema.to_json()),
        "pages": [],
    }
    payload = io.BytesIO()
    for cname, col in part.columns.items():
        for pname, words in col.pages.items():
            header["pages"].append(
                {"column": cname, "page": pname, "words": int(words.shape[0])}
            )
            payload.write(np.ascontiguousarray(words, dtype=np.uint32).tobytes())
    body = payload.getvalue()
    # write-time payload checksum: read_partition verifies it when present,
    # so a bit-flipped or truncated page is detected, never mis-decoded.
    # Older files without the field still load (verification is opt-in by
    # the file, not the reader).
    header["checksum"] = hashlib.sha256(body).hexdigest()[:16]
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(_MAGIC)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        f.write(body)


def read_partition(path: str) -> Partition:
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != _MAGIC:
            raise CorruptPartitionFile(
                f"{path}: bad magic {magic!r} (want {_MAGIC!r})"
            )
        raw_hlen = f.read(4)
        if len(raw_hlen) != 4:
            raise CorruptPartitionFile(f"{path}: truncated before header length")
        (hlen,) = struct.unpack("<I", raw_hlen)
        raw_header = f.read(hlen)
        if len(raw_header) != hlen:
            raise CorruptPartitionFile(
                f"{path}: truncated header ({len(raw_header)} of {hlen} bytes)"
            )
        try:
            header = json.loads(raw_header)
            schema = PartitionSchema.from_json(json.dumps(header["schema"]))
            pages = header["pages"]
            partition_id = header["partition_id"]
        except (ValueError, KeyError, TypeError, AssertionError) as e:
            raise CorruptPartitionFile(f"{path}: corrupt header: {e}") from e
        body = f.read()
    want_ck = header.get("checksum")
    if want_ck is not None:
        got_ck = hashlib.sha256(body).hexdigest()[:16]
        if got_ck != want_ck:
            raise CorruptPartitionFile(
                f"{path}: payload checksum mismatch "
                f"(stored {want_ck}, computed {got_ck})"
            )
    cols: Dict[str, EncodedColumn] = {}
    cschemas = {c.name: c for c in schema.columns}
    off = 0
    for pmeta in pages:
        try:
            nwords = int(pmeta["words"])
            cname = pmeta["column"]
            pname = pmeta["page"]
            cs = cschemas[cname]
        except (KeyError, TypeError, ValueError) as e:
            raise CorruptPartitionFile(f"{path}: corrupt page table: {e}") from e
        end = off + nwords * 4
        if nwords < 0 or end > len(body):
            raise CorruptPartitionFile(
                f"{path}: truncated payload (page {cname}/{pname} wants "
                f"bytes [{off}, {end}) of {len(body)})"
            )
        words = np.frombuffer(body, dtype=np.uint32, count=nwords, offset=off)
        off = end
        if cname not in cols:
            cols[cname] = EncodedColumn(cs, {})
        cols[cname].pages[pname] = words
    return Partition(partition_id, schema, cols)
