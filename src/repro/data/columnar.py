"""Partitioned columnar file format (Parquet-lite) for raw RecSys features.

A *partition* is a self-contained group of rows (one training mini-batch in
the paper: 8,192 rows).  Partitions are mutually independent — the property
PreSto exploits: all transforms for a mini-batch touch exactly one partition,
so preprocessing can run wherever that partition lives with zero cross-shard
communication.

On-disk layout (one file per partition):
    [8B magic 'RPRESTO1'][4B header_len][header JSON][page words...]
Each column's pages are contiguous uint32 word arrays whose sizes are fully
determined by the dataset-level schema, so a partition can be decoded by a
single pre-compiled XLA program.

Column kinds
------------
dense : float32 per row.  encodings: 'plain' | 'bytesplit'
sparse: variable-length list of int32 ids per row, stored ragged:
        lengths  bitpacked at `len_width` bits   (per-row list lengths)
        values   bitpacked at `id_width` bits or dictionary-encoded
"""

from __future__ import annotations

import dataclasses
import io
import json
import struct
from typing import Dict, List, Mapping

import numpy as np

from repro.data import encoding as enc

_MAGIC = b"RPRESTO1"


@dataclasses.dataclass(frozen=True)
class ColumnSchema:
    name: str
    kind: str  # 'dense' | 'sparse'
    encoding: str  # dense: 'plain'|'bytesplit'; sparse: 'bitpack'|'dict'
    # sparse-only static parameters (dataset-level, fixed across partitions):
    max_len: int = 1  # padded list length after decode
    id_width: int = 32  # bit width of raw ids ('bitpack')
    len_width: int = 8  # bit width of per-row lengths
    dict_size: int = 0  # >0 for 'dict' encoding (fixed dictionary capacity)

    @property
    def code_width(self) -> int:
        return enc.width_for(max(self.dict_size - 1, 1))


@dataclasses.dataclass(frozen=True)
class PartitionSchema:
    """Dataset-level schema: identical for every partition of a dataset."""

    rows: int
    columns: tuple[ColumnSchema, ...]

    def dense_columns(self) -> List[ColumnSchema]:
        return [c for c in self.columns if c.kind == "dense"]

    def sparse_columns(self) -> List[ColumnSchema]:
        return [c for c in self.columns if c.kind == "sparse"]

    def page_sizes(self, col: ColumnSchema) -> Dict[str, int]:
        """Word counts of each page of `col` — static given the schema."""
        r = self.rows
        if col.kind == "dense":
            return {"data": r}  # 1 word per float (plain and bytesplit alike)
        total_vals = r * col.max_len  # ragged values stored padded-capacity
        sizes = {"lengths": enc.pack_words_needed(r, col.len_width)}
        if col.encoding == "dict":
            sizes["dict"] = col.dict_size
            sizes["values"] = enc.pack_words_needed(total_vals, col.code_width)
        else:
            sizes["values"] = enc.pack_words_needed(total_vals, col.id_width)
        return sizes

    def encoded_words(self) -> int:
        return sum(sum(self.page_sizes(c).values()) for c in self.columns)

    def to_json(self) -> str:
        return json.dumps(
            {
                "rows": self.rows,
                "columns": [dataclasses.asdict(c) for c in self.columns],
            }
        )

    @staticmethod
    def from_json(s: str) -> "PartitionSchema":
        d = json.loads(s)
        return PartitionSchema(
            rows=d["rows"],
            columns=tuple(ColumnSchema(**c) for c in d["columns"]),
        )


@dataclasses.dataclass
class EncodedColumn:
    schema: ColumnSchema
    pages: Dict[str, np.ndarray]  # page name -> uint32 words


@dataclasses.dataclass
class Partition:
    """One encoded partition: the unit of in-storage preprocessing."""

    partition_id: int
    schema: PartitionSchema
    columns: Dict[str, EncodedColumn]

    def nbytes(self) -> int:
        return sum(
            int(p.nbytes) for c in self.columns.values() for p in c.pages.values()
        )

    def page_arrays(self) -> Dict[str, np.ndarray]:
        """Flat dict 'col/page' -> words, the kernel-side input layout."""
        out = {}
        for cname, col in self.columns.items():
            for pname, words in col.pages.items():
                out[f"{cname}/{pname}"] = words
        return out


def encode_partition(
    partition_id: int,
    schema: PartitionSchema,
    dense: Mapping[str, np.ndarray],
    sparse_values: Mapping[str, np.ndarray],
    sparse_lengths: Mapping[str, np.ndarray],
) -> Partition:
    """Encode raw host arrays into a Partition.

    dense[name]         : (rows,) float
    sparse_values[name] : (rows, max_len) int — entries beyond length are 0
    sparse_lengths[name]: (rows,) int, each <= max_len
    """
    cols: Dict[str, EncodedColumn] = {}
    for cs in schema.columns:
        if cs.kind == "dense":
            v = np.asarray(dense[cs.name], dtype=np.float32)
            assert v.shape == (schema.rows,), (cs.name, v.shape)
            if cs.encoding == "bytesplit":
                words, _ = enc.bytesplit_encode(v)
            else:
                words = enc.plain_f32_encode(v)
            cols[cs.name] = EncodedColumn(cs, {"data": words})
        else:
            vals = np.asarray(sparse_values[cs.name], dtype=np.int64)
            lens = np.asarray(sparse_lengths[cs.name], dtype=np.int64)
            assert vals.shape == (schema.rows, cs.max_len), (cs.name, vals.shape)
            assert lens.max(initial=0) <= cs.max_len
            flat = vals.reshape(-1)
            pages = {"lengths": enc.bitpack(lens, cs.len_width)}
            if cs.encoding == "dict":
                # fixed-capacity dictionary: ids are already < dict_size by
                # construction (dataset-level id space); dictionary is the
                # identity-ish mapping table generated at dataset build time.
                dictionary = np.arange(cs.dict_size, dtype=np.int32)
                pages["dict"] = dictionary.view(np.uint32)
                pages["values"] = enc.bitpack(flat, cs.code_width)
            else:
                pages["values"] = enc.bitpack(flat, cs.id_width)
            cols[cs.name] = EncodedColumn(cs, pages)
    return Partition(partition_id, schema, cols)


def decode_partition_numpy(part: Partition) -> dict:
    """Numpy decode oracle: Partition -> raw feature arrays.

    Returns {'dense': {name: (rows,) f32},
             'sparse_values': {name: (rows, max_len) i32},
             'sparse_lengths': {name: (rows,) i32}}
    """
    schema = part.schema
    out = {"dense": {}, "sparse_values": {}, "sparse_lengths": {}}
    for cs in schema.columns:
        col = part.columns[cs.name]
        if cs.kind == "dense":
            if cs.encoding == "bytesplit":
                out["dense"][cs.name] = enc.bytesplit_decode(
                    col.pages["data"], schema.rows
                )
            else:
                out["dense"][cs.name] = enc.plain_f32_decode(
                    col.pages["data"], schema.rows
                )
        else:
            total = schema.rows * cs.max_len
            lens = enc.bitunpack(col.pages["lengths"], schema.rows, cs.len_width)
            if cs.encoding == "dict":
                dictionary = col.pages["dict"].view(np.int32)
                vals = enc.dict_decode(
                    dictionary, col.pages["values"], total, cs.code_width
                )
            else:
                vals = enc.bitunpack(col.pages["values"], total, cs.id_width).astype(
                    np.int32
                )
            out["sparse_values"][cs.name] = vals.reshape(schema.rows, cs.max_len)
            out["sparse_lengths"][cs.name] = lens.astype(np.int32)
    return out


# ---------------------------------------------------------------------------
# File round-trip


def write_partition(path: str, part: Partition) -> None:
    header = {
        "partition_id": part.partition_id,
        "schema": json.loads(part.schema.to_json()),
        "pages": [],
    }
    payload = io.BytesIO()
    for cname, col in part.columns.items():
        for pname, words in col.pages.items():
            header["pages"].append(
                {"column": cname, "page": pname, "words": int(words.shape[0])}
            )
            payload.write(np.ascontiguousarray(words, dtype=np.uint32).tobytes())
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(_MAGIC)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        f.write(payload.getvalue())


def read_partition(path: str) -> Partition:
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == _MAGIC, f"bad magic in {path}"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        schema = PartitionSchema.from_json(json.dumps(header["schema"]))
        cols: Dict[str, EncodedColumn] = {}
        cschemas = {c.name: c for c in schema.columns}
        for pmeta in header["pages"]:
            words = np.frombuffer(f.read(pmeta["words"] * 4), dtype=np.uint32)
            cname = pmeta["column"]
            if cname not in cols:
                cols[cname] = EncodedColumn(cschemas[cname], {})
            cols[cname].pages[pmeta["page"]] = words
    return Partition(header["partition_id"], schema, cols)
