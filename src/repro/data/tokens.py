"""Synthetic token streams for the LM-family architectures.

PreSto's feature-level ops are tabular-only, but its *placement* idea
(preprocess each data shard where it lives, zero redistribution) applies to
any ingestion pipeline.  For LM archs the per-shard preprocessing is:
decode -> pack documents to fixed seq_len -> shift labels -> mask pads.
Generation is deterministic in (seed, shard, step) so any host can
regenerate any shard (elastic restart / straggler re-issue safe).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenSynthesizer:
    vocab_size: int
    seq_len: int
    seed: int = 0

    def shard_batch(self, shard: int, step: int, per_shard_batch: int) -> dict:
        """One local shard's batch: tokens/labels/mask of (B_local, seq)."""
        rng = np.random.default_rng(
            (self.seed << 40) ^ (shard << 20) ^ (step & 0xFFFFF)
        )
        # zipf-ish unigram stream: realistic skew without a real corpus
        u = rng.random(size=(per_shard_batch, self.seq_len + 1))
        toks = ((u ** 3.0) * (self.vocab_size - 2)).astype(np.int32) + 1
        # random document boundaries -> packing mask
        doclen = rng.integers(64, self.seq_len + 1)
        pos = np.arange(self.seq_len)
        segment = (pos // max(doclen, 1)).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "segment_ids": np.broadcast_to(segment, (per_shard_batch, self.seq_len)).copy(),
            "mask": np.ones((per_shard_batch, self.seq_len), dtype=np.bool_),
        }


def lm_input_batch(
    vocab_size: int, seq_len: int, global_batch: int, seed: int = 0, step: int = 0
) -> dict:
    """Full global batch on host (small configs / tests only)."""
    synth = TokenSynthesizer(vocab_size, seq_len, seed)
    return synth.shard_batch(shard=0, step=step, per_shard_batch=global_batch)
