"""Synthetic raw-feature sources for RM1-RM5 (Table I of the paper).

RM1 mirrors the public Criteo dataset (13 dense / 26 sparse features, sparse
length fixed at 1).  RM2-RM5 are the paper's production-scale synthetics
(504 dense / 42 sparse, average sparse length 20) with growing numbers of
generated features and bucket sizes.  Generation is deterministic in
(seed, partition_id) so any worker can regenerate any partition — this is
what makes straggler re-issue and elastic restart trivially correct.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict

import numpy as np

from repro.data.columnar import (
    ColumnSchema,
    Partition,
    PartitionSchema,
    encode_partition,
    refs_column,
)


@dataclasses.dataclass(frozen=True)
class RMDataConfig:
    name: str
    n_dense: int
    n_sparse: int
    avg_sparse_len: int
    max_sparse_len: int
    n_generated: int  # dense features bucketized into new sparse features
    bucket_size: int  # number of bucket boundaries (m in Alg. 1)
    id_space: int  # raw sparse-id space (SigridHash squeezes into table)
    embedding_rows: int  # avg embeddings per table (d in Alg. 2)
    rows_per_partition: int = 8192
    dense_encoding: str = "bytesplit"
    sparse_encoding: str = "bitpack"
    # -- sample-level dedup (RecD) -----------------------------------------
    # dup_factor: every `dup_factor` consecutive rows form one session that
    # shares ONE sparse-feature block (dense features + labels stay
    # per-sample).  Partitions are then stored dedup-encoded (unique blocks
    # + per-sample refs, data.columnar).  1 = no duplication.
    dup_factor: int = 1
    # dup_pool: > 0 draws each partition's session blocks from a DATASET-
    # level pool of this many distinct blocks, so different partitions (and
    # tenants of the same dataset) share identical blocks — the cross-
    # partition overlap the feature cache's block tier dedups.  0 = every
    # partition's blocks are fresh.
    dup_pool: int = 0

    @property
    def n_tables(self) -> int:
        return self.n_sparse + self.n_generated

    @property
    def id_width(self) -> int:
        return max(int(self.id_space - 1).bit_length(), 1)

    @property
    def len_width(self) -> int:
        return max(int(self.max_sparse_len).bit_length(), 1)


# Table I of the paper. id_space is a large raw space (ids are hashed down to
# embedding_rows by SigridHash); embedding_rows = "Avg. # Embeddings".
RM_CONFIGS: Dict[str, RMDataConfig] = {
    "rm1": RMDataConfig("rm1", 13, 26, 1, 1, 13, 1024, 1 << 24, 500_000),
    "rm2": RMDataConfig("rm2", 504, 42, 20, 32, 21, 1024, 1 << 24, 500_000),
    "rm3": RMDataConfig("rm3", 504, 42, 20, 32, 42, 1024, 1 << 24, 500_000),
    "rm4": RMDataConfig("rm4", 504, 42, 20, 32, 42, 2048, 1 << 24, 500_000),
    "rm5": RMDataConfig("rm5", 504, 42, 20, 32, 42, 4096, 1 << 24, 500_000),
}


@dataclasses.dataclass
class RawBatch:
    """Decoded raw features for one partition (pre-Transform)."""

    dense: np.ndarray  # (rows, n_dense) f32
    sparse_values: np.ndarray  # (rows, n_sparse, max_len) i32
    sparse_lengths: np.ndarray  # (rows, n_sparse) i32
    labels: np.ndarray  # (rows,) f32 in {0,1}
    # dedup datasets (cfg.dup_factor > 1): row r's sparse block is unique
    # block sparse_refs[r]; sparse_values/lengths are the EXPANDED logical
    # view (rows referencing one block are exact copies).  None otherwise.
    sparse_refs: np.ndarray | None = None


def _schema_for(cfg: RMDataConfig, rows: int) -> PartitionSchema:
    cols = []
    for i in range(cfg.n_dense):
        cols.append(ColumnSchema(f"d{i}", "dense", cfg.dense_encoding))
    for i in range(cfg.n_sparse):
        cols.append(
            ColumnSchema(
                f"s{i}",
                "sparse",
                cfg.sparse_encoding,
                max_len=cfg.max_sparse_len,
                id_width=cfg.id_width,
                len_width=cfg.len_width,
                dict_size=cfg.id_space if cfg.sparse_encoding == "dict" else 0,
            )
        )
    # label column rides along as a dense column
    cols.append(ColumnSchema("label", "dense", "plain"))
    if cfg.dup_factor > 1:
        cols.append(refs_column())
    return PartitionSchema(
        rows=rows, columns=tuple(cols), dup_factor=cfg.dup_factor
    )


class SyntheticRecSysSource:
    """Deterministic partition generator + encoder for one RM config."""

    def __init__(self, cfg: RMDataConfig, rows: int | None = None, seed: int = 0):
        self.cfg = cfg
        self.rows = rows or cfg.rows_per_partition
        self.seed = seed
        if cfg.dup_factor > 1:
            # unique-block pages regroup into 32-value word groups at the
            # kernel boundary, so unique_rows must stay word-aligned
            assert self.rows % cfg.dup_factor == 0 and (
                (self.rows // cfg.dup_factor) % 32 == 0
            ), (
                f"rows={self.rows} needs rows/dup_factor divisible by 32 "
                f"(dup_factor={cfg.dup_factor})"
            )
        self.schema = _schema_for(cfg, self.rows)
        self._pool_cache: Dict[int, tuple] = {}  # pool block id -> (ids, lens)
        # Dataset-level bucket boundaries (one sorted array per generated
        # feature) drawn from the dense-feature distribution's range.
        rng = np.random.default_rng(seed ^ 0x5EED)
        self.bucket_boundaries = np.sort(
            rng.lognormal(mean=1.0, sigma=2.0, size=(cfg.n_generated, cfg.bucket_size))
            .astype(np.float32),
            axis=-1,
        )
        # which dense column feeds each generated feature
        self.generated_source = (
            np.arange(cfg.n_generated, dtype=np.int32) % max(cfg.n_dense, 1)
        )

    def fingerprint(self) -> str:
        """Content identity of the dataset this source generates.

        Generation is deterministic in (cfg, rows, seed), so that triple IS
        the content: two sources built with equal parameters produce bitwise-
        equal partitions and must fingerprint alike (this is what lets two
        tenants with separate store objects share feature-cache entries)."""
        payload = json.dumps(
            {"cfg": dataclasses.asdict(self.cfg), "rows": self.rows,
             "seed": self.seed},
            sort_keys=True,
        )
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    # -- raw (decoded) view ------------------------------------------------
    def _sparse_block_batch(self, rng, n: int):
        """Draw n sparse blocks: ((n, S, L) ids, (n, S) lengths)."""
        cfg = self.cfg
        if cfg.max_sparse_len == 1:
            lengths = np.ones((n, cfg.n_sparse), dtype=np.int32)
        else:
            lengths = np.clip(
                rng.poisson(cfg.avg_sparse_len, size=(n, cfg.n_sparse)),
                1,
                cfg.max_sparse_len,
            ).astype(np.int32)
        # Zipf-flavored ids: square a uniform to skew toward small ids, then
        # scatter across the space with a multiplicative hash for realism.
        u = rng.random(size=(n, cfg.n_sparse, cfg.max_sparse_len))
        ids = (u * u * (cfg.id_space - 1)).astype(np.int64)
        ids = (ids * 2654435761) % cfg.id_space
        mask = np.arange(cfg.max_sparse_len)[None, None, :] < lengths[..., None]
        ids = np.where(mask, ids, 0).astype(np.int32)
        return ids, lengths

    def _pool_block(self, pool_id: int):
        """One dataset-level session block, deterministic in (seed, pool_id)."""
        blk = self._pool_cache.get(pool_id)
        if blk is None:
            rng = np.random.default_rng((self.seed << 20) ^ 0xB10C0000 ^ pool_id)
            ids, lens = self._sparse_block_batch(rng, 1)
            blk = (ids[0], lens[0])
            self._pool_cache[pool_id] = blk
        return blk

    def block_pool_ids(self, partition_id: int) -> np.ndarray | None:
        """Pool index of each unique block of one partition (dup_pool > 0).

        Cheap (one rng draw, no content generation) — the source-backed fast
        path for block fingerprints, and deterministic in (seed, pid) like
        everything else here."""
        cfg = self.cfg
        if cfg.dup_factor <= 1 or cfg.dup_pool <= 0:
            return None
        n_unique = self.rows // cfg.dup_factor
        rng = np.random.default_rng((self.seed << 20) ^ 0x5E55 ^ partition_id)
        return rng.integers(0, cfg.dup_pool, size=n_unique, dtype=np.int64)

    def block_refs(self, partition_id: int) -> np.ndarray | None:
        """The (rows,) refs vector of one partition (contiguous sessions)."""
        d = self.cfg.dup_factor
        if d <= 1:
            return None
        return np.arange(self.rows, dtype=np.int64) // d

    def raw(self, partition_id: int) -> RawBatch:
        cfg, rows = self.cfg, self.rows
        rng = np.random.default_rng((self.seed << 20) ^ partition_id)
        dense = rng.lognormal(mean=1.0, sigma=2.0, size=(rows, cfg.n_dense)).astype(
            np.float32
        )
        if cfg.dup_factor <= 1:
            ids, lengths = self._sparse_block_batch(rng, rows)
            labels = (rng.random(size=(rows,)) < 0.25).astype(np.float32)
            return RawBatch(dense, ids, lengths, labels)
        # dedup dataset: one sparse block per session of dup_factor rows
        n_unique = rows // cfg.dup_factor
        pool_ids = self.block_pool_ids(partition_id)
        if pool_ids is None:
            uids, ulens = self._sparse_block_batch(rng, n_unique)
        else:
            blocks = [self._pool_block(int(p)) for p in pool_ids]
            uids = np.stack([b[0] for b in blocks])
            ulens = np.stack([b[1] for b in blocks])
        labels = (rng.random(size=(rows,)) < 0.25).astype(np.float32)
        refs = self.block_refs(partition_id)
        return RawBatch(dense, uids[refs], ulens[refs], labels, refs)

    # -- encoded partition ---------------------------------------------------
    def partition(self, partition_id: int) -> Partition:
        raw = self.raw(partition_id)
        cfg = self.cfg
        dense = {f"d{i}": raw.dense[:, i] for i in range(cfg.n_dense)}
        dense["label"] = raw.labels
        svals = {f"s{i}": raw.sparse_values[:, i] for i in range(cfg.n_sparse)}
        slens = {f"s{i}": raw.sparse_lengths[:, i] for i in range(cfg.n_sparse)}
        return encode_partition(
            partition_id, self.schema, dense, svals, slens,
            sparse_refs=raw.sparse_refs,
        )


def make_rm_source(
    name: str, rows: int | None = None, seed: int = 0
) -> SyntheticRecSysSource:
    cfg = RM_CONFIGS[name.lower()]
    return SyntheticRecSysSource(cfg, rows=rows, seed=seed)
