"""Columnar page encodings (host-side encode, numpy decode oracles).

These are the wire encodings of the columnar store (our Parquet-lite). The
paper's ISP "Decode" unit consumes exactly these pages; the TPU-side decoders
live in ``repro.kernels`` (Pallas) with pure-jnp oracles in
``repro.kernels.ref`` that must match the numpy decoders here bit-for-bit.

Encodings
---------
``bitpack(width)``
    n unsigned ints of bit-width ``w <= 32`` packed LSB-first into uint32
    words, padded with one trailing word so straddling reads never go out of
    bounds.  This is the workhorse for sparse-id values, dictionary codes and
    per-row lengths.

``dict`` (dictionary + bitpacked codes)
    Distinct values in a dictionary array; codes bitpacked at
    ``ceil(log2(len(dict)))`` bits.

``bytesplit`` (BYTE_STREAM_SPLIT)
    float32 values split into 4 byte planes (all byte-0s, then byte-1s, ...),
    which is what real columnar stores do before general-purpose compression.
    Decode reassembles the planes.

Widths are fixed at *dataset* level (not per page) so every partition of a
dataset decodes with a single compiled XLA program.  Real systems use
per-page frame-of-reference; we trade a few bits of entropy for one-program
ingestion, which is the right call on an accelerator.
"""

from __future__ import annotations

import numpy as np


def pack_words_needed(n: int, width: int) -> int:
    """Number of uint32 words to hold n values of `width` bits, +1 pad word."""
    if n == 0:
        return 1
    return (n * width + 31) // 32 + 1


def width_for(max_value: int) -> int:
    """Bit width needed to represent values in [0, max_value]."""
    if max_value <= 0:
        return 1
    return int(max_value).bit_length()


def bitpack(values: np.ndarray, width: int) -> np.ndarray:
    """Pack uint values (< 2**width) LSB-first into a uint32 word array."""
    values = np.asarray(values)
    assert width >= 1 and width <= 32, width
    v = values.astype(np.uint64) & ((np.uint64(1) << np.uint64(width)) - np.uint64(1))
    n = v.shape[0]
    out = np.zeros(pack_words_needed(n, width), dtype=np.uint64)
    bit_pos = np.arange(n, dtype=np.uint64) * np.uint64(width)
    word_idx = (bit_pos >> np.uint64(5)).astype(np.int64)
    bit_off = bit_pos & np.uint64(31)
    lo = (v << bit_off) & np.uint64(0xFFFFFFFF)
    hi = v >> (np.uint64(32) - bit_off)  # bit_off == 0 -> shift by 32: handle below
    hi = np.where(bit_off == 0, np.uint64(0), hi)
    np.bitwise_or.at(out, word_idx, lo)
    np.bitwise_or.at(out, word_idx + 1, hi)
    return out.astype(np.uint32)


def bitunpack(packed: np.ndarray, n: int, width: int) -> np.ndarray:
    """Inverse of `bitpack` -> uint32 array of n values. Numpy oracle."""
    packed64 = packed.astype(np.uint64)
    bit_pos = np.arange(n, dtype=np.uint64) * np.uint64(width)
    word_idx = (bit_pos >> np.uint64(5)).astype(np.int64)
    bit_off = bit_pos & np.uint64(31)
    lo = packed64[word_idx] >> bit_off
    hi = packed64[word_idx + 1] << (np.uint64(32) - bit_off)
    hi = np.where(bit_off == 0, np.uint64(0), hi)
    mask = (np.uint64(1) << np.uint64(width)) - np.uint64(1)
    return ((lo | hi) & mask).astype(np.uint32)


def dict_encode(values: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """Dictionary-encode int values -> (dictionary, packed_codes, code_width)."""
    dictionary, codes = np.unique(np.asarray(values), return_inverse=True)
    code_width = width_for(max(len(dictionary) - 1, 1))
    packed = bitpack(codes.astype(np.uint64), code_width)
    return dictionary.astype(np.int32), packed, code_width


def dict_decode(
    dictionary: np.ndarray, packed_codes: np.ndarray, n: int, code_width: int
) -> np.ndarray:
    codes = bitunpack(packed_codes, n, code_width).astype(np.int64)
    return dictionary[codes]


def bytesplit_encode(values: np.ndarray) -> np.ndarray:
    """float32 -> byte planes, returned as a uint32 word array (4 planes)."""
    v = np.ascontiguousarray(values.astype(np.float32))
    raw = v.view(np.uint8).reshape(-1, 4)
    n = raw.shape[0]
    # plane-major layout: [all byte0][all byte1][all byte2][all byte3]
    planes = raw.T.reshape(-1)  # (4*n,) uint8
    pad = (-planes.shape[0]) % 4
    if pad:
        planes = np.concatenate([planes, np.zeros(pad, dtype=np.uint8)])
    return planes.view(np.uint32).copy(), n  # type: ignore[return-value]


def bytesplit_decode(words: np.ndarray, n: int) -> np.ndarray:
    planes = words.view(np.uint8)[: 4 * n].reshape(4, n)
    raw = planes.T.reshape(-1).copy()
    return raw.view(np.float32).copy()


def plain_f32_encode(values: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(values.astype(np.float32)).view(np.uint32).copy()


def plain_f32_decode(words: np.ndarray, n: int) -> np.ndarray:
    return words[:n].view(np.float32).copy()
