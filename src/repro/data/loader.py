"""Host-side async loader: prefetch queue + work stealing + straggler re-issue.

The producer-consumer model of the paper's software architecture (Fig. 9):
preprocessing workers fill an input queue that the train manager drains.  At
fleet scale a slow storage device (straggler) must not stall the queue, so
the work queue supports *speculative re-issue*: if a claimed partition has
not completed within `straggler_timeout`, another worker may claim a backup
copy; first completion wins, duplicates are dropped (partitions are
deterministic, so duplicate results are identical — re-issue is always safe).
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional


class WorkQueue:
    """Partition work queue with straggler re-issue (backup tasks)."""

    def __init__(self, partition_ids: Iterable[int], straggler_timeout: float = 30.0):
        self._pending: List[int] = list(partition_ids)
        self._inflight: Dict[int, float] = {}  # pid -> claim time
        self._done: set[int] = set()
        self._lock = threading.Lock()
        self.straggler_timeout = straggler_timeout
        self.reissues = 0
        self.total = len(self._pending)  # distinct partitions at creation

    def remaining(self) -> int:
        """Partitions not yet completed (pending + inflight), under the lock."""
        with self._lock:
            return len(self._pending) + len(self._inflight)

    def claim(self) -> Optional[int]:
        with self._lock:
            if self._pending:
                pid = self._pending.pop(0)
                self._inflight[pid] = time.monotonic()
                return pid
            # steal: re-issue the longest-overdue inflight partition
            now = time.monotonic()
            overdue = [
                (t, p)
                for p, t in self._inflight.items()
                if now - t > self.straggler_timeout and p not in self._done
            ]
            if overdue:
                overdue.sort()
                _, pid = overdue[0]
                self._inflight[pid] = now
                self.reissues += 1
                return pid
            return None

    def complete(self, pid: int) -> bool:
        """Returns True if this completion is the winner (not a duplicate)."""
        with self._lock:
            if pid in self._done:
                return False
            self._done.add(pid)
            self._inflight.pop(pid, None)
            return True

    @property
    def exhausted(self) -> bool:
        with self._lock:
            return not self._pending and not self._inflight


class PrefetchLoader:
    """Threaded prefetching producer: keeps `depth` ready batches queued.

    produce_fn(partition_id) -> batch.  Batches are delivered in completion
    order (training is order-agnostic across partitions, like the paper's
    mini-batch queue).
    """

    def __init__(
        self,
        partition_ids: Iterable[int],
        produce_fn: Callable[[int], Any],
        num_workers: int = 2,
        depth: int = 4,
        straggler_timeout: float = 30.0,
    ):
        self.work = WorkQueue(partition_ids, straggler_timeout)
        self.produce_fn = produce_fn
        self.out: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._threads = [
            threading.Thread(target=self._run, daemon=True) for _ in range(num_workers)
        ]
        self._stop = threading.Event()
        self._started = False
        self._produced = 0
        self._total = self.work.total

    def start(self) -> "PrefetchLoader":
        self._started = True
        for t in self._threads:
            t.start()
        return self

    def _run(self) -> None:
        while not self._stop.is_set():
            pid = self.work.claim()
            if pid is None:
                if self.work.exhausted:
                    return
                time.sleep(0.005)
                continue
            batch = self.produce_fn(pid)
            if self.work.complete(pid):  # drop duplicate straggler results
                self.out.put((pid, batch))

    def __iter__(self):
        if not self._started:
            self.start()
        while self._produced < self._total:
            try:
                pid, batch = self.out.get(timeout=0.5)
            except queue.Empty:
                if self._stop.is_set():
                    return
                # Liveness: if every worker has exited but work is undone and
                # nothing is queued, a worker died mid-produce — blocking on
                # get() forever would hang the trainer.
                if (
                    not any(t.is_alive() for t in self._threads)
                    and self.out.empty()
                ):
                    if self.work.remaining() == 0:
                        return  # nothing left and nothing queued: clean end
                    raise RuntimeError(
                        "PrefetchLoader workers exited with "
                        f"{self.work.remaining()} partitions unfinished"
                    )
                continue
            self._produced += 1
            yield pid, batch

    def stop(self) -> None:
        self._stop.set()
