"""Host-side async loading: work queue + straggler re-issue + per-session queues.

The producer-consumer model of the paper's software architecture (Fig. 9):
preprocessing workers fill an input queue that the train manager drains.  At
fleet scale a slow storage device (straggler) must not stall the queue, so
the work queue supports *speculative re-issue*: if a claimed partition has
not completed within `straggler_timeout`, another worker may claim a backup
copy; first completion wins, duplicates are dropped (partitions are
deterministic, so duplicate results are identical — re-issue is always safe).

Two delivery mechanisms sit on top of ``WorkQueue``:

* ``PrefetchLoader``  — the single-tenant convenience: private threads owned
  by one consumer, delivering batches in completion order.
* ``SessionQueue``    — the multi-tenant generalization used by
  ``core.service.PreprocessingService``: production is done by EXTERNAL pool
  workers shared across sessions; delivery is a stream of futures in claim
  order, and fresh claims are refused while ``depth`` futures are undelivered
  (backpressure) — straggler re-issues stay allowed so liveness never depends
  on a slow consumer.  An optional ``lookup`` hook (the shared
  ``core.featcache.FeatureCache`` probe) short-circuits claims whose batch
  is already cached: the future resolves without a produce.
"""

from __future__ import annotations

import collections
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Deque, Dict, Iterable, Optional, Tuple


class WorkQueue:
    """Partition work queue with straggler re-issue (backup tasks)."""

    def __init__(self, partition_ids: Iterable[int], straggler_timeout: float = 30.0):
        # dedup, order-preserving: a repeated pid would complete once and then
        # be dropped as a straggler duplicate, stranding its consumer forever
        self._pending: Deque[int] = collections.deque(dict.fromkeys(partition_ids))
        self._inflight: Dict[int, float] = {}  # pid -> claim time
        self._done: set[int] = set()
        self._lock = threading.Lock()
        self.straggler_timeout = straggler_timeout
        self.reissues = 0
        self.total = len(self._pending)  # distinct partitions at creation

    def remaining(self) -> int:
        """Partitions not yet completed (pending + inflight), under the lock."""
        with self._lock:
            return len(self._pending) + len(self._inflight)

    def claim(self, *, reissue_only: bool = False) -> Optional[int]:
        """Claim a partition; FIFO over pending, then straggler re-issue.

        ``reissue_only=True`` skips fresh claims (used by backpressured
        sessions: no new work may start, but an overdue straggler may still
        be backed up so the stream's head future always resolves).
        """
        with self._lock:
            if self._pending and not reissue_only:
                pid = self._pending.popleft()
                self._inflight[pid] = time.monotonic()
                return pid
            # steal: re-issue the longest-overdue inflight partition
            now = time.monotonic()
            overdue = [
                (t, p)
                for p, t in self._inflight.items()
                if now - t > self.straggler_timeout and p not in self._done
            ]
            if overdue:
                overdue.sort()
                _, pid = overdue[0]
                self._inflight[pid] = now
                self.reissues += 1
                return pid
            return None

    def complete(self, pid: int) -> bool:
        """Returns True if this completion is the winner (not a duplicate)."""
        with self._lock:
            if pid in self._done:
                return False
            self._done.add(pid)
            self._inflight.pop(pid, None)
            return True

    @property
    def exhausted(self) -> bool:
        with self._lock:
            return not self._pending and not self._inflight


class SessionQueue:
    """Per-session queues for a shared preprocessing pool.

    The claim/complete bookkeeping (straggler re-issue, duplicate drop) stays
    in ``WorkQueue``; production is done by external pool workers.  The first
    claim of a partition enqueues a ``Future`` on ``out`` (so delivery is in
    claim order); re-issued claims reuse the existing future and the first
    ``complete`` wins.  Backpressure: ``claim`` refuses fresh work while
    ``depth`` claims are undelivered (``mark_delivered`` is the consumer's
    pacing signal), so at most ``depth`` produced batches are ever held in
    service-side structures.
    """

    def __init__(
        self,
        partition_ids: Iterable[int],
        *,
        depth: int = 4,
        straggler_timeout: float = 30.0,
        lookup: Optional[Callable[[int, bool], Any]] = None,
    ):
        self.work = WorkQueue(partition_ids, straggler_timeout)
        self.depth = depth
        self.out: "queue.Queue[Future]" = queue.Queue()
        self._futures: Dict[int, Future] = {}  # claimed, not yet completed
        self._lock = threading.Lock()
        self.cancelled = threading.Event()
        self.total = self.work.total
        self._created = 0
        self._delivered = 0
        # feature-cache probe: lookup(pid, fresh) -> None (produce), a batch
        # (cached: complete immediately, no produce), or a Future (another
        # tenant is producing this content: complete when it resolves).  The
        # claim loop continues past short-circuited pids so the caller only
        # ever receives a pid that actually needs a produce.
        self.lookup = lookup
        self.short_circuits = 0

    def claim(self) -> Optional[Tuple[int, Future]]:
        """Pool-worker side: claim (pid, future), or None if nothing to do.

        With a ``lookup`` bound, every claimed pid is probed first: cached
        claims complete immediately, claims whose content another tenant is
        already producing pend on that tenant's future (winner semantics
        throughout — a re-issued claim whose twin is still producing resolves
        from cache and the straggler's own result is dropped as a duplicate),
        and claiming continues so the worker only ever receives a pid that
        actually needs a produce."""
        while True:
            with self._lock:
                if self.cancelled.is_set():
                    return None
                backpressured = self._created - self._delivered >= self.depth
                pid = self.work.claim(reissue_only=backpressured)
                if pid is None:
                    return None
                fut = self._futures.get(pid)
                fresh = fut is None
                if fresh:
                    fut = Future()
                    fut.set_running_or_notify_cancel()
                    self._futures[pid] = fut
                    self._created += 1
                    self.out.put(fut)
            if self.lookup is not None:
                try:
                    found = self.lookup(pid, fresh)
                except Exception:
                    found = None  # a broken cache probe degrades to a miss
                if isinstance(found, Future):
                    self._pend(pid, found)
                    continue
                if found is not None:
                    if self.complete(pid, found):
                        with self._lock:
                            self.short_circuits += 1
                    continue
            return pid, fut

    def _pend(self, pid: int, donor: Future) -> None:
        """Resolve `pid` from another tenant's in-flight produce of the same
        content.  If the donor is cancelled (leader dropped without a
        result), nothing completes here — the pid stays inflight and the
        straggler timeout re-issues it to a real produce."""

        def _done(d: Future) -> None:
            if d.cancelled():
                return
            exc = d.exception()
            if exc is not None:
                self.complete_error(pid, exc)
            # shallow copy: every follower gets its own batch dict (array
            # buffers stay shared — they are immutable)
            elif self.complete(pid, dict(d.result())):
                with self._lock:
                    self.short_circuits += 1

        donor.add_done_callback(_done)

    def mark_delivered(self) -> None:
        """Consumer pacing signal: one claimed batch has left the stream."""
        with self._lock:
            self._delivered += 1

    def complete(self, pid: int, batch: Any) -> bool:
        """First completion wins and resolves the future; duplicates dropped."""
        if not self.work.complete(pid):
            return False
        with self._lock:
            # drop our reference: once delivered, the batch's lifetime is the
            # consumer's (memory stays bounded by depth, not job size)
            fut = self._futures.pop(pid)
        fut.set_result((pid, batch))
        return True

    def complete_error(self, pid: int, exc: BaseException) -> bool:
        """Propagate a producer failure to the consumer (winner-only)."""
        if not self.work.complete(pid):
            return False
        with self._lock:
            fut = self._futures.pop(pid)
        fut.set_exception(exc)
        return True

    @property
    def exhausted(self) -> bool:
        return self.work.exhausted

    def cancel(self) -> None:
        self.cancelled.set()


class PrefetchLoader:
    """Threaded prefetching producer: keeps `depth` ready batches queued.

    produce_fn(partition_id) -> batch.  Batches are delivered in completion
    order (training is order-agnostic across partitions, like the paper's
    mini-batch queue).
    """

    def __init__(
        self,
        partition_ids: Iterable[int],
        produce_fn: Callable[[int], Any],
        num_workers: int = 2,
        depth: int = 4,
        straggler_timeout: float = 30.0,
    ):
        self.work = WorkQueue(partition_ids, straggler_timeout)
        self.produce_fn = produce_fn
        self.out: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._threads = [
            threading.Thread(target=self._run, daemon=True) for _ in range(num_workers)
        ]
        self._stop = threading.Event()
        self._started = False
        self._produced = 0
        self._total = self.work.total

    def start(self) -> "PrefetchLoader":
        self._started = True
        for t in self._threads:
            t.start()
        return self

    def _run(self) -> None:
        while not self._stop.is_set():
            pid = self.work.claim()
            if pid is None:
                if self.work.exhausted:
                    return
                time.sleep(0.005)
                continue
            batch = self.produce_fn(pid)
            if self.work.complete(pid):  # drop duplicate straggler results
                # Timed put: a plain blocking put() would ignore stop()
                # forever when the consumer goes away with the queue full.
                while not self._stop.is_set():
                    try:
                        self.out.put((pid, batch), timeout=0.05)
                        break
                    except queue.Full:
                        continue

    def __iter__(self):
        if not self._started:
            self.start()
        while self._produced < self._total:
            try:
                pid, batch = self.out.get(timeout=0.5)
            except queue.Empty:
                if self._stop.is_set():
                    return
                # Liveness: if every worker has exited but work is undone and
                # nothing is queued, a worker died mid-produce — blocking on
                # get() forever would hang the trainer.
                if (
                    not any(t.is_alive() for t in self._threads)
                    and self.out.empty()
                ):
                    if self.work.remaining() == 0:
                        return  # nothing left and nothing queued: clean end
                    raise RuntimeError(
                        "PrefetchLoader workers exited with "
                        f"{self.work.remaining()} partitions unfinished"
                    )
                continue
            self._produced += 1
            yield pid, batch

    def stop(self) -> None:
        self._stop.set()
        me = threading.current_thread()
        for t in self._threads:
            if t.is_alive() and t is not me:
                t.join(timeout=5.0)
