"""Host-side async loading: work queue + straggler re-issue + per-session queues.

The producer-consumer model of the paper's software architecture (Fig. 9):
preprocessing workers fill an input queue that the train manager drains.  At
fleet scale a slow storage device (straggler) must not stall the queue, so
the work queue supports *speculative re-issue*: if a claimed partition has
not completed within `straggler_timeout`, another worker may claim a backup
copy; first completion wins, duplicates are dropped (partitions are
deterministic, so duplicate results are identical — re-issue is always safe).

Two delivery mechanisms sit on top of ``WorkQueue``:

* ``PrefetchLoader``  — the single-tenant convenience: private threads owned
  by one consumer, delivering batches in completion order.
* ``SessionQueue``    — the multi-tenant generalization used by
  ``core.service.PreprocessingService``: production is done by EXTERNAL pool
  workers shared across sessions; delivery is a stream of futures in claim
  order, and fresh claims are refused while ``depth`` futures are undelivered
  (backpressure) — straggler re-issues stay allowed so liveness never depends
  on a slow consumer.  An optional ``lookup`` hook (the shared
  ``core.featcache.FeatureCache`` probe) short-circuits claims whose batch
  is already cached: the future resolves without a produce.

Both queues are device-aware when given an ``owner_of`` mapping: claims
prefer partitions owned by the claimer's own ISP device and fall back to
host placement only when the caller's ``fallback_ok`` predicate admits it
(see ``core.service`` for the contention-aware policy).  Routing never
changes batch bytes — only where/when they are produced.
"""

from __future__ import annotations

import collections
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Deque, Dict, Iterable, Optional, Tuple


class WorkQueue:
    """Partition work queue with straggler re-issue (backup tasks).

    With an ``owner_of`` mapping (pid -> storage device), claims become
    locality-aware: a claimer may prefer partitions owned by ITS device
    (``prefer_device``) and take foreign partitions only when the caller's
    ``fallback_ok`` predicate admits them (typically: the owning device's
    queue is past the host-fallback threshold, or the device has no bound
    unit at all).  FIFO order is preserved within each preference class.
    """

    def __init__(
        self,
        partition_ids: Iterable[int],
        straggler_timeout: float = 30.0,
        *,
        owner_of: Optional[Callable[[int], int]] = None,
        on_reissue: Optional[Callable[[int], None]] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        # dedup, order-preserving: a repeated pid would complete once and then
        # be dropped as a straggler duplicate, stranding its consumer forever
        self._pending: Deque[int] = collections.deque(dict.fromkeys(partition_ids))
        # Membership is authoritative in _pending_set; the deques are ORDER
        # indexes with lazy deletion: a pid popped through one index stays in
        # the other as a tombstone and is skipped when reached.  This makes
        # device-preferred claims O(1) amortized (pop the device deque's
        # head) instead of a linear rescan of the global deque per claim.
        self._pending_set: set[int] = set(self._pending)
        self._by_dev: Optional[Dict[int, Deque[int]]] = None
        if owner_of is not None:
            self._by_dev = {}
            for pid in self._pending:
                self._by_dev.setdefault(owner_of(pid), collections.deque()).append(pid)
        self._inflight: Dict[int, float] = {}  # pid -> claim time
        self._done: set[int] = set()
        # Fault-retry state: a pid whose produce hit a retryable I/O fault
        # is `requeue`d — back to pending, optionally embargoed until a
        # backoff deadline, and marked in _requeued so claims may take it
        # even under backpressure (its future already exists; re-claiming
        # it can never grow the consumer's undelivered window, and the
        # stream's head future may be exactly this pid — liveness).
        self._embargo: Dict[int, float] = {}  # pid -> claimable-at instant
        self._requeued: set[int] = set()
        self._lock = threading.Lock()
        self.straggler_timeout = straggler_timeout
        # Injectable time source (``core.simclock.VirtualClock.now`` under the
        # discrete-event simulator): every inflight stamp, straggler deadline
        # and expiry back-date reads THIS clock, so a virtual-time run makes
        # straggler re-issue deterministic instead of wall-clock-raced.
        self._clock: Callable[[], float] = clock or time.monotonic
        self.owner_of = owner_of
        # control-plane observer: called with the pid of every straggler
        # re-issue, OUTSIDE the queue lock (it may emit events / take other
        # locks); a broken observer never breaks the claim path
        self.on_reissue = on_reissue
        self.reissues = 0
        self.requeues = 0  # fault retries returned to the pending pool
        self.total = len(self._pending)  # distinct partitions at creation

    def remaining(self) -> int:
        """Partitions not yet completed (pending + inflight), under the lock."""
        with self._lock:
            return len(self._pending_set) + len(self._inflight)

    def is_pending(self, pid: int) -> bool:
        """True while `pid` is claimable (not yet claimed or completed)."""
        with self._lock:
            return pid in self._pending_set

    def pending_snapshot(self) -> list:
        """Pending pids in claim order (fresh-claim FIFO), tombstones skipped."""
        with self._lock:
            return [p for p in self._pending if p in self._pending_set]

    def peek_ahead(self, n: int, *, prefer_device: Optional[int] = None) -> list:
        """The first `n` pending pids in the order fresh claims would take
        them, WITHOUT claiming: the preferred device's own partitions first
        (when device routing is bound), then the global FIFO.  A pure
        snapshot — nothing is marked inflight, backpressure is untouched —
        so lookahead prefetchers can stage reads and pre-warm caches for
        future claims while never racing the claim path for ownership."""
        if n <= 0:
            return []
        out: list = []
        seen: set[int] = set()
        with self._lock:
            if prefer_device is not None and self._by_dev is not None:
                for pid in self._by_dev.get(prefer_device, ()):
                    if pid in self._pending_set and pid not in seen:
                        out.append(pid)
                        seen.add(pid)
                        if len(out) >= n:
                            return out
            for pid in self._pending:
                if pid in self._pending_set and pid not in seen:
                    out.append(pid)
                    seen.add(pid)
                    if len(out) >= n:
                        break
        return out

    def next_deadline(self) -> Optional[float]:
        """Earliest instant anything becomes claimable again: an inflight
        claim going straggler-overdue, or an embargoed fault-retry's backoff
        expiring (on this queue's clock — ``time.monotonic`` unless
        injected); None when neither applies.  Idle claimers sleep until
        this instant instead of polling."""
        with self._lock:
            deadlines = []
            if self._inflight:
                deadlines.append(
                    min(self._inflight.values()) + self.straggler_timeout
                )
            if self._embargo:
                deadlines.append(min(self._embargo.values()))
            return min(deadlines) if deadlines else None

    def _claimable(self, pid: int, now: float) -> bool:
        """Pending and past any fault-retry backoff embargo."""
        if pid not in self._pending_set:
            return False
        until = self._embargo.get(pid)
        return until is None or now >= until

    def _claimed(self, pid: int) -> None:
        """Bookkeeping for a pid leaving the pending pool."""
        self._pending_set.discard(pid)
        self._embargo.pop(pid, None)
        self._requeued.discard(pid)

    def _pop(self, dq: Optional[Deque[int]], now: float) -> Optional[int]:
        """Pop the first claimable pid off an order index, discarding
        tombstones (pids already popped through the other index).  An
        embargoed pid rotates to the back instead of being dropped — the
        bounded loop guarantees termination when everything is embargoed."""
        if dq is None:
            return None
        for _ in range(len(dq)):
            pid = dq.popleft()
            if pid not in self._pending_set:
                continue  # tombstone: discard
            if self._claimable(pid, now):
                self._claimed(pid)
                return pid
            dq.append(pid)  # embargoed: keep for a later round
        return None

    def _take_first(
        self, pred: Callable[[int], bool], now: float
    ) -> Optional[int]:
        """First claimable pid matching `pred`, global FIFO order.  The
        popped pid is left in the deques as a tombstone (membership alone
        decides pending-ness).  Linear, but only the rare host-fallback and
        fault-retry scans use it — the device-local hot path pops its own
        index in O(1)."""
        for pid in self._pending:
            if self._claimable(pid, now) and pred(pid):
                self._claimed(pid)
                return pid
        return None

    def claim(
        self,
        *,
        reissue_only: bool = False,
        prefer_device: Optional[int] = None,
        fallback_ok: Optional[Callable[[int], bool]] = None,
    ) -> Optional[int]:
        """Claim a partition; FIFO over pending, then straggler re-issue.

        ``reissue_only=True`` skips fresh claims (used by backpressured
        sessions: no new work may start, but an overdue straggler may still
        be backed up so the stream's head future always resolves).  Fault
        RETRIES (``requeue``d pids) are exempt from that gate for the same
        liveness reason: their futures already exist — the stream's blocked
        head may be exactly the requeued pid, and re-claiming it never grows
        the undelivered window.

        ``prefer_device`` (with an ``owner_of`` bound) restricts fresh
        claims to that device's own partitions, then to partitions
        ``fallback_ok`` admits; a foreign partition neither local nor
        fallback-eligible is left for its own device's unit.  Straggler
        re-issue ignores locality — liveness beats placement.
        """
        reissued: Optional[int] = None
        try:
            with self._lock:
                now = self._clock()
                pid: Optional[int] = None
                if self._pending_set and not reissue_only:
                    if prefer_device is None or self.owner_of is None or self._by_dev is None:
                        pid = self._pop(self._pending, now)
                    else:
                        owner = self.owner_of
                        pid = self._pop(self._by_dev.get(prefer_device), now)
                        if pid is None and fallback_ok is not None:
                            # the offload verdict depends only on the OWNING
                            # device (manned? queue past threshold?), so cache
                            # it per device for this scan instead of re-pricing
                            # every pending pid under the lock
                            verdicts: Dict[int, bool] = {}

                            def _ok(p: int) -> bool:
                                d = owner(p)
                                if d not in verdicts:
                                    verdicts[d] = bool(fallback_ok(p))
                                return verdicts[d]

                            pid = self._take_first(_ok, now)
                elif self._requeued and reissue_only:
                    # backpressure bypass for fault retries (see docstring);
                    # locality is ignored — liveness beats placement, like
                    # straggler re-issue
                    pid = self._take_first(self._requeued.__contains__, now)
                if pid is not None:
                    self._inflight[pid] = now
                    return pid
                # steal: re-issue the longest-overdue inflight partition
                overdue = [
                    (t, p)
                    for p, t in self._inflight.items()
                    if now - t > self.straggler_timeout and p not in self._done
                ]
                if overdue:
                    overdue.sort()
                    _, pid = overdue[0]
                    self._inflight[pid] = now
                    self.reissues += 1
                    reissued = pid
                    return pid
                return None
        finally:
            if reissued is not None and self.on_reissue is not None:
                try:
                    self.on_reissue(reissued)
                except Exception:
                    pass

    def expire(self, pid: int) -> bool:
        """Force an inflight claim straggler-overdue NOW.

        The control plane's crash hook: a dead worker's claim must not wait
        out the full ``straggler_timeout``, so its inflight stamp is
        back-dated past the deadline and the very next claim round re-issues
        it through the normal straggler path (same future, same bytes —
        partitions are deterministic, so re-issue is always safe).  A
        completion that raced ahead wins as usual.  Returns True if the pid
        was actually inflight."""
        with self._lock:
            if pid in self._inflight and pid not in self._done:
                self._inflight[pid] = (
                    self._clock() - self.straggler_timeout - 1.0
                )
                return True
            return False

    def requeue(self, pid: int, delay: float = 0.0) -> bool:
        """Return a failed inflight claim to the pending pool (fault retry).

        The claim-path recovery policy's hook: a produce that died on a
        retryable I/O fault re-queues its pid instead of failing the future
        — back of the FIFO (and its device index), embargoed for ``delay``
        seconds of backoff on this queue's clock, and marked requeued so
        backpressured sessions may still re-claim it (its future already
        exists; see ``claim``).  Returns False without touching anything if
        the pid is already done or already pending (a duplicate claim's
        loser — the twin's retry or completion is in motion)."""
        with self._lock:
            if (
                pid in self._done
                or pid in self._pending_set
                or pid not in self._inflight
            ):
                return False
            del self._inflight[pid]
            self._pending_set.add(pid)
            self._pending.append(pid)
            if self._by_dev is not None and self.owner_of is not None:
                self._by_dev.setdefault(
                    self.owner_of(pid), collections.deque()
                ).append(pid)
            self._requeued.add(pid)
            if delay > 0:
                self._embargo[pid] = self._clock() + delay
            self.requeues += 1
            return True

    def complete(self, pid: int) -> bool:
        """Returns True if this completion is the winner (not a duplicate)."""
        with self._lock:
            if pid in self._done:
                return False
            self._done.add(pid)
            self._inflight.pop(pid, None)
            return True

    @property
    def exhausted(self) -> bool:
        with self._lock:
            return not self._pending_set and not self._inflight


class SessionQueue:
    """Per-session queues for a shared preprocessing pool.

    The claim/complete bookkeeping (straggler re-issue, duplicate drop) stays
    in ``WorkQueue``; production is done by external pool workers.  The first
    claim of a partition enqueues a ``Future`` on ``out`` (so delivery is in
    claim order); re-issued claims reuse the existing future and the first
    ``complete`` wins.  Backpressure: ``claim`` refuses fresh work while
    ``depth`` claims are undelivered (``mark_delivered`` is the consumer's
    pacing signal), so at most ``depth`` produced batches are ever held in
    service-side structures.
    """

    def __init__(
        self,
        partition_ids: Iterable[int],
        *,
        depth: int = 4,
        straggler_timeout: float = 30.0,
        lookup: Optional[Callable[[int, bool], Any]] = None,
        owner_of: Optional[Callable[[int], int]] = None,
        fallback_ok: Optional[Callable[[int], bool]] = None,
        on_settled: Optional[Callable[[int], None]] = None,
        on_offload: Optional[Callable[[int], None]] = None,
        on_reissue: Optional[Callable[[int], None]] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.work = WorkQueue(
            partition_ids, straggler_timeout, owner_of=owner_of,
            on_reissue=on_reissue, clock=clock,
        )
        self.depth = depth
        self.out: "queue.Queue[Future]" = queue.Queue()
        self._futures: Dict[int, Future] = {}  # claimed, not yet completed
        self._lock = threading.Lock()
        self.cancelled = threading.Event()
        self.total = self.work.total
        self._created = 0
        self._delivered = 0
        # feature-cache probe: lookup(pid, fresh) -> None (produce), a batch
        # (cached: complete immediately, no produce), or a Future (another
        # tenant is producing this content: complete when it resolves).  The
        # claim loop continues past short-circuited pids so the caller only
        # ever receives a pid that actually needs a produce.
        self.lookup = lookup
        self.short_circuits = 0
        # device routing: owner_of maps pid -> owning device, fallback_ok
        # admits foreign pids (queue past threshold / unmanned device),
        # on_settled(pid) fires once per pid on winner completion (backlog
        # release), on_offload(pid) fires when a fresh claim is routed to
        # the host (the pid stops waiting on its device)
        self.fallback_ok = fallback_ok
        self.on_settled = on_settled
        self.on_offload = on_offload
        self.host_fallbacks = 0  # fresh claims routed off their device

    def claim(
        self, prefer_device: Optional[int] = None
    ) -> Optional[Tuple[int, Future, Optional[str]]]:
        """Pool-worker side: claim (pid, future, route), or None if idle.

        ``route`` is ``None`` (no device routing), ``"isp"`` (produce on the
        pid's owning device) or ``"host"`` (host-fallback produce: pages over
        the link, compute off-device).  Routing NEVER changes the produced
        bytes — only where/when they are accounted.

        With a ``lookup`` bound, every claimed pid is probed first: cached
        claims complete immediately, claims whose content another tenant is
        already producing pend on that tenant's future (winner semantics
        throughout — a re-issued claim whose twin is still producing resolves
        from cache and the straggler's own result is dropped as a duplicate),
        and claiming continues so the worker only ever receives a pid that
        actually needs a produce."""
        while True:
            with self._lock:
                if self.cancelled.is_set():
                    return None
                backpressured = self._created - self._delivered >= self.depth
                pid = self.work.claim(
                    reissue_only=backpressured,
                    prefer_device=prefer_device,
                    fallback_ok=self.fallback_ok,
                )
                if pid is None:
                    return None
                fut = self._futures.get(pid)
                fresh = fut is None
                if fresh:
                    fut = Future()
                    fut.set_running_or_notify_cancel()
                    self._futures[pid] = fut
                    self._created += 1
                    self.out.put(fut)
            route: Optional[str] = None
            if self.work.owner_of is not None:
                owner = self.work.owner_of(pid)
                local = prefer_device is None or owner == prefer_device
                route = "isp" if local else "host"
            if self.lookup is not None:
                try:
                    found = self.lookup(pid, fresh)
                except Exception:
                    found = None  # a broken cache probe degrades to a miss
                if isinstance(found, Future):
                    self._pend(pid, found)
                    continue
                if found is not None:
                    if self.complete(pid, found):
                        with self._lock:
                            self.short_circuits += 1
                    continue
            if fresh and route == "host":
                # counted only for claims that actually reach a produce —
                # a cache short-circuit above needs no fallback at all
                with self._lock:
                    self.host_fallbacks += 1
                if self.on_offload is not None:
                    self.on_offload(pid)
            return pid, fut, route

    def _pend(self, pid: int, donor: Future) -> None:
        """Resolve `pid` from another tenant's in-flight produce of the same
        content.  If the donor is cancelled (leader dropped without a
        result), nothing completes here — the pid stays inflight and the
        straggler timeout re-issues it to a real produce."""

        def _done(d: Future) -> None:
            if d.cancelled():
                return
            exc = d.exception()
            if exc is not None:
                self.complete_error(pid, exc)
            # shallow copy: every follower gets its own batch dict (array
            # buffers stay shared — they are immutable)
            elif self.complete(pid, dict(d.result())):
                with self._lock:
                    self.short_circuits += 1

        donor.add_done_callback(_done)

    def peek_ahead(
        self, n: int, prefer_device: Optional[int] = None
    ) -> list:
        """Non-claiming window over this session's upcoming fresh claims,
        in the order ``claim`` would take them.  Safe to call from any
        worker at any time: nothing is claimed, created, or backpressured —
        it is the oracle a lookahead prefetcher / cache pre-warmer reads to
        stage work for claims that have not happened yet."""
        if self.cancelled.is_set():
            return []
        return self.work.peek_ahead(n, prefer_device=prefer_device)

    def mark_delivered(self) -> None:
        """Consumer pacing signal: one claimed batch has left the stream."""
        with self._lock:
            self._delivered += 1

    def expire(self, pid: int) -> bool:
        """Force `pid`'s inflight claim immediately re-issuable (a dead
        worker held it); see ``WorkQueue.expire``."""
        return self.work.expire(pid)

    def requeue(self, pid: int, delay: float = 0.0) -> bool:
        """Return `pid` to the pending pool for a fault retry with `delay`
        seconds of backoff; its existing future stays pending and resolves
        when a later claim produces (or quarantines) it.  See
        ``WorkQueue.requeue``."""
        return self.work.requeue(pid, delay)

    def complete(self, pid: int, batch: Any) -> bool:
        """First completion wins and resolves the future; duplicates dropped."""
        if not self.work.complete(pid):
            return False
        self._settle(pid)
        with self._lock:
            # drop our reference: once delivered, the batch's lifetime is the
            # consumer's (memory stays bounded by depth, not job size)
            fut = self._futures.pop(pid)
        fut.set_result((pid, batch))
        return True

    def complete_error(self, pid: int, exc: BaseException) -> bool:
        """Propagate a producer failure to the consumer (winner-only)."""
        if not self.work.complete(pid):
            return False
        self._settle(pid)
        with self._lock:
            fut = self._futures.pop(pid)
        fut.set_exception(exc)
        return True

    def _settle(self, pid: int) -> None:
        """Winner-only settle hook (device backlog release); never lets an
        accounting callback break the delivery path."""
        if self.on_settled is not None:
            try:
                self.on_settled(pid)
            except Exception:
                pass

    @property
    def exhausted(self) -> bool:
        return self.work.exhausted

    def cancel(self) -> None:
        self.cancelled.set()


class PrefetchLoader:
    """Threaded prefetching producer: keeps `depth` ready batches queued.

    produce_fn(partition_id) -> batch.  Batches are delivered in completion
    order (training is order-agnostic across partitions, like the paper's
    mini-batch queue).
    """

    def __init__(
        self,
        partition_ids: Iterable[int],
        produce_fn: Callable[[int], Any],
        num_workers: int = 2,
        depth: int = 4,
        straggler_timeout: float = 30.0,
    ):
        self.work = WorkQueue(partition_ids, straggler_timeout)
        self.produce_fn = produce_fn
        self.out: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._threads = [
            threading.Thread(target=self._run, daemon=True) for _ in range(num_workers)
        ]
        self._stop = threading.Event()
        # Idle-worker wakeups: a worker with nothing claimable sleeps on this
        # condition until a completion changes claimability (straggler gone /
        # queue exhausted), the next straggler deadline passes, or stop() —
        # no polling loop burning CPU while partitions are in flight
        # elsewhere.
        self._idle_cv = threading.Condition()
        self._started = False
        self._produced = 0
        self._total = self.work.total

    def start(self) -> "PrefetchLoader":
        self._started = True
        for t in self._threads:
            t.start()
        return self

    def _wake_idle(self) -> None:
        with self._idle_cv:
            self._idle_cv.notify_all()

    def _run(self) -> None:
        while not self._stop.is_set():
            pid = self.work.claim()
            if pid is None:
                if self.work.exhausted:
                    return
                # Nothing claimable: every pending pid is inflight elsewhere
                # and none is overdue yet.  Sleep until a completion notifies
                # us or the earliest straggler deadline arrives — whichever
                # first — instead of spin-polling.
                deadline = self.work.next_deadline()
                with self._idle_cv:
                    if self._stop.is_set() or self.work.exhausted:
                        continue
                    if deadline is None:
                        self._idle_cv.wait(timeout=0.05)  # claim/wait race
                    else:
                        self._idle_cv.wait(
                            timeout=max(0.0, deadline - time.monotonic())
                        )
                continue
            batch = self.produce_fn(pid)
            won = self.work.complete(pid)  # drop duplicate straggler results
            self._wake_idle()  # claimability / exhaustion changed
            if won:
                # Timed put: a plain blocking put() would ignore stop()
                # forever when the consumer goes away with the queue full.
                while not self._stop.is_set():
                    try:
                        self.out.put((pid, batch), timeout=0.05)
                        break
                    except queue.Full:
                        continue

    def __iter__(self):
        if not self._started:
            self.start()
        while self._produced < self._total:
            try:
                pid, batch = self.out.get(timeout=0.5)
            except queue.Empty:
                if self._stop.is_set():
                    return
                # Liveness: if every worker has exited but work is undone and
                # nothing is queued, a worker died mid-produce — blocking on
                # get() forever would hang the trainer.
                if (
                    not any(t.is_alive() for t in self._threads)
                    and self.out.empty()
                ):
                    if self.work.remaining() == 0:
                        return  # nothing left and nothing queued: clean end
                    raise RuntimeError(
                        "PrefetchLoader workers exited with "
                        f"{self.work.remaining()} partitions unfinished"
                    )
                continue
            self._produced += 1
            yield pid, batch

    def stop(self) -> None:
        self._stop.set()
        self._wake_idle()
        me = threading.current_thread()
        for t in self._threads:
            if t.is_alive() and t is not me:
                t.join(timeout=5.0)
