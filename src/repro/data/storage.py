"""Partitioned distributed store: partition ownership + placement.

Models the paper's distributed storage layer (Tectonic-style): every
partition's blocks live contiguously on exactly ONE storage device, which is
the property that lets an ISP unit preprocess a whole mini-batch locally.

Two placements are expressible:

* ``presto``  — partition p is owned by the SAME mesh shard that will consume
  the resulting mini-batch slice.  Preprocessing ⇒ zero redistribution.
* ``disagg``  — partitions are owned by a disjoint "preprocessing pool" slice
  of the mesh; train-ready tensors must be redistributed to the consumers
  (copy-in/copy-out of Fig. 7(b)).

The store can be disk-backed (one file per partition) or generate-on-read
(synthetic source), which is how we simulate petabyte-scale data without
petabytes.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from repro.data.columnar import Partition, read_partition, write_partition
from repro.data.synth import SyntheticRecSysSource


class PartitionedStore:
    def __init__(
        self,
        num_partitions: int,
        num_devices: int,
        source: Optional[SyntheticRecSysSource] = None,
        root: Optional[str] = None,
        placement: str = "presto",
    ):
        assert placement in ("presto", "disagg")
        self.num_partitions = num_partitions
        self.num_devices = num_devices
        self.source = source
        self.root = root
        self.placement = placement
        self._read_bytes = 0

    # -- ownership -----------------------------------------------------------
    def owner_of(self, partition_id: int) -> int:
        """Storage device that holds this partition (round-robin shard)."""
        return partition_id % self.num_devices

    def partitions_of(self, device: int) -> List[int]:
        return list(range(device, self.num_partitions, self.num_devices))

    # -- I/O -------------------------------------------------------------------
    def materialize(self, partition_ids: Iterable[int]) -> None:
        """Write partitions to disk (one columnar file each)."""
        assert self.root and self.source
        os.makedirs(self.root, exist_ok=True)
        for pid in partition_ids:
            path = self._path(pid)
            if not os.path.exists(path):
                write_partition(path, self.source.partition(pid))

    def read(self, partition_id: int) -> Partition:
        if self.root is not None:
            path = self._path(partition_id)
            if os.path.exists(path):
                part = read_partition(path)
                self._read_bytes += part.nbytes()
                return part
        assert self.source is not None, "no disk file and no synthetic source"
        part = self.source.partition(partition_id)
        self._read_bytes += part.nbytes()
        return part

    @property
    def bytes_read(self) -> int:
        return self._read_bytes

    def _path(self, pid: int) -> str:
        # deviceNN/ prefix models per-device directories of the storage array
        assert self.root is not None
        dev = self.owner_of(pid)
        ddir = os.path.join(self.root, f"device{dev:03d}")
        os.makedirs(ddir, exist_ok=True)
        return os.path.join(ddir, f"part{pid:06d}.rp")
