"""Partitioned distributed store: partition ownership + placement.

Models the paper's distributed storage layer (Tectonic-style): every
partition's blocks live contiguously on exactly ONE storage device, which is
the property that lets an ISP unit preprocess a whole mini-batch locally.

Two placements are expressible:

* ``presto``  — partition p is owned by the SAME mesh shard that will consume
  the resulting mini-batch slice.  Preprocessing ⇒ zero redistribution.
* ``disagg``  — partitions are owned by a disjoint "preprocessing pool" slice
  of the mesh; train-ready tensors must be redistributed to the consumers
  (copy-in/copy-out of Fig. 7(b)).

The store can be disk-backed (one file per partition) or generate-on-read
(synthetic source), which is how we simulate petabyte-scale data without
petabytes.
"""

from __future__ import annotations

import hashlib
import math
import os
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.data import columnar
from repro.data.columnar import (
    EncodedColumn,
    Partition,
    read_partition,
    write_partition,
)
from repro.data.synth import SyntheticRecSysSource


# ---------------------------------------------------------------------------
# Storage fault domain: typed I/O faults + the seeded injector
#
# PreSto's preprocessing lives IN the storage layer, so device read errors,
# torn blocks, and offline devices are the system's primary failure domain
# (Meta's DSI characterization: production ingestion survives constant
# partial storage failures).  The exceptions below are the vocabulary the
# claim-path recovery policy (core.service) speaks: `retryable` faults are
# re-queued with backoff, a DeviceOfflineError additionally re-routes the
# partition through the host-fallback replica path, and a partition that
# keeps failing past its poison budget is quarantined with a structured
# SessionError instead of hanging the iterator.


class IoFaultError(RuntimeError):
    """Base of all storage-domain I/O faults.

    ``retryable`` tells the claim-path policy whether re-reading can ever
    succeed (a torn DMA: yes; verified at-rest corruption: no — retrying
    the same bytes fails identically, so quarantine immediately)."""

    def __init__(
        self,
        message: str,
        *,
        pid: Optional[int] = None,
        device: Optional[int] = None,
        retryable: bool = True,
    ):
        super().__init__(message)
        self.pid = pid
        self.device = device
        self.retryable = retryable


class TransientReadError(IoFaultError):
    """A read failed in a way that a retry can fix (bus hiccup, timeout)."""


class CorruptPartitionError(IoFaultError):
    """A partition read failed end-to-end integrity verification."""


class CorruptBlockError(IoFaultError):
    """A spilled cache block failed integrity verification."""


class DeviceOfflineError(IoFaultError):
    """The partition's owning device is offline; failover is the fix."""


class IoFaultInjector:
    """Seeded, deterministic I/O fault injection for the storage layer.

    Composes with ``ctrlplane.FailureInjector`` (worker crashes) to cover
    the data-fault half of the chaos story: transient read errors, torn
    (bit-flipped) partition reads, corrupt-at-rest spill blocks, slow reads,
    and whole-device-offline.  Attach one to a ``PartitionedStore`` and/or a
    ``CacheSpillStore``; with no injector attached the hot paths are
    untouched.

    Determinism: every fault decision hashes ``(seed, op, ident, attempt)``
    — NOT a shared RNG — so the decision for a given read attempt is
    independent of thread interleaving, and the same seed replays the same
    fault schedule under the virtual-clock sim engine.  Per-ident attempt
    counters advance under a lock, so retries of the same partition see
    fresh rolls and a transient fault eventually clears.

    ``offline_device``/``offline_after`` model one whole device going dark:
    the trigger fires once when the total partition-read count reaches
    ``offline_after`` (the ``FailureInjector`` fire-once idiom), marks the
    fleet device ``offline`` and fails every read of its partitions until
    the claim path grants failover (``PartitionedStore.allow_failover``).

    ``events`` is the duck-typed EventLog hook (``emit(kind, **data)``) —
    this module never imports ``core``; ``sleep`` is injectable so
    virtual-time runs pass ``VirtualClock.sleep`` and slow-read faults
    advance modeled time instead of blocking a real thread.
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        transient: float = 0.0,
        corrupt: float = 0.0,
        spill: float = 0.0,
        slow: float = 0.0,
        slow_s: float = 1e-3,
        offline_device: Optional[int] = None,
        offline_after: Optional[int] = None,
        sleep: Optional[Callable[[float], None]] = None,
        events: Any = None,
    ):
        assert 0.0 <= transient <= 1.0 and 0.0 <= corrupt <= 1.0
        assert 0.0 <= spill <= 1.0 and 0.0 <= slow <= 1.0
        self.seed = int(seed)
        self.transient = float(transient)
        self.corrupt = float(corrupt)
        self.spill = float(spill)
        self.slow = float(slow)
        self.slow_s = float(slow_s)
        self.offline_device = offline_device
        self.offline_after = offline_after
        self.sleep = sleep if sleep is not None else time.sleep
        self.events = events
        self._lock = threading.Lock()
        self._attempts: Dict[tuple, int] = {}  # (op, ident) -> attempt count
        self._reads = 0  # total partition reads (the offline trigger's clock)
        self.offline_devices: set[int] = set()
        self.injected: Dict[str, int] = {}  # fault kind -> count

    # -- plumbing --------------------------------------------------------------
    def _roll(self, op: str, ident, attempt: int) -> float:
        """Uniform [0, 1) decision value for one (op, ident, attempt)."""
        h = hashlib.sha256(
            f"{self.seed}:{op}:{ident}:{attempt}".encode()
        ).digest()
        return int.from_bytes(h[:8], "big") / float(1 << 64)

    def _next_attempt(self, op: str, ident) -> int:
        with self._lock:
            n = self._attempts.get((op, ident), 0) + 1
            self._attempts[(op, ident)] = n
            return n

    def _count(self, fault: str) -> None:
        with self._lock:
            self.injected[fault] = self.injected.get(fault, 0) + 1

    def _emit(self, kind: str, **data) -> None:
        ev = self.events
        if ev is None:
            return
        try:
            ev.emit(kind, **data)
        except Exception:
            pass  # a broken observer never breaks the data path

    # -- partition reads -------------------------------------------------------
    def on_partition_read(self, store: "PartitionedStore", pid: int) -> int:
        """Pre-read hook: offline / slow / transient faults.  Returns the
        attempt number (the corrupt roll's salt).  Raises on injected
        failure — the store never performs the read."""
        with self._lock:
            self._reads += 1
            reads = self._reads
            attempt = self._attempts.get(("part", pid), 0) + 1
            self._attempts[("part", pid)] = attempt
        if (
            self.offline_device is not None
            and self.offline_after is not None
            and reads >= self.offline_after
        ):
            with self._lock:
                newly = self.offline_device not in self.offline_devices
                if newly:
                    self.offline_devices.add(self.offline_device)
            if newly:
                if store.fleet is not None and 0 <= self.offline_device < len(
                    store.fleet
                ):
                    store.fleet[self.offline_device].offline = True
                self._count("device_offline")
                self._emit(
                    "device_offline",
                    device=self.offline_device,
                    after_reads=self.offline_after,
                )
        dev = store.owner_of(pid)
        if dev in self.offline_devices and not store.is_failover(pid):
            self._count("offline_read")
            self._emit("io_fault", fault="device_offline", pid=pid, device=dev)
            raise DeviceOfflineError(
                f"device {dev} is offline (partition {pid})",
                pid=pid, device=dev,
            )
        if self.slow > 0 and self._roll("slow", pid, attempt) < self.slow:
            self._count("slow_read")
            self._emit(
                "io_fault", fault="slow_read", pid=pid, attempt=attempt,
                delay_s=self.slow_s,
            )
            if self.slow_s > 0:
                self.sleep(self.slow_s)
        if self.transient > 0 and self._roll("transient", pid, attempt) < (
            self.transient
        ):
            self._count("transient")
            self._emit(
                "io_fault", fault="transient", pid=pid, device=dev,
                attempt=attempt,
            )
            raise TransientReadError(
                f"transient read error on partition {pid} "
                f"(device {dev}, attempt {attempt})",
                pid=pid, device=dev,
            )
        return attempt

    def maybe_corrupt_partition(
        self, pid: int, part: Partition, attempt: int
    ) -> Partition:
        """Torn-read model: with probability ``corrupt``, return a COPY of
        the partition with one page word bit-flipped.  The authoritative
        content (file / source) stays clean, so a retry can succeed; the
        store's digest verification catches the flip, so the corrupt copy is
        never delivered."""
        if self.corrupt <= 0 or self._roll("corrupt", pid, attempt) >= (
            self.corrupt
        ):
            return part
        bad = Partition(
            part.partition_id,
            part.schema,
            {
                n: EncodedColumn(c.schema, dict(c.pages))
                for n, c in part.columns.items()
            },
        )
        for cname in sorted(bad.columns):
            col = bad.columns[cname]
            for pname in sorted(col.pages):
                words = col.pages[pname]
                if words.size == 0:
                    continue
                flipped = np.array(words, dtype=np.uint32)
                flipped[attempt % flipped.size] ^= np.uint32(0xFFFFFFFF)
                flipped.setflags(write=False)
                col.pages[pname] = flipped
                self._count("corrupt")
                self._emit(
                    "io_fault", fault="corrupt", pid=pid, attempt=attempt,
                    page=f"{cname}/{pname}",
                )
                return bad
        return part

    # -- spill blocks ----------------------------------------------------------
    def on_spill_read(self, key: str) -> bool:
        """True → fail this spill read (the caller treats it as a miss and
        recomputes cold — latency, never wrong bytes)."""
        if self.transient <= 0:
            return False
        attempt = self._next_attempt("spillr", key)
        if self._roll("spill_transient", key, attempt) < self.transient:
            self._count("spill_transient")
            self._emit(
                "io_fault", fault="spill_transient", key=key, attempt=attempt
            )
            return True
        return False

    def maybe_corrupt_spill(
        self, key: str, arrays: Dict[str, np.ndarray]
    ) -> Dict[str, np.ndarray]:
        """Corrupt-at-rest model: with probability ``spill``, flip one byte
        of one stored array (a copy).  The block's write-time checksum is
        computed over the CLEAN arrays, so the next read detects the damage,
        drops the block, and recomputes."""
        attempt = self._next_attempt("spillw", key)
        if self.spill <= 0 or self._roll("spill_corrupt", key, attempt) >= (
            self.spill
        ):
            return arrays
        bad = dict(arrays)
        for k in sorted(bad):
            a = np.asarray(bad[k])
            if a.nbytes == 0:
                continue
            raw = bytearray(a.tobytes())
            raw[0] ^= 0xFF
            b = np.frombuffer(bytes(raw), dtype=a.dtype).reshape(a.shape)
            b.setflags(write=False)
            bad[k] = b
            self._count("spill_corrupt")
            self._emit("io_fault", fault="spill_corrupt", key=key, array=k)
            return bad
        return arrays

    def summary(self) -> Dict[str, int]:
        """Injected fault counts by kind (for asserts and reports)."""
        with self._lock:
            return dict(self.injected)


def parse_iofault_spec(spec: str) -> IoFaultInjector:
    """Build an ``IoFaultInjector`` from a compact CLI spec string.

    Comma-separated knobs, e.g.::

        transient=0.2,corrupt=0.1,spill=0.3,slow=0.05:0.01,offline=2@6,seed=7

    - ``transient=P``  transient read-error probability per attempt
    - ``corrupt=P``    torn (bit-flipped) partition read probability
    - ``spill=P``      corrupt-at-rest probability per spilled block write
    - ``slow=P[:S]``   slow-read probability, each costing S seconds (1 ms)
    - ``offline=D@N``  device D goes offline at the Nth partition read
    - ``seed=K``       fault-schedule seed (default 0)
    """
    kw: Dict[str, Any] = {}
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        k, sep, v = item.partition("=")
        if not sep:
            raise ValueError(f"io-fault knob {item!r} wants KEY=VALUE")
        k, v = k.strip(), v.strip()
        if k in ("transient", "corrupt", "spill"):
            kw[k] = float(v)
        elif k == "slow":
            rate, _, secs = v.partition(":")
            kw["slow"] = float(rate)
            if secs:
                kw["slow_s"] = float(secs)
        elif k == "offline":
            dev, sep2, after = v.partition("@")
            if not sep2:
                raise ValueError(f"offline wants DEV@N, got {v!r}")
            kw["offline_device"] = int(dev)
            kw["offline_after"] = int(after)
        elif k == "seed":
            kw["seed"] = int(v)
        else:
            raise ValueError(f"unknown io-fault knob {k!r} in {spec!r}")
    return IoFaultInjector(**kw)


class IspDevice:
    """One simulated in-storage processing unit: the schedulable resource.

    A device has an identity, rate budgets (SSD->FPGA stream rate, ISP compute
    roofline — defaults mirror ``core.costmodel.PlacementCostModel``), and an
    occupancy ledger.  Everything that touches the device charges the SAME
    ledger: partition reads (``PartitionedStore.read``), spill-tier traffic
    (``CacheSpillStore``), and ISP-routed Transform compute
    (``core.service``) all contend for the one modeled unit.  ``busy_s``
    serializes stream and compute seconds (a SmartSSD's FPGA streams pages,
    then runs the chain), which is the pessimistic end of the roofline the
    cost model prices with ``max(...)`` — good enough to rank devices.

    ``queue_depth`` is the scheduling signal: partitions bound to this device
    that have not yet completed (or been offloaded to a host worker).  The
    locality-aware claim path reads it live to decide host fallback.
    Thread-safe; counters are read without the lock (point-in-time reads of
    ints are fine for scheduling heuristics).
    """

    def __init__(
        self,
        device_id: int,
        *,
        stream_bytes_per_s: float = 8e9,
        compute_ops_per_s: float = 5e9,
    ):
        self.device_id = device_id
        self.stream_bytes_per_s = stream_bytes_per_s
        self.compute_ops_per_s = compute_ops_per_s
        self._lock = threading.Lock()
        self.bytes_streamed = 0  # partition reads + spill blocks, one stream
        self.spill_bytes = 0  # subset of bytes_streamed owed to the cache tier
        self.compute_ops = 0.0  # ISP-routed Transform ops run on this unit
        self.busy_s = 0.0  # modeled occupancy: stream + compute, serialized
        self.spill_io_s = 0.0  # subset of busy_s owed to the spill tier
        self.queue_depth = 0  # bound partitions not yet completed/offloaded
        self.inflight = 0  # claims executing on this unit right now
        self.max_inflight = 0  # high-water mark of `inflight`
        self.isp_claims = 0  # claims produced here (locality or blind)
        self.host_fallbacks = 0  # claims this device shed to the host path
        # Fault domain: an offline device serves NO reads or compute — the
        # IoFaultInjector sets this at its trigger, and the claim path
        # re-routes the device's partitions through the host-fallback
        # replica path (PartitionedStore.allow_failover).
        self.offline = False
        # Virtual-time occupancy (core.simclock): the instant this unit next
        # becomes idle.  Wall-clock paths never touch it; the discrete-event
        # engine reserves the unit through `reserve`, which both advances
        # free_at and charges the same busy_s ledger the wall-clock paths
        # charge — so a simulated schedule and a threaded run of the same
        # work agree on total device seconds.
        self.free_at = 0.0

    # -- ledger ----------------------------------------------------------------
    def charge_stream(self, nbytes: int, *, spill: bool = False) -> float:
        """Move `nbytes` through the SSD->FPGA stream; returns modeled s."""
        dt = nbytes / self.stream_bytes_per_s
        with self._lock:
            self.bytes_streamed += int(nbytes)
            self.busy_s += dt
            if spill:
                self.spill_bytes += int(nbytes)
                self.spill_io_s += dt
        return dt

    def charge_compute(self, ops: float) -> float:
        """Run `ops` abstract Transform ops on the unit; returns modeled s."""
        dt = ops / self.compute_ops_per_s
        with self._lock:
            self.compute_ops += ops
            self.busy_s += dt
        return dt

    # -- virtual-time occupancy ------------------------------------------------
    def reserve(
        self, now: float, service_s: float, *, nbytes: int = 0, ops: float = 0.0
    ) -> tuple:
        """Reserve the unit for ``service_s`` modeled seconds, starting no
        earlier than ``now``: returns ``(start, end)`` with
        ``start = max(now, free_at)`` — the device is busy *in time*, so a
        claim arriving while the unit works waits out the queue.  Charges
        the same ledger counters as the wall-clock ``charge_*`` path (do not
        combine both for one produce)."""
        with self._lock:
            start = max(now, self.free_at)
            end = start + service_s
            self.free_at = end
            self.busy_s += service_s
            self.bytes_streamed += int(nbytes)
            self.compute_ops += ops
            return start, end

    # -- occupancy -------------------------------------------------------------
    def enqueue(self, n: int = 1) -> None:
        """`n` more partitions are bound to this device (backlog grows)."""
        with self._lock:
            self.queue_depth += n

    def dequeue(self, n: int = 1) -> None:
        """`n` bound partitions completed or were offloaded to the host."""
        with self._lock:
            self.queue_depth = max(0, self.queue_depth - n)

    def shed(self) -> None:
        """One bound partition was offloaded to the host path."""
        with self._lock:
            self.host_fallbacks += 1

    def begin_claim(self) -> None:
        with self._lock:
            self.inflight += 1
            self.max_inflight = max(self.max_inflight, self.inflight)
            self.isp_claims += 1

    def end_claim(self) -> None:
        with self._lock:
            self.inflight = max(0, self.inflight - 1)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return {
                "device": self.device_id,
                "busy_s": self.busy_s,
                "queue_depth": self.queue_depth,
                "inflight": self.inflight,
                "max_inflight": self.max_inflight,
                "isp_claims": self.isp_claims,
                "host_fallbacks": self.host_fallbacks,
                "offline": self.offline,
                "bytes_streamed": self.bytes_streamed,
                "spill_bytes": self.spill_bytes,
                "compute_ops": self.compute_ops,
                "spill_io_s": self.spill_io_s,
            }

    def __repr__(self) -> str:  # pragma: no cover - debugging sugar
        return (
            f"IspDevice({self.device_id}, busy={self.busy_s * 1e3:.2f}ms, "
            f"queue={self.queue_depth})"
        )


class DeviceFleet:
    """The shared registry of simulated ISP devices, plus the host ledger.

    One fleet object is threaded through every layer that touches devices —
    ``PartitionedStore`` (partition reads), ``CacheSpillStore`` (spill
    traffic), and ``core.service.PreprocessingService`` (claim routing,
    compute charges) — so contention is modeled against one shared set of
    ledgers rather than per-layer copies.  Host-fallback produces charge the
    fleet-level host ledger: encoded pages + train-ready tensors cross the
    link, and the chain runs at host compute rate.
    """

    def __init__(
        self,
        num_devices: int = 4,
        *,
        stream_bytes_per_s: float = 8e9,
        compute_ops_per_s: float = 5e9,
        link_bytes_per_s: float = 3e9,
        host_ops_per_s: float = 100e9,
    ):
        assert num_devices >= 1
        self.devices = [
            IspDevice(
                d,
                stream_bytes_per_s=stream_bytes_per_s,
                compute_ops_per_s=compute_ops_per_s,
            )
            for d in range(num_devices)
        ]
        self.link_bytes_per_s = link_bytes_per_s
        self.host_ops_per_s = host_ops_per_s
        self._lock = threading.Lock()
        self.host_busy_s = 0.0  # link transfer + host compute, serialized
        self.host_link_bytes = 0
        self.host_ops = 0.0
        self.host_produces = 0
        # Virtual-time host occupancy: one free_at instant per provisioned
        # host worker slot (lazily sized by `reserve_host`'s parallelism).
        self._host_free_at: List[float] = []

    @classmethod
    def from_cost_model(cls, num_devices: int, model) -> "DeviceFleet":
        """Budgets taken from a ``core.costmodel.PlacementCostModel`` (duck-
        typed so this module never imports the cost model)."""
        return cls(
            num_devices,
            stream_bytes_per_s=model.isp_stream_bytes_per_s,
            compute_ops_per_s=model.isp_ops_per_s,
            link_bytes_per_s=model.link_bytes_per_s,
            host_ops_per_s=model.host_ops_per_s,
        )

    def __len__(self) -> int:
        return len(self.devices)

    def __getitem__(self, device_id: int) -> IspDevice:
        return self.devices[device_id]

    def __iter__(self):
        return iter(self.devices)

    def charge_host(self, link_bytes: int, ops: float) -> float:
        """One host-fallback produce: pages in + tensors out over the link,
        chain at host compute rate.  Returns modeled seconds."""
        dt = link_bytes / self.link_bytes_per_s + ops / self.host_ops_per_s
        with self._lock:
            self.host_busy_s += dt
            self.host_link_bytes += int(link_bytes)
            self.host_ops += ops
            self.host_produces += 1
        return dt

    def reserve_host(
        self,
        now: float,
        service_s: float,
        *,
        link_bytes: int = 0,
        ops: float = 0.0,
        parallelism: int = 1,
    ) -> tuple:
        """Virtual-time twin of ``charge_host``: reserve the earliest-free of
        ``parallelism`` host worker slots for ``service_s`` modeled seconds
        starting no earlier than ``now``; returns ``(start, end)``.  Ledger
        counters are charged exactly as ``charge_host`` would (do not call
        both for one produce).  Slot choice is deterministic: the lowest-
        indexed slot among the earliest free."""
        with self._lock:
            while len(self._host_free_at) < max(parallelism, 1):
                self._host_free_at.append(0.0)
            slot = min(
                range(max(parallelism, 1)), key=lambda i: self._host_free_at[i]
            )
            start = max(now, self._host_free_at[slot])
            end = start + service_s
            self._host_free_at[slot] = end
            self.host_busy_s += service_s
            self.host_link_bytes += int(link_bytes)
            self.host_ops += ops
            self.host_produces += 1
            return start, end

    def utilization(self) -> List[Dict[str, float]]:
        return [d.snapshot() for d in self.devices]

    def max_busy_s(self) -> float:
        return max(d.busy_s for d in self.devices)

    def makespan_s(self, host_parallelism: int = 1) -> float:
        """Modeled end-to-end seconds: each device serializes its own ledger;
        host work parallelizes across `host_parallelism` provisioned host
        workers.  The bottleneck resource is the makespan."""
        return max(self.max_busy_s(), self.host_busy_s / max(host_parallelism, 1))


def zipf_owner_map(
    num_partitions: int, num_devices: int, alpha: float, seed: int = 0
) -> List[int]:
    """Zipf-skewed partition->device ownership (Meta's ingestion skew).

    Device d's ownership quota follows the Zipf pmf rank^(-alpha) via largest
    remainder (exact counts, never a lucky uniform draw), then the assignment
    order is shuffled deterministically by `seed` so contiguous pid ranges
    don't all land on one device.  alpha=0 degenerates to uniform quotas.
    """
    assert num_partitions >= 1 and num_devices >= 1
    ranks = np.arange(1, num_devices + 1, dtype=np.float64)
    w = ranks ** -float(alpha)
    w /= w.sum()
    quotas = w * num_partitions
    counts = [math.floor(q) for q in quotas]
    rema = sorted(
        range(num_devices), key=lambda d: quotas[d] - counts[d], reverse=True
    )
    for d in rema[: num_partitions - sum(counts)]:
        counts[d] += 1
    owners = [d for d in range(num_devices) for _ in range(counts[d])]
    rng = np.random.default_rng(seed)
    rng.shuffle(owners)
    return [int(d) for d in owners]


class PartitionedStore:
    def __init__(
        self,
        num_partitions: int,
        num_devices: int,
        source: Optional[SyntheticRecSysSource] = None,
        root: Optional[str] = None,
        placement: str = "presto",
        *,
        fleet: Optional[DeviceFleet] = None,
        owner_map: Optional[Sequence[int]] = None,
        fault_injector: Optional[IoFaultInjector] = None,
    ):
        assert placement in ("presto", "disagg")
        if fleet is not None:
            assert num_devices == len(fleet), (
                f"num_devices={num_devices} but the shared fleet has "
                f"{len(fleet)} device(s)"
            )
        self.num_partitions = num_partitions
        self.num_devices = num_devices
        self.source = source
        self.root = root
        self.placement = placement
        self.fleet = fleet  # shared ledgers: reads charge the owning device
        if owner_map is not None:
            owner_map = [int(d) for d in owner_map]
            assert len(owner_map) == num_partitions, (
                f"owner_map covers {len(owner_map)} of {num_partitions} "
                "partitions"
            )
            assert all(0 <= d < num_devices for d in owner_map)
        self.owner_map = owner_map
        self._read_bytes = 0
        self._logical_read_bytes = 0
        # pid -> (stat signature | None, fingerprint); guarded by _fp_lock
        self._fp_cache: Dict[int, tuple] = {}
        # pid -> (stat signature, (fingerprints, refs) | None); file-backed
        # dedup metadata only (source-backed derivation is cheap every call)
        self._blockfp_cache: Dict[int, tuple] = {}
        self._fp_lock = threading.Lock()
        # Fault domain: with an injector attached, every read is verified
        # against the trusted content digest below before delivery; pids in
        # _failover read through the host/replica path (their owning device
        # is offline) and charge the fleet's host-link ledger instead.
        self.fault_injector = fault_injector
        self._failover: set[int] = set()
        self._digest_cache: Dict[int, str] = {}  # pid -> trusted digest

    # -- ownership -----------------------------------------------------------
    def owner_of(self, partition_id: int) -> int:
        """Storage device that holds this partition.  Round-robin by default;
        an explicit ``owner_map`` expresses skewed placements (hot devices own
        disproportionately many partitions — the contention the device-aware
        scheduler manages).  Ownership never changes partition CONTENT: the
        same pid yields the same bytes under any map."""
        if self.owner_map is not None:
            return self.owner_map[partition_id]
        return partition_id % self.num_devices

    def device_of(self, partition_id: int) -> Optional[IspDevice]:
        """The owning ``IspDevice`` when a shared fleet is attached."""
        if self.fleet is None:
            return None
        return self.fleet[self.owner_of(partition_id)]

    def partitions_of(self, device: int) -> List[int]:
        return [
            pid for pid in range(self.num_partitions) if self.owner_of(pid) == device
        ]

    # -- I/O -------------------------------------------------------------------
    def materialize(self, partition_ids: Iterable[int]) -> None:
        """Write partitions to disk (one columnar file each)."""
        assert self.root and self.source
        os.makedirs(self.root, exist_ok=True)
        for pid in partition_ids:
            path = self._path(pid)
            if not os.path.exists(path):
                write_partition(path, self.source.partition(pid))

    def read(self, partition_id: int) -> Partition:
        inj = self.fault_injector
        if inj is None:
            part = self._read_raw(partition_id)
            self._account_read(
                partition_id, part.nbytes(), part.logical_nbytes()
            )
            return part
        # fault-injected read: pre-read faults (offline/slow/transient) may
        # raise before any bytes move; the clean read then pins the trusted
        # digest; a torn-read corruption lands on a COPY and is caught by
        # verification — a corrupt partition is never returned, only raised.
        attempt = inj.on_partition_read(self, partition_id)
        try:
            part = self._read_raw(partition_id)
        except columnar.CorruptPartitionFile as e:
            # verified at-rest corruption: retrying the same bytes fails
            # identically, so surface it non-retryable (quarantine fast)
            raise CorruptPartitionError(
                str(e), pid=partition_id,
                device=self.owner_of(partition_id), retryable=False,
            ) from e
        self._account_read(partition_id, part.nbytes(), part.logical_nbytes())
        want = self.content_digest(partition_id, part)
        delivered = inj.maybe_corrupt_partition(partition_id, part, attempt)
        if delivered is not part:
            got = columnar.partition_digest(delivered)
            if got != want:
                raise CorruptPartitionError(
                    f"partition {partition_id} failed integrity verification "
                    f"(want {want}, got {got}, attempt {attempt})",
                    pid=partition_id, device=self.owner_of(partition_id),
                )
        return delivered

    def _read_raw(self, partition_id: int) -> Partition:
        """The unverified read: disk file wins, else the synthetic source."""
        if self.root is not None:
            path = self._path(partition_id)
            if os.path.exists(path):
                return read_partition(path)
        assert self.source is not None, "no disk file and no synthetic source"
        return self.source.partition(partition_id)

    def content_digest(
        self, partition_id: int, part: Optional[Partition] = None
    ) -> str:
        """Trusted write-time digest of one partition's page content.

        Pinned on first computation (the clean read, or write time via an
        explicit call) and compared against every subsequent delivered read
        when a fault injector is attached — the end-to-end integrity anchor.
        Pass ``part`` when the clean partition is already in hand to avoid
        a second read."""
        with self._fp_lock:
            hit = self._digest_cache.get(partition_id)
        if hit is not None:
            return hit
        if part is None:
            part = self._read_raw(partition_id)
        d = columnar.partition_digest(part)
        with self._fp_lock:
            self._digest_cache[partition_id] = d
        return d

    # -- failover --------------------------------------------------------------
    def allow_failover(self, partition_id: int) -> None:
        """Grant replica reads for one partition of an offline device: its
        reads stop raising ``DeviceOfflineError`` and charge the fleet's
        host-link ledger (the replica crosses the link) instead of the dark
        device.  Content is unchanged — same pid, same bytes, still
        digest-verified."""
        with self._fp_lock:
            self._failover.add(partition_id)

    def is_failover(self, partition_id: int) -> bool:
        with self._fp_lock:
            return partition_id in self._failover

    @property
    def failover_partitions(self) -> List[int]:
        with self._fp_lock:
            return sorted(self._failover)

    def _account_read(
        self, partition_id: int, nbytes: int, logical_nbytes: int | None = None
    ) -> None:
        """Every partition read streams off its OWNING device: charge that
        device's shared ledger (when a fleet is attached) so reads contend
        with ISP compute and cache spills for the same modeled bandwidth.

        ``nbytes`` is the partition's STORED size — for dedup partitions the
        unique block bytes (``Partition.nbytes``), which is exactly what the
        device streams; ``logical_nbytes`` rides along for the savings
        report (``logical_bytes_read - bytes_read`` = bytes dedup kept off
        the devices).  Failover reads (owning device offline) pull the
        replica over the host link instead."""
        self._read_bytes += nbytes
        self._logical_read_bytes += (
            logical_nbytes if logical_nbytes is not None else nbytes
        )
        if self.fleet is not None:
            if self.is_failover(partition_id):
                self.fleet.charge_host(nbytes, 0.0)
            else:
                self.fleet[self.owner_of(partition_id)].charge_stream(nbytes)

    @property
    def bytes_read(self) -> int:
        return self._read_bytes

    @property
    def logical_bytes_read(self) -> int:
        """Bytes the same reads would have streamed without dedup."""
        return self._logical_read_bytes

    # -- content identity ------------------------------------------------------
    def partition_fingerprint(self, partition_id: int) -> str:
        """Content-addressed identity of one partition's encoded bytes.

        Mirrors ``read()``'s precedence exactly: when a disk file exists it
        IS the content (read() serves its bytes even on a sourced store), so
        the fingerprint hashes the file bytes, revalidated against the
        file's (mtime, size) so a rewritten partition never serves a stale
        cache key.  Only fileless partitions fall back to the source's
        deterministic (cfg, rows, seed, pid) identity.  Equal fingerprint ⇒
        equal bytes, always — a mismatch between tenants can only cost a
        missed dedup, never a wrong batch.  This is the ``partition
        fingerprint`` component of a feature-cache key."""
        path = self._path(partition_id) if self.root is not None else None
        if path is not None and os.path.exists(path):
            st = os.stat(path)
            sig = (st.st_mtime_ns, st.st_size)
            with self._fp_lock:
                hit = self._fp_cache.get(partition_id)
            if hit is not None and hit[0] == sig:
                return hit[1]
            h = hashlib.sha256()
            with open(path, "rb") as f:
                for chunk in iter(lambda: f.read(1 << 20), b""):
                    h.update(chunk)
            fp = h.hexdigest()[:16]
            with self._fp_lock:
                self._fp_cache[partition_id] = (sig, fp)
            return fp
        assert self.source is not None, "no disk file and no synthetic source"
        with self._fp_lock:
            hit = self._fp_cache.get(partition_id)
        if hit is not None and hit[0] is None:
            return hit[1]
        fp = hashlib.sha256(
            f"{self.source.fingerprint()}:{partition_id}".encode()
        ).hexdigest()[:16]
        with self._fp_lock:
            self._fp_cache[partition_id] = (None, fp)
        return fp

    def block_fingerprints(self, partition_id: int) -> Optional[List[str]]:
        """Content identity of each unique sparse block (dedup datasets).

        None for classic (dup-factor-1) data.  Mirrors ``read()``'s file vs
        source precedence like ``partition_fingerprint``: a disk file's
        blocks hash their decoded content (``columnar.block_fingerprints``,
        cached against the file's stat signature); fileless partitions use
        the source's deterministic identity — ``(source fp, pool id)`` when
        blocks come from a dataset-level pool (``RMDataConfig.dup_pool``, the
        cross-partition overlap case) else ``(source fp, pid, block idx)`` —
        with no content generation at probe time.  Equal fingerprint ⇒ equal
        decoded block, always; the two derivations never match each other,
        which can only cost a missed block-cache dedup, never a wrong batch.
        """
        meta = self._block_meta(partition_id)
        return meta[0] if meta is not None else None

    def block_refs(self, partition_id: int) -> Optional[np.ndarray]:
        """The (rows,) unique-block reference vector (dedup datasets), else
        None.  Same file/source precedence (and cache) as
        ``block_fingerprints`` — the publish side of the block cache slices
        a produced batch with these."""
        meta = self._block_meta(partition_id)
        return meta[1] if meta is not None else None

    def _block_meta(self, partition_id: int):
        """(fingerprints, refs) of one dedup partition, or None (classic)."""
        path = self._path(partition_id) if self.root is not None else None
        if path is not None and os.path.exists(path):
            st = os.stat(path)
            sig = (st.st_mtime_ns, st.st_size)
            with self._fp_lock:
                hit = self._blockfp_cache.get(partition_id)
            if hit is not None and hit[0] == sig:
                return hit[1]
            part = read_partition(path)  # metadata derivation: not a
            # modeled data-path read, like partition_fingerprint's file hash
            fps = columnar.block_fingerprints(part)
            meta = (
                (fps, columnar.partition_refs(part)) if fps is not None else None
            )
            with self._fp_lock:
                self._blockfp_cache[partition_id] = (sig, meta)
            return meta
        assert self.source is not None, "no disk file and no synthetic source"
        src = self.source
        if getattr(src.cfg, "dup_factor", 1) <= 1:
            return None
        src_fp = src.fingerprint()
        refs = src.block_refs(partition_id)
        pool_ids = src.block_pool_ids(partition_id)
        if pool_ids is not None:
            fps = [
                hashlib.sha256(f"{src_fp}:pool:{int(p)}".encode())
                .hexdigest()[:16]
                for p in pool_ids
            ]
        else:
            n_unique = src.rows // src.cfg.dup_factor
            fps = [
                hashlib.sha256(f"{src_fp}:{partition_id}:{b}".encode())
                .hexdigest()[:16]
                for b in range(n_unique)
            ]
        return fps, refs

    def _path(self, pid: int) -> str:
        # deviceNN/ prefix models per-device directories of the storage array
        assert self.root is not None
        dev = self.owner_of(pid)
        ddir = os.path.join(self.root, f"device{dev:03d}")
        os.makedirs(ddir, exist_ok=True)
        return os.path.join(ddir, f"part{pid:06d}.rp")


class CacheSpillStore:
    """Spill tier for the preprocessed-feature cache, on the simulated devices.

    Blocks evicted from the cache's in-memory LRU tier land here: each block
    (one train-ready mini-batch, as numpy arrays) is assigned to a simulated
    storage device by key hash, mirroring ``PartitionedStore``'s per-device
    ownership.  Residency is charged to the same byte-movement cost model as
    ISP placement — every write and read accrues ``bytes / bytes_per_s``
    modeled seconds (default: the ISP unit's internal SSD->FPGA stream rate,
    ``core.costmodel.PlacementCostModel.isp_stream_bytes_per_s``), so a spill
    hit is cheaper than recompute only when the cost model says so.

    With ``root`` set, blocks live as one ``.npz`` file per block under
    per-device directories (restart-survivable); otherwise they live in
    per-device dicts (pure simulation).  Thread-safe.

    Spilled payloads are row-deduped at rest: integer arrays whose leading-
    axis rows repeat (dedup datasets' ``multi_hot_ids``/``lengths`` repeat
    every session's block) are stored as unique rows + a refs vector when
    that is strictly smaller, and the ledgers are charged only the stored
    (unique) bytes.  Reads expand back before returning — bitwise lossless,
    invisible to callers.
    """

    # key suffixes of a row-deduped spilled array (unique rows / refs);
    # batch keys never carry them
    _DD_BLOCKS = "__ddb"
    _DD_REFS = "__ddr"
    # reserved key of the block's write-time checksum (sha256 over the clean
    # stored arrays); read verifies it, so a corrupt block is detected and
    # dropped — a cache hit is never wrong, a miss only costs recompute
    _CK = "__ck"

    @classmethod
    def _checksum(cls, arrays: Dict[str, np.ndarray]) -> np.ndarray:
        """Canonical content digest of a stored block (names, dtypes,
        shapes, bytes — order-independent), as a (32,) uint8 array so it
        survives the npz round trip."""
        h = hashlib.sha256()
        for k in sorted(arrays):
            if k == cls._CK:
                continue
            a = np.ascontiguousarray(arrays[k])
            h.update(f"{k}:{a.dtype.str}:{a.shape}".encode())
            h.update(a.tobytes())
        return np.frombuffer(h.digest(), dtype=np.uint8).copy()

    @classmethod
    def _dedup_rows(cls, arrays: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Row-dedup eligible arrays for storage (lossless; see class doc)."""
        out: Dict[str, np.ndarray] = {}
        for k, a in arrays.items():
            a = np.asarray(a)
            # integer-only: exact row equality, and that's where dedup
            # datasets repeat (hashed ids / lengths); float rows are noise
            if a.ndim >= 2 and a.shape[0] >= 2 and a.dtype.kind in "iub":
                flat = np.ascontiguousarray(a.reshape(a.shape[0], -1))
                uniq, inv = np.unique(flat, axis=0, return_inverse=True)
                inv = np.ascontiguousarray(inv.reshape(-1).astype(np.int32))
                if uniq.nbytes + inv.nbytes < a.nbytes:
                    out[k + cls._DD_BLOCKS] = uniq.reshape(-1, *a.shape[1:])
                    out[k + cls._DD_REFS] = inv
                    continue
            out[k] = a
        return out

    @classmethod
    def _expand_rows(cls, block: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Inverse of ``_dedup_rows``: rebuild the logical arrays (bitwise)."""
        out: Dict[str, np.ndarray] = {}
        for k, a in block.items():
            if k.endswith(cls._DD_REFS):
                continue
            if k.endswith(cls._DD_BLOCKS):
                base = k[: -len(cls._DD_BLOCKS)]
                full = a[block[base + cls._DD_REFS]]
                full.setflags(write=False)
                out[base] = full
            else:
                out[k] = a
        return out

    def __init__(
        self,
        num_devices: int = 4,
        *,
        capacity_bytes: Optional[int] = None,
        bytes_per_s: float = 8e9,
        root: Optional[str] = None,
        fleet: Optional[DeviceFleet] = None,
    ):
        assert num_devices >= 1
        if fleet is not None:
            assert num_devices == len(fleet), (
                f"num_devices={num_devices} but the shared fleet has "
                f"{len(fleet)} device(s)"
            )
        self.num_devices = num_devices
        self.capacity_bytes = capacity_bytes
        self.bytes_per_s = bytes_per_s
        self.root = root
        self.fleet = fleet  # spill traffic contends on the shared ledgers
        self._devices: List[Dict[str, Dict[str, np.ndarray]]] = [
            {} for _ in range(num_devices)
        ]
        self._sizes: Dict[str, int] = {}  # key -> block bytes (insertion order)
        self._resident = 0  # running sum of _sizes values
        self._lock = threading.Lock()
        self.bytes_written = 0
        self.bytes_read = 0
        self.modeled_io_s = 0.0
        # Fault domain: `events` is the duck-typed EventLog hook (wired by
        # the service before warm_start so boot-time corruption is visible);
        # `fault_injector` corrupts blocks at rest / fails reads; corrupt
        # blocks found on read are dropped + counted here, never served.
        self.events: Any = None
        self.fault_injector: Optional[IoFaultInjector] = None
        self.corrupt_drops = 0
        # per-owning-device modeled seconds: spill residency is DEVICE work,
        # so a hot device's cache traffic shows up on ITS ledger, not a
        # global pot (the global modeled_io_s stays as the aggregate)
        self.io_s_by_device: List[float] = [0.0] * num_devices
        if root is not None:
            self._rescan()

    def owner_of(self, key: str) -> int:
        return int(hashlib.sha256(key.encode()).hexdigest()[:8], 16) % self.num_devices

    def _charge(self, key: str, nbytes: int) -> None:
        """Charge one block movement to the OWNING device (caller holds no
        lock ordering obligations: device ledgers use their own locks)."""
        dev = self.owner_of(key)
        if self.fleet is not None:
            dt = self.fleet[dev].charge_stream(nbytes, spill=True)
        else:
            dt = nbytes / self.bytes_per_s
        with self._lock:
            self.modeled_io_s += dt
            self.io_s_by_device[dev] += dt

    def keys(self) -> List[str]:
        """Resident block keys, oldest first (insertion/rescan order)."""
        with self._lock:
            return list(self._sizes)

    def _rescan(self) -> None:
        """Rebuild the residency index from blocks that survived a restart.

        Blocks live one ``.npz`` per key under per-device directories; after
        a process restart the in-memory index is empty even though the bytes
        are still on the simulated devices.  Rescanning (oldest mtime first,
        so eviction order survives too) is what makes the feature cache's
        warm start possible.  Sizes are file sizes — close enough to the
        original array bytes for capacity and charging purposes."""
        assert self.root is not None
        if not os.path.isdir(self.root):
            return
        found = []
        for d in range(self.num_devices):
            ddir = os.path.join(self.root, f"device{d:03d}")
            if not os.path.isdir(ddir):
                continue
            for fn in os.listdir(ddir):
                if not (fn.startswith("cache_") and fn.endswith(".npz")):
                    continue
                key = fn[len("cache_"):-len(".npz")]
                try:
                    st = os.stat(os.path.join(ddir, fn))
                except OSError:
                    continue
                found.append((st.st_mtime_ns, key, st.st_size))
        with self._lock:
            for _, key, size in sorted(found):
                if key in self._sizes:
                    continue
                self._sizes[key] = size
                self._resident += size

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._resident

    def __len__(self) -> int:
        with self._lock:
            return len(self._sizes)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._sizes

    def _block_path(self, key: str) -> str:
        assert self.root is not None
        ddir = os.path.join(self.root, f"device{self.owner_of(key):03d}")
        os.makedirs(ddir, exist_ok=True)
        return os.path.join(ddir, f"cache_{key}.npz")

    def write(self, key: str, arrays: Dict[str, np.ndarray]) -> int:
        """Spill one block; returns its size in bytes.  Oldest blocks are
        dropped when a capacity bound is set (the spill tier is a cache of a
        cache — recompute is always available underneath)."""
        def frozen(v: np.ndarray) -> np.ndarray:
            # blocks are served to many tenants: never mutable.  A read-only
            # VIEW leaves the caller's own array untouched, zero-copy.
            a = np.asarray(v)
            if a.flags.writeable:
                a = a.view()
                a.setflags(write=False)
            return a

        arrays = self._dedup_rows({k: frozen(v) for k, v in arrays.items()})
        nbytes = sum(int(a.nbytes) for a in arrays.values())
        # checksum over the CLEAN arrays, stored alongside: survives process
        # restarts inside the npz, so warm_start rescans verify too.  An
        # injector corrupts the STORED copy only — the checksum stays
        # honest, which is exactly what lets the next read detect it.
        ck = self._checksum(arrays)
        stored = dict(arrays)
        if self.fault_injector is not None:
            stored = self.fault_injector.maybe_corrupt_spill(key, stored)
        stored[self._CK] = ck
        if self.root is not None:
            np.savez(self._block_path(key), **stored)
        dropped: List[str] = []
        with self._lock:
            if self.root is None:
                self._devices[self.owner_of(key)][key] = stored
            old_bytes = self._sizes.pop(key, None)
            if old_bytes is not None:
                self._resident -= old_bytes
            self._sizes[key] = nbytes
            self._resident += nbytes
            self.bytes_written += nbytes
            if self.capacity_bytes is not None:
                while self._resident > self.capacity_bytes and len(self._sizes) > 1:
                    old = next(iter(self._sizes))
                    if old == key:
                        break
                    self._resident -= self._sizes.pop(old)
                    self._devices[self.owner_of(old)].pop(old, None)
                    dropped.append(old)
        self._charge(key, nbytes)
        if self.root is not None:
            for old in dropped:
                try:
                    os.remove(self._block_path(old))
                except OSError:
                    pass
        return nbytes

    def read(self, key: str) -> Optional[Dict[str, np.ndarray]]:
        """Fetch one spilled block (None if absent, unreadable, or corrupt).

        The read bytes are charged to the block's OWNING device's ledger — a
        spill hit promoted back to the memory tier is byte movement on that
        device, contending with its partition reads and ISP compute.

        Integrity: the block's stored checksum is verified before return.  A
        mismatch (or an unreadable npz — torn writes raise anything from
        ``BadZipFile`` to ``EOFError``, not just ``OSError``) drops the
        block from the index AND the device, emits a ``spill_corrupt``
        event, and reads as a miss: the feature cache recomputes cold.  A
        session never sees corrupt bytes from the spill tier, only latency.
        This is also what makes ``FeatureCache.warm_start`` safe: a corrupt
        survivor block is skipped at boot instead of aborting the service."""
        with self._lock:
            nbytes = self._sizes.get(key)
            if nbytes is None:
                return None
        inj = self.fault_injector
        if inj is not None and inj.on_spill_read(key):
            return None  # injected transient: a miss, recompute underneath
        if self.root is None:
            with self._lock:
                stored = self._devices[self.owner_of(key)].get(key)
            if stored is None:
                return None
            block = dict(stored)
        else:
            try:
                with np.load(self._block_path(key)) as z:
                    block = {k: z[k] for k in z.files}
            except FileNotFoundError:
                return None  # evicted between the size check and the load
            except Exception as e:
                self._drop_corrupt(key, f"unreadable: {e!r}")
                return None
            for a in block.values():
                a.setflags(write=False)
        ck = block.pop(self._CK, None)
        if ck is None or not np.array_equal(
            self._checksum(block), np.asarray(ck)
        ):
            self._drop_corrupt(
                key, "checksum missing" if ck is None else "checksum mismatch"
            )
            return None
        with self._lock:
            self.bytes_read += nbytes
        self._charge(key, nbytes)
        return self._expand_rows(block)

    def _drop_corrupt(self, key: str, reason: str) -> None:
        """Evict a block that failed integrity on read.  The spill tier is
        a cache of a cache — recompute is always available underneath, so
        dropping is always safe; the event makes the damage observable."""
        dev = self.owner_of(key)
        with self._lock:
            nbytes = self._sizes.pop(key, None)
            if nbytes is not None:
                self._resident -= nbytes
            self._devices[dev].pop(key, None)
            self.corrupt_drops += 1
        if self.root is not None:
            try:
                os.remove(self._block_path(key))
            except OSError:
                pass
        ev = self.events
        if ev is not None:
            try:
                ev.emit("spill_corrupt", key=key, device=dev, reason=reason)
            except Exception:
                pass  # a broken observer never breaks the read path
