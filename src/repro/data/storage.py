"""Partitioned distributed store: partition ownership + placement.

Models the paper's distributed storage layer (Tectonic-style): every
partition's blocks live contiguously on exactly ONE storage device, which is
the property that lets an ISP unit preprocess a whole mini-batch locally.

Two placements are expressible:

* ``presto``  — partition p is owned by the SAME mesh shard that will consume
  the resulting mini-batch slice.  Preprocessing ⇒ zero redistribution.
* ``disagg``  — partitions are owned by a disjoint "preprocessing pool" slice
  of the mesh; train-ready tensors must be redistributed to the consumers
  (copy-in/copy-out of Fig. 7(b)).

The store can be disk-backed (one file per partition) or generate-on-read
(synthetic source), which is how we simulate petabyte-scale data without
petabytes.
"""

from __future__ import annotations

import hashlib
import os
import threading
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from repro.data.columnar import Partition, read_partition, write_partition
from repro.data.synth import SyntheticRecSysSource


class PartitionedStore:
    def __init__(
        self,
        num_partitions: int,
        num_devices: int,
        source: Optional[SyntheticRecSysSource] = None,
        root: Optional[str] = None,
        placement: str = "presto",
    ):
        assert placement in ("presto", "disagg")
        self.num_partitions = num_partitions
        self.num_devices = num_devices
        self.source = source
        self.root = root
        self.placement = placement
        self._read_bytes = 0
        # pid -> (stat signature | None, fingerprint); guarded by _fp_lock
        self._fp_cache: Dict[int, tuple] = {}
        self._fp_lock = threading.Lock()

    # -- ownership -----------------------------------------------------------
    def owner_of(self, partition_id: int) -> int:
        """Storage device that holds this partition (round-robin shard)."""
        return partition_id % self.num_devices

    def partitions_of(self, device: int) -> List[int]:
        return list(range(device, self.num_partitions, self.num_devices))

    # -- I/O -------------------------------------------------------------------
    def materialize(self, partition_ids: Iterable[int]) -> None:
        """Write partitions to disk (one columnar file each)."""
        assert self.root and self.source
        os.makedirs(self.root, exist_ok=True)
        for pid in partition_ids:
            path = self._path(pid)
            if not os.path.exists(path):
                write_partition(path, self.source.partition(pid))

    def read(self, partition_id: int) -> Partition:
        if self.root is not None:
            path = self._path(partition_id)
            if os.path.exists(path):
                part = read_partition(path)
                self._read_bytes += part.nbytes()
                return part
        assert self.source is not None, "no disk file and no synthetic source"
        part = self.source.partition(partition_id)
        self._read_bytes += part.nbytes()
        return part

    @property
    def bytes_read(self) -> int:
        return self._read_bytes

    # -- content identity ------------------------------------------------------
    def partition_fingerprint(self, partition_id: int) -> str:
        """Content-addressed identity of one partition's encoded bytes.

        Mirrors ``read()``'s precedence exactly: when a disk file exists it
        IS the content (read() serves its bytes even on a sourced store), so
        the fingerprint hashes the file bytes, revalidated against the
        file's (mtime, size) so a rewritten partition never serves a stale
        cache key.  Only fileless partitions fall back to the source's
        deterministic (cfg, rows, seed, pid) identity.  Equal fingerprint ⇒
        equal bytes, always — a mismatch between tenants can only cost a
        missed dedup, never a wrong batch.  This is the ``partition
        fingerprint`` component of a feature-cache key."""
        path = self._path(partition_id) if self.root is not None else None
        if path is not None and os.path.exists(path):
            st = os.stat(path)
            sig = (st.st_mtime_ns, st.st_size)
            with self._fp_lock:
                hit = self._fp_cache.get(partition_id)
            if hit is not None and hit[0] == sig:
                return hit[1]
            h = hashlib.sha256()
            with open(path, "rb") as f:
                for chunk in iter(lambda: f.read(1 << 20), b""):
                    h.update(chunk)
            fp = h.hexdigest()[:16]
            with self._fp_lock:
                self._fp_cache[partition_id] = (sig, fp)
            return fp
        assert self.source is not None, "no disk file and no synthetic source"
        with self._fp_lock:
            hit = self._fp_cache.get(partition_id)
        if hit is not None and hit[0] is None:
            return hit[1]
        fp = hashlib.sha256(
            f"{self.source.fingerprint()}:{partition_id}".encode()
        ).hexdigest()[:16]
        with self._fp_lock:
            self._fp_cache[partition_id] = (None, fp)
        return fp

    def _path(self, pid: int) -> str:
        # deviceNN/ prefix models per-device directories of the storage array
        assert self.root is not None
        dev = self.owner_of(pid)
        ddir = os.path.join(self.root, f"device{dev:03d}")
        os.makedirs(ddir, exist_ok=True)
        return os.path.join(ddir, f"part{pid:06d}.rp")


class CacheSpillStore:
    """Spill tier for the preprocessed-feature cache, on the simulated devices.

    Blocks evicted from the cache's in-memory LRU tier land here: each block
    (one train-ready mini-batch, as numpy arrays) is assigned to a simulated
    storage device by key hash, mirroring ``PartitionedStore``'s per-device
    ownership.  Residency is charged to the same byte-movement cost model as
    ISP placement — every write and read accrues ``bytes / bytes_per_s``
    modeled seconds (default: the ISP unit's internal SSD->FPGA stream rate,
    ``core.costmodel.PlacementCostModel.isp_stream_bytes_per_s``), so a spill
    hit is cheaper than recompute only when the cost model says so.

    With ``root`` set, blocks live as one ``.npz`` file per block under
    per-device directories (restart-survivable); otherwise they live in
    per-device dicts (pure simulation).  Thread-safe.
    """

    def __init__(
        self,
        num_devices: int = 4,
        *,
        capacity_bytes: Optional[int] = None,
        bytes_per_s: float = 8e9,
        root: Optional[str] = None,
    ):
        assert num_devices >= 1
        self.num_devices = num_devices
        self.capacity_bytes = capacity_bytes
        self.bytes_per_s = bytes_per_s
        self.root = root
        self._devices: List[Dict[str, Dict[str, np.ndarray]]] = [
            {} for _ in range(num_devices)
        ]
        self._sizes: Dict[str, int] = {}  # key -> block bytes (insertion order)
        self._resident = 0  # running sum of _sizes values
        self._lock = threading.Lock()
        self.bytes_written = 0
        self.bytes_read = 0
        self.modeled_io_s = 0.0

    def owner_of(self, key: str) -> int:
        return int(hashlib.sha256(key.encode()).hexdigest()[:8], 16) % self.num_devices

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._resident

    def __len__(self) -> int:
        with self._lock:
            return len(self._sizes)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._sizes

    def _block_path(self, key: str) -> str:
        assert self.root is not None
        ddir = os.path.join(self.root, f"device{self.owner_of(key):03d}")
        os.makedirs(ddir, exist_ok=True)
        return os.path.join(ddir, f"cache_{key}.npz")

    def write(self, key: str, arrays: Dict[str, np.ndarray]) -> int:
        """Spill one block; returns its size in bytes.  Oldest blocks are
        dropped when a capacity bound is set (the spill tier is a cache of a
        cache — recompute is always available underneath)."""
        def frozen(v: np.ndarray) -> np.ndarray:
            # blocks are served to many tenants: never mutable.  A read-only
            # VIEW leaves the caller's own array untouched, zero-copy.
            a = np.asarray(v)
            if a.flags.writeable:
                a = a.view()
                a.setflags(write=False)
            return a

        arrays = {k: frozen(v) for k, v in arrays.items()}
        nbytes = sum(int(a.nbytes) for a in arrays.values())
        if self.root is not None:
            np.savez(self._block_path(key), **arrays)
        dropped: List[str] = []
        with self._lock:
            if self.root is None:
                self._devices[self.owner_of(key)][key] = arrays
            old_bytes = self._sizes.pop(key, None)
            if old_bytes is not None:
                self._resident -= old_bytes
            self._sizes[key] = nbytes
            self._resident += nbytes
            self.bytes_written += nbytes
            self.modeled_io_s += nbytes / self.bytes_per_s
            if self.capacity_bytes is not None:
                while self._resident > self.capacity_bytes and len(self._sizes) > 1:
                    old = next(iter(self._sizes))
                    if old == key:
                        break
                    self._resident -= self._sizes.pop(old)
                    self._devices[self.owner_of(old)].pop(old, None)
                    dropped.append(old)
        if self.root is not None:
            for old in dropped:
                try:
                    os.remove(self._block_path(old))
                except OSError:
                    pass
        return nbytes

    def read(self, key: str) -> Optional[Dict[str, np.ndarray]]:
        """Fetch one spilled block (None if absent), charging modeled I/O."""
        with self._lock:
            nbytes = self._sizes.get(key)
            if nbytes is None:
                return None
            if self.root is None:
                block = self._devices[self.owner_of(key)].get(key)
                if block is None:
                    return None
                self.bytes_read += nbytes
                self.modeled_io_s += nbytes / self.bytes_per_s
                return dict(block)
        try:
            with np.load(self._block_path(key)) as z:
                block = {k: z[k] for k in z.files}
        except OSError:
            return None  # evicted between the size check and the load
        for a in block.values():
            a.setflags(write=False)
        with self._lock:
            self.bytes_read += nbytes
            self.modeled_io_s += nbytes / self.bytes_per_s
        return block
