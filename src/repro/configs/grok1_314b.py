"""grok-1-314b [moe] — 8 experts top-2, every layer MoE.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2
[hf:xai-org/grok-1; unverified].  Pure full attention -> long_500k SKIPPED.
bf16 params + Adafactor: 314B params do not fit a 256-chip v5e pod with
fp32+Adam (12 B/param = 14.7 GB/chip before activations); bf16+factored
states keep the dry-run inside HBM (see EXPERIMENTS.md §Dry-run).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    attention="full",
    mlp_kind="swiglu",
    n_experts=8,
    top_k=2,
    moe_period=1,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    optimizer="adafactor",
    # 8 experts divide neither the 16-way model nor data axes; each expert
    # is TP'd over 'ff' and the expert axis replicates.  Pod-EP (experts
    # over the 2-way pod axis + a2a) was tried and REFUTED: inside the
    # pod-manual region the expert einsums lose the weight-gathering
    # constraint and auto-SPMD reshards activations (x: 56 -> 595 s on the
    # multi-pod cell).  See EXPERIMENTS.md SPerf.  The production fix is a
    # dedicated 8x2 expert submesh (future work).
    sharding_overrides=(("experts", None), ("ff", "model")),
)

REDUCED = ModelConfig(
    name="grok-1-314b-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attention="full",
    mlp_kind="swiglu",
    n_experts=4,
    top_k=2,
    moe_period=1,
    dtype="float32",
    param_dtype="float32",
    remat="none",
)

SKIP_SHAPES = frozenset({"long_500k"})
