"""glm4-9b [dense] — RoPE, extreme GQA (kv=2).

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552
[hf:THUDM/glm-4-9b].  Pure full attention -> long_500k SKIPPED.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    attention="full",
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    optimizer="adamw",
    remat="dots",  # saves dot outputs: skips remat-replay of TP all-reduces (SPerf it.3)
)

REDUCED = ModelConfig(
    name="glm4-9b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attention="full",
    mlp_kind="swiglu",
    dtype="float32",
    remat="none",
)

SKIP_SHAPES = frozenset({"long_500k"})
