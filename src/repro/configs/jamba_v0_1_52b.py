"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536 [arXiv:2403.19887; hf].
Period of 8: one attention layer per 8 (position 4), the rest mamba;
MoE every other layer.  SSM state 16 (jamba uses mamba-1 state size; we run
the SSD formulation with N=16 — recorded in DESIGN.md).  long_500k RUNS:
only 4 of 32 layers hold full KV.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    mlp_kind="swiglu",
    n_experts=16,
    top_k=2,
    moe_period=2,
    attn_period=8,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_chunk=256,
    rope_theta=10_000.0,
    optimizer="adafactor",
)

REDUCED = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    mlp_kind="swiglu",
    n_experts=4,
    top_k=2,
    moe_period=2,
    attn_period=8,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_chunk=32,
    dtype="float32",
    remat="none",
)

SKIP_SHAPES: frozenset = frozenset()  # hybrid => long_500k runs
