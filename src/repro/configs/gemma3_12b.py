"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf:google/gemma-3-1b-pt pattern; unverified].  Local window 1024; period of
6 = 5 SWA + 1 global.  long_500k RUNS: 40 of 48 layers are windowed; the 8
global layers hold the full KV but decode is O(S) per token.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    attention="local_global",
    local_global_period=6,
    window=1024,
    mlp_kind="geglu",
    rope_theta=10_000.0,
    optimizer="adamw",
    remat="dots",  # saves dot outputs: skips remat-replay of TP all-reduces (SPerf it.3)
)

REDUCED = ModelConfig(
    name="gemma3-12b-smoke",
    family="dense",
    n_layers=6,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    attention="local_global",
    local_global_period=6,
    window=32,
    mlp_kind="geglu",
    dtype="float32",
    remat="none",
)

SKIP_SHAPES: frozenset = frozenset()  # mostly-local => long_500k runs
