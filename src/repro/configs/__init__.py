from repro.configs.registry import (
    ARCH_IDS,
    get_arch,
    get_recsys,
    list_arch_ids,
)

__all__ = ["ARCH_IDS", "get_arch", "get_recsys", "list_arch_ids"]
