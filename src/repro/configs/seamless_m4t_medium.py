"""seamless-m4t-medium [audio] — encoder-decoder, multimodal frontend STUB.

12L (decoder) + 12L (encoder) d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 [arXiv:2308.11596; hf].  input_specs() provides precomputed
audio frame embeddings for the encoder (modality frontend is a stub per the
assignment).  Full-attention enc-dec -> long_500k SKIPPED; decode shapes run
(it has a decoder).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    attention="full",
    mlp_kind="gelu",
    rope_theta=10_000.0,
    frontend="audio",
    optimizer="adamw",
    remat="dots",  # saves dot outputs: skips remat-replay of TP all-reduces (SPerf it.3)
)

REDUCED = ModelConfig(
    name="seamless-smoke",
    family="audio",
    n_layers=2,
    enc_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    attention="full",
    mlp_kind="gelu",
    frontend="audio",
    dtype="float32",
    remat="none",
)

SKIP_SHAPES = frozenset({"long_500k"})
