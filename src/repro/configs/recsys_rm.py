"""RM1-RM5 — the paper's own RecSys models (Table I).

RM1 = public Criteo scale; RM2-5 = production-scale synthetics.
Full configs are exercised by the dry-run and the PreSto benchmarks;
REDUCED variants (tiny embedding tables) run the smoke tests on CPU.
"""

import dataclasses

from repro.data.synth import RM_CONFIGS, RMDataConfig
from repro.models.recsys import RecSysConfig

CONFIGS = {
    f"rm{i}": RecSysConfig(name=f"rm{i}", data=RM_CONFIGS[f"rm{i}"])
    for i in range(1, 6)
}


def reduced_data(cfg: RMDataConfig, rows: int = 256) -> RMDataConfig:
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        bucket_size=min(cfg.bucket_size, 64),
        id_space=1 << 16,
        embedding_rows=1024,
        rows_per_partition=rows,
    )


REDUCED = {
    f"rm{i}": RecSysConfig(
        name=f"rm{i}-smoke", data=reduced_data(RM_CONFIGS[f"rm{i}"])
    )
    for i in range(1, 6)
}
