"""internvl2-76b [vlm] — InternViT frontend (STUB) + InternLM2-76B backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 [arXiv:2404.16821].
The vision tower is a stub per the assignment: input_specs() provides 256
precomputed patch embeddings per sample, prepended to the token sequence.
Pure full attention -> long_500k SKIPPED.  Adafactor (76B params).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    attention="full",
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    frontend="vision",
    frontend_positions=256,
    optimizer="adafactor",
)

REDUCED = ModelConfig(
    name="internvl2-76b-smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attention="full",
    mlp_kind="swiglu",
    frontend="vision",
    frontend_positions=16,
    dtype="float32",
    remat="none",
)

SKIP_SHAPES = frozenset({"long_500k"})
