"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf]
SWA window 4096 (mistral-style), so long_500k RUNS (sub-quadratic).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    attention="swa",
    window=4096,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    optimizer="adamw",
    remat="dots",  # saves dot outputs: skips remat-replay of TP all-reduces (SPerf it.3)
)

REDUCED = ModelConfig(
    name="h2o-danube-1.8b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attention="swa",
    window=64,
    mlp_kind="swiglu",
    dtype="float32",
    remat="none",
)

SKIP_SHAPES: frozenset = frozenset()  # SWA => long_500k runs
