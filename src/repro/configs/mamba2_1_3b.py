"""mamba2-1.3b [ssm] — pure SSD (state-space duality), attention-free.

48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified].  d_inner=4096, head_dim=64 -> 64 SSD heads.
O(1) state per token -> long_500k RUNS (this is the showcase arch for it).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_chunk=128,
    optimizer="adamw",
    remat="dots",  # saves dot outputs: skips remat-replay of TP all-reduces (SPerf it.3)
)

REDUCED = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=128,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_chunk=32,
    dtype="float32",
    remat="none",
)

SKIP_SHAPES: frozenset = frozenset()
