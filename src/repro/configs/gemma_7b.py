"""gemma-7b [dense] — GeGLU, head_dim=256, full (global) attention.

28L d_model=3072 16H (GQA kv=16 = MHA) d_ff=24576 vocab=256000
[arXiv:2403.08295; hf].  Pure full attention -> long_500k SKIPPED
(see DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    attention="full",
    mlp_kind="geglu",
    rope_theta=10_000.0,
    optimizer="adamw",
    remat="dots",  # saves dot outputs: skips remat-replay of TP all-reduces (SPerf it.3)
)

REDUCED = ModelConfig(
    name="gemma-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    attention="full",
    mlp_kind="geglu",
    dtype="float32",
    remat="none",
)

SKIP_SHAPES = frozenset({"long_500k"})  # pure full attention
