"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, interleaved MoE,
iRoPE-style chunked attention.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4-* pattern; unverified].  Chunked attention (8k
chunks, 3 chunked + 1 full per period) keeps long-context tractable ->
long_500k RUNS.  MoE every other layer (interleaved, Maverick-style).
bf16 params + Adafactor (400B total, 17B active).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    attention="chunked",
    chunk_size=8192,
    mlp_kind="swiglu",
    n_experts=128,
    top_k=1,
    moe_period=2,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    optimizer="adafactor",
    # EP over DATA (128 experts / 16 = 8 per shard) + TP over model within
    # each expert: tokens move to experts (a2a-sized) instead of FSDP
    # re-gathering 1.3 GB expert weights per microbatch x layer (measured
    # 3.1 TB/step/device at baseline — §Perf iteration L2).
    sharding_overrides=(("experts", "data"), ("ff", "model")),
)

REDUCED = ModelConfig(
    name="llama4-maverick-smoke",
    family="moe",
    n_layers=4,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attention="chunked",
    chunk_size=32,
    mlp_kind="swiglu",
    n_experts=8,
    top_k=1,
    moe_period=2,
    dtype="float32",
    param_dtype="float32",
    remat="none",
)

SKIP_SHAPES: frozenset = frozenset()  # chunked attention => long_500k runs
