"""Architecture registry: --arch <id> resolution for all assigned configs."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.models.config import ModelConfig

_MODULES: Dict[str, str] = {
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "gemma-7b": "repro.configs.gemma_7b",
    "glm4-9b": "repro.configs.glm4_9b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
}

ARCH_IDS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    config: ModelConfig
    reduced: ModelConfig
    skip_shapes: frozenset


def get_arch(arch_id: str) -> ArchEntry:
    mod = importlib.import_module(_MODULES[arch_id])
    return ArchEntry(mod.CONFIG, mod.REDUCED, mod.SKIP_SHAPES)


def list_arch_ids() -> tuple:
    return ARCH_IDS


def get_recsys(name: str, *, reduced: bool = False):
    from repro.configs import recsys_rm

    return (recsys_rm.REDUCED if reduced else recsys_rm.CONFIGS)[name]
