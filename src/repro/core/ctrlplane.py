"""The elastic control plane: events, checkpoints, autoscaling, chaos hooks.

PreSto's value claim is COST-efficiency: a preprocessing pool sized to the
work instead of a static CPU fleet.  Meta's production ingestion stack (DPP,
in the DSI paper) is the template this module reproduces around
``core.service.PreprocessingService``: stateless pool workers behind a
master that checkpoints job progress, auto-scales the pool from queue
depth / QoS targets, and survives worker loss.  Everything here is
deliberately mechanism-light because the data plane already guarantees the
hard part — partitions are deterministic, so re-producing one is always
bitwise safe:

* ``EventLog`` — a bounded ring-buffer metrics publisher (the Ray dashboard
  publisher/buffer/tail idiom): every membership change, claim re-issue,
  checkpoint, scale decision, and plan change lands here as a structured
  ``Event``; ``stats()``, ``serve_preprocess``, and the tests read it back
  via ``tail``/``since``/``counts``.
* ``SessionCheckpoint`` — a session's progress frontier (DELIVERED
  partition ids, tuner state, counters), JSON-serializable.  Delivered —
  not merely produced — is the frontier: an undelivered result dies with
  the service, so resume must re-produce it.  ``apply`` turns an original
  ``JobSpec`` into its resume spec (the remaining partitions, original
  order); determinism makes the combined pre-crash + post-resume stream
  bitwise identical to an uninterrupted run.  The feature cache's
  ``warm_start`` covers the data side of the same restart.
* ``AutoscalePolicy`` / ``Autoscaler`` — the backlog-driven policy loop:
  reads ``service.load_snapshot()`` (live workers, sessions, backlog,
  hit-rate-discounted demand units), grows the pool while the backlog per
  worker exceeds the policy's target, and shrinks it back to the floor when
  drained — every decision emitted as a ``scale_up``/``scale_down`` event.
  ``step()`` is deterministic (the tests drive it directly); ``start()``
  runs it on a background thread for the CLI.
* ``FailureInjector`` / ``SimulatedFailure`` — the shared failure-injection
  contract.  ``train.elastic.ElasticTrainer`` (the seed's elasticity
  design: regenerable data + topology-agnostic restore) injects trainer
  failures through it; the service side simulates worker crashes with
  ``PreprocessingService.kill_worker`` (in-flight claims re-issued through
  the queue's straggler path).  Same drill, both sides of the stream.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import math
import os
import threading
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

__all__ = [
    "Autoscaler",
    "AutoscalePolicy",
    "Event",
    "EventLog",
    "FailureInjector",
    "SessionCheckpoint",
    "SessionError",
    "SimulatedFailure",
    "parse_kill_spec",
]


# -- structured event stream ---------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Event:
    """One control-plane occurrence: monotone ``seq``, wall-clock ``ts``,
    a ``kind`` tag, and a small JSON-able payload."""

    seq: int
    ts: float
    kind: str
    data: Dict[str, Any]

    def to_dict(self) -> Dict[str, Any]:
        return {"seq": self.seq, "ts": self.ts, "kind": self.kind,
                "data": dict(self.data)}


class EventLog:
    """Bounded-buffer event publisher (publisher/buffer/tail idiom).

    Thread-safe.  ``emit`` never blocks and never fails the caller; the ring
    keeps the newest ``capacity`` events (older ones are dropped but still
    counted), so observability can never leak memory on a long-lived pool.

    ``clock`` defaults to wall time; the discrete-event simulator injects
    its ``VirtualClock.now`` so every event is stamped with the *modeled*
    instant — with a virtual clock, same-seed runs produce byte-identical
    event traces (``dump``/``to_dicts``), which is what the deterministic-
    simulation tests diff.
    """

    def __init__(self, capacity: int = 512, *, clock=None):
        self._buf: Deque[Event] = collections.deque(maxlen=max(1, int(capacity)))
        self._lock = threading.Lock()
        self._seq = 0
        self._counts: Dict[str, int] = {}
        self._clock = clock or time.time

    def emit(self, kind: str, **data: Any) -> Event:
        with self._lock:
            ev = Event(self._seq, self._clock(), str(kind), data)
            self._seq += 1
            self._counts[ev.kind] = self._counts.get(ev.kind, 0) + 1
            self._buf.append(ev)
        return ev

    @property
    def emitted(self) -> int:
        """All-time emit count (>= what the ring still holds)."""
        with self._lock:
            return self._seq

    def counts(self) -> Dict[str, int]:
        """All-time per-kind counts — unaffected by ring-buffer drops."""
        with self._lock:
            return dict(self._counts)

    def tail(self, n: int = 20, kind: Optional[str] = None) -> List[Event]:
        """The newest `n` buffered events (oldest-first), optionally filtered."""
        with self._lock:
            evs = list(self._buf)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        return evs[-max(0, int(n)):]

    def since(self, seq: int) -> List[Event]:
        """Buffered events with ``seq`` strictly greater than `seq` — the
        incremental-consumer cursor (a dropped prefix is simply absent)."""
        with self._lock:
            return [e for e in self._buf if e.seq > seq]

    def to_dicts(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [e.to_dict() for e in self._buf]

    def dump(self, path: str) -> None:
        """Write the buffered events as a JSON artifact (CI uploads these)."""
        with open(path, "w") as f:
            json.dump(self.to_dicts(), f, indent=2, default=str)

    def summary(self, tail: int = 8) -> Dict[str, Any]:
        """The ``stats()``-embeddable view: totals, per-kind counts, newest
        few events."""
        with self._lock:
            emitted = self._seq
            dropped = emitted - len(self._buf)
            counts = dict(self._counts)
            newest = [e.to_dict() for e in list(self._buf)[-max(0, int(tail)):]]
        return {"emitted": emitted, "dropped": dropped, "counts": counts,
                "tail": newest}


# -- session checkpoint/resume -------------------------------------------------


@dataclasses.dataclass
class SessionCheckpoint:
    """A session's progress frontier, snapshotted for restart/resume.

    ``partitions`` is the job's full deduplicated partition order;
    ``delivered`` the pids the consumer has actually received (delivery
    order).  Produced-but-undelivered batches are deliberately NOT in the
    frontier — their futures die with the service, so resume re-produces
    them (bitwise identical: partitions are deterministic).  ``tuner`` is a
    ``MegabatchTuner.summary()`` so a resumed autotuned session re-seeds at
    its converged rung instead of re-climbing; ``stats`` carries the closing
    counters for the record (a resumed session's own counters start fresh).
    """

    job: str
    partitions: List[int]
    delivered: List[int]
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)
    tuner: Optional[Dict[str, Any]] = None

    def remaining(self) -> List[int]:
        """Partitions still owed to the consumer, in original claim order."""
        done = set(self.delivered)
        return [p for p in self.partitions if p not in done]

    @property
    def fraction_done(self) -> float:
        return len(self.delivered) / max(len(self.partitions), 1)

    def apply(self, job: Any) -> Any:
        """Derive the resume ``JobSpec`` from the original: same contract,
        remaining partitions only.  (Duck-typed via ``dataclasses.replace``
        so this module never imports the service layer.)"""
        if getattr(job, "name", None) != self.job:
            raise ValueError(
                f"checkpoint is for job {self.job!r}, not {getattr(job, 'name', None)!r}"
            )
        return dataclasses.replace(job, partitions=self.remaining())

    def to_dict(self) -> Dict[str, Any]:
        return {
            "job": self.job,
            "partitions": [int(p) for p in self.partitions],
            "delivered": [int(p) for p in self.delivered],
            "stats": dict(self.stats),
            "tuner": self.tuner,
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "SessionCheckpoint":
        return SessionCheckpoint(
            job=d["job"],
            partitions=[int(p) for p in d.get("partitions", [])],
            delivered=[int(p) for p in d.get("delivered", [])],
            stats=dict(d.get("stats") or {}),
            tuner=d.get("tuner"),
        )

    def save(self, path: str) -> None:
        """Atomic write: tmp file + fsync + ``os.replace``.  A crash at any
        instant leaves either the previous checkpoint or the new one —
        never a torn half-write — so ``load`` on the survivor always
        parses."""
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    @staticmethod
    def load(path: str) -> "SessionCheckpoint":
        """Parse a checkpoint, rejecting torn/truncated JSON with a clear
        error (``ValueError`` naming the path and size) instead of a bare
        decode traceback — the restart path can then fall back to a fresh
        session rather than crash-looping on a bad file."""
        with open(path) as f:
            raw = f.read()
        try:
            d = json.loads(raw)
            if not isinstance(d, dict) or "job" not in d:
                raise ValueError("not a checkpoint object (missing 'job')")
        except ValueError as exc:
            raise ValueError(
                f"checkpoint {path!r} is torn or truncated "
                f"({len(raw)} bytes): {exc}"
            ) from exc
        return SessionCheckpoint.from_dict(d)


# -- backlog-driven autoscaling ------------------------------------------------


@dataclasses.dataclass
class AutoscalePolicy:
    """Bounds + targets for the backlog-driven scaling loop.

    The pool grows while the backlog (unfinished partitions across every
    admitted session) exceeds ``backlog_per_worker`` per live worker, never
    past ``max_workers`` or the sessions' aggregate hit-rate-discounted
    demand (scaling beyond demand buys nothing: shares are demand-capped).
    A drained pool shrinks back to the floor — ``min_workers``, but never
    below one schedulable unit per admitted session (the admission floor).
    """

    min_workers: int = 1
    max_workers: int = 8
    backlog_per_worker: float = 2.0
    cooldown_s: float = 0.0  # minimum seconds between applied scale moves
    max_step: int = 1  # workers added/removed per decision


class Autoscaler:
    """Drives ``service.add_worker``/``remove_worker`` from pool load.

    ``step()`` is one deterministic policy evaluation (tests call it
    directly); ``start(interval_s)`` runs the loop on a daemon thread until
    ``stop()`` or the service closes.  Every applied decision is emitted to
    the service's ``EventLog`` with the inputs that justified it.
    """

    def __init__(self, service: Any, policy: Optional[AutoscalePolicy] = None):
        self.service = service
        self.policy = policy or AutoscalePolicy()
        self._last_move: Optional[float] = None
        self._halt = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def desired(self, snapshot: Optional[Dict[str, int]] = None) -> int:
        """Target pool size for a load snapshot (pure policy, no side
        effects): demand- and backlog-capped want, clamped to the bounds."""
        pol = self.policy
        snap = snapshot if snapshot is not None else self.service.load_snapshot()
        if snap["backlog"] <= 0:
            want = 0  # drained: fall to the floor
        else:
            want = min(
                snap["demand_units"],
                math.ceil(snap["backlog"] / max(pol.backlog_per_worker, 1e-9)),
            )
        floor = max(1, pol.min_workers, min(snap["sessions"], pol.max_workers))
        return max(floor, min(pol.max_workers, want))

    def step(self) -> int:
        """One policy evaluation; returns the worker delta actually applied
        (bounded by ``max_step``; 0 inside the cooldown window)."""
        svc = self.service
        if svc.closed:
            return 0
        now = time.monotonic()
        if (
            self._last_move is not None
            and now - self._last_move < self.policy.cooldown_s
        ):
            return 0
        snap = svc.load_snapshot()
        target = self.desired(snap)
        delta = max(
            -self.policy.max_step, min(self.policy.max_step, target - snap["workers"])
        )
        applied = 0
        for _ in range(delta):
            svc.add_worker()
            applied += 1
        for _ in range(-delta):
            if svc.remove_worker() is None:
                break  # admission floor refused the shrink
            applied -= 1
        if applied:
            self._last_move = now
            svc.events.emit(
                "scale_up" if applied > 0 else "scale_down",
                delta=applied,
                workers=svc.num_workers,
                target=target,
                backlog=snap["backlog"],
                demand_units=snap["demand_units"],
                sessions=snap["sessions"],
            )
        return applied

    def start(self, interval_s: float = 0.05) -> "Autoscaler":
        if self._thread is not None:
            return self

        def _loop() -> None:
            while not self._halt.is_set() and not self.service.closed:
                self.step()
                self._halt.wait(timeout=interval_s)

        self._thread = threading.Thread(
            target=_loop, daemon=True, name="presto-autoscaler"
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._halt.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


# -- shared failure injection (chaos hooks) ------------------------------------


class SimulatedFailure(RuntimeError):
    """An injected failure — distinguishable from a real production error."""


class SessionError(RuntimeError):
    """A structured, terminal per-partition session failure.

    Raised through the session iterator when the claim-path recovery policy
    exhausts a partition's poison budget (retries + failover did not help):
    the consumer gets WHICH job, WHICH partition, HOW many attempts, and the
    underlying cause — promptly, instead of a hung iterator.  Quarantining
    is deliberate: a partition that fails deterministically would otherwise
    burn the pool's retry bandwidth forever.
    """

    def __init__(
        self,
        message: str,
        *,
        job: Optional[str] = None,
        pid: Optional[int] = None,
        attempts: int = 0,
        cause: Optional[BaseException] = None,
    ):
        super().__init__(message)
        self.job = job
        self.pid = pid
        self.attempts = attempts
        self.cause = cause


@dataclasses.dataclass
class FailureInjector:
    """The shared chaos contract: raise once when execution reaches
    ``fail_at``.

    ``train.elastic.ElasticTrainer`` injects trainer-step failures through
    it (the restart replays past the injection point, so it fires at most
    once per injector); service-side drills pair it with
    ``PreprocessingService.kill_worker`` / checkpoint-restart, which
    exercise the same recovery invariant from the pool side.
    """

    fail_at: Optional[int] = None
    events: Optional[EventLog] = None
    fired: bool = False

    def check(self, step: int) -> None:
        if self.fail_at is None or self.fired or step != self.fail_at:
            return
        self.fired = True
        if self.events is not None:
            self.events.emit("failure_injected", step=step)
        raise SimulatedFailure(f"simulated failure at step {step}")


def parse_kill_spec(spec: str) -> Tuple[int, int]:
    """Parse one ``WID@N`` chaos directive -> ``(after_batches, wid)``:
    kill pool worker WID once N total batches have been delivered."""
    wid_s, sep, after_s = spec.partition("@")
    if not sep:
        raise ValueError(f"kill spec {spec!r} is not WID@AFTER_BATCHES")
    return int(after_s), int(wid_s)
