"""The ETL Transform: encoded pages -> train-ready mini-batch.

The Transform itself is declared once as an operator graph
(``repro.core.opgraph``) and *lowered* per placement; everything in this
module is a thin wrapper over that lowering:

* ``preprocess_pages(mode="fused")``   — all families on ISP: decode+transform
  fused per column family (one HBM read of encoded bytes, one write of
  tensors) — the PreSto path.
* ``preprocess_pages(mode="unfused")`` — all families on host: the
  Disagg/CPU-style multi-step path (decode, then each transform as its own
  pass), used for the per-stage latency breakdown (paper Fig. 5 / Fig. 12).
* ``preprocess_pages(mode="hybrid")``  — per-family placement chosen by the
  cost model (bytes-moved vs compute roofline, ``core.costmodel``); a dict
  ``{family: "isp"|"host"}`` is also accepted.

Everything here is jit-able and shard_map-able; shapes are static given a
``PartitionSchema`` + ``TransformSpec``.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.opgraph import (
    LoweredPlan,
    build_transform_graph,
    lower,
    prepare_env,
    resolve_placements,
)
from repro.core.spec import TransformSpec
from repro.data.columnar import Partition, partition_refs
from repro.kernels import ops as K

MiniBatch = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Host-side page staging: Partition (numpy, flat pages) -> kernel layout


def pages_from_partition(part: Partition, spec: TransformSpec) -> Dict[str, np.ndarray]:
    """Stack per-column pages into the grouped arrays the kernels consume.

    Dedup partitions (``schema.dup_factor > 1``) stage their sparse/length
    pages at UNIQUE-block geometry — each shared block's encoded words enter
    device memory exactly once — plus a ``sparse_refs`` vector mapping the
    ``rows`` logical samples back to blocks; the compiled Transform
    gather-expands after hashing (``execute_plan``).
    """
    cfg = spec.cfg
    rows = part.schema.rows
    u = part.schema.unique_rows  # == rows for classic partitions
    dense = []
    for i in range(cfg.n_dense):
        col = part.columns[f"d{i}"]
        dense.append(K.regroup_bytesplit(col.pages["data"], rows))
    sparse, lengths = [], []
    n_vals = u * cfg.max_sparse_len
    for i in range(cfg.n_sparse):
        col = part.columns[f"s{i}"]
        sparse.append(K.regroup_bitpack(col.pages["values"], n_vals, cfg.id_width))
        lengths.append(K.regroup_bitpack(col.pages["lengths"], u, cfg.len_width))
    label_words = part.columns["label"].pages["data"][:rows]
    pages = {
        "dense_words": np.stack(dense),  # (n_dense, rows/4, 4) u32
        "sparse_words": np.stack(sparse),  # (n_sparse, u*L/32, w) u32
        "length_words": np.stack(lengths),  # (n_sparse, u/32, lw) u32
        "label_words": label_words,  # (rows,) u32
    }
    refs = partition_refs(part)
    if refs is not None:
        pages["sparse_refs"] = refs.astype(np.int32)  # (rows,) block index
    return pages


def stack_pages(pages_list) -> Dict[str, np.ndarray]:
    """Stack K partitions' staged pages into one leading-axis megabatch.

    Input: K dicts from ``pages_from_partition`` (equal shapes — megabatches
    require uniform partition geometry, which the partitioned stores
    guarantee).  Output: one dict whose every array gains a leading K axis,
    the input of ``PreStoEngine.preprocess_megabatch``.
    """
    pages_list = list(pages_list)
    if len(pages_list) == 1:
        return {k: v[None] for k, v in pages_list[0].items()}
    return {
        k: np.stack([p[k] for p in pages_list]) for k in pages_list[0]
    }


def flatten_megabatch(stacked: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Fold the leading megabatch axis into the row-group axis (traceable).

    Every page array is grouped ``(features, row_groups, words)`` with the
    feature axis leading (labels are flat ``(rows,)``), and every operator in
    the standard Transform is row-local — so a K-partition megabatch is
    exactly a single partition with K x the rows.  ``(K, F, G, w)`` becomes
    ``(F, K*G, w)`` (partition-major row order) and ``(K, R)`` becomes
    ``(K*R,)``; the resulting mini-batch splits back per partition along its
    leading row axis.
    """
    out: Dict[str, jax.Array] = {}
    for name, v in stacked.items():
        if name == "sparse_refs":
            # (K, rows) block refs -> (K*rows,) into the K*u flattened unique
            # blocks: partition k's blocks land at offset k*u after the
            # sparse/length pages fold their own row-group axes below.
            k, _ = v.shape
            u = stacked["length_words"].shape[2] * 32
            off = (jnp.arange(k, dtype=v.dtype) * u)[:, None]
            out[name] = (v + off).reshape(-1)
        elif v.ndim == 2:  # label_words: (K, rows) -> (K*rows,)
            out[name] = v.reshape(-1)
        else:  # (K, F, G, w) -> (F, K*G, w)
            k, f, g, w = v.shape
            out[name] = jnp.moveaxis(v, 0, 1).reshape(f, k * g, w)
    return out


def megabatch_pages_shape_dtypes(
    spec: TransformSpec, rows: int, k: int
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a K-partition stacked megabatch."""
    return {
        name: jax.ShapeDtypeStruct((k, *s.shape), s.dtype)
        for name, s in pages_shape_dtypes(spec, rows).items()
    }


def pages_shape_dtypes(spec: TransformSpec, rows: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the page arrays (dry-run inputs).

    Sparse/length pages live at unique-block geometry when the dataset
    dedups (``cfg.dup_factor > 1``), matching ``pages_from_partition``.
    """
    cfg = spec.cfg
    d = getattr(cfg, "dup_factor", 1)
    u = rows // d
    u32 = jnp.uint32
    out = {
        "dense_words": jax.ShapeDtypeStruct((cfg.n_dense, rows // 4, 4), u32),
        "sparse_words": jax.ShapeDtypeStruct(
            (cfg.n_sparse, u * cfg.max_sparse_len // 32, cfg.id_width), u32
        ),
        "length_words": jax.ShapeDtypeStruct(
            (cfg.n_sparse, u // 32, cfg.len_width), u32
        ),
        "label_words": jax.ShapeDtypeStruct((rows,), u32),
    }
    if d > 1:
        out["sparse_refs"] = jax.ShapeDtypeStruct((rows,), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Transform entry points (all lowered from the operator graph)


def execute_plan(plan: LoweredPlan, pages: Dict[str, jax.Array]) -> MiniBatch:
    """Run a lowered plan over staged pages, dedup-aware (traceable).

    Classic pages run ``plan.execute`` untouched.  Dedup pages (carrying
    ``sparse_refs``) run the sparse/length stages at unique-block geometry —
    decode + SigridHash touch each shared block once — then gather-expand
    ``sparse_hashed``/``lengths_i32`` through the refs just before
    ``form_batch``.  Every sparse-chain operator is per-value row-local
    (``kernels.ROW_LOCAL_KINDS``), so transform-then-expand is bitwise
    identical to expand-then-transform: the undeduped result, for fused,
    unfused and hybrid lowerings alike.
    """
    if "sparse_refs" not in pages:
        return plan.execute(pages)
    pages = dict(pages)
    refs = jnp.asarray(pages.pop("sparse_refs"))
    cfg = plan.spec.cfg
    env = prepare_env(pages, plan.spec)
    for st in plan.stages:
        if st.name == "form_batch":
            sh = env["sparse_hashed"]  # (n_sparse, u*L) at unique geometry
            s, ul = sh.shape
            blocks = sh.reshape(s, ul // cfg.max_sparse_len, cfg.max_sparse_len)
            env["sparse_hashed"] = jnp.take(blocks, refs, axis=1).reshape(
                s, refs.shape[0] * cfg.max_sparse_len
            )
            env["lengths_i32"] = jnp.take(env["lengths_i32"], refs, axis=0)
        vals = st.fn(*(env[k] for k in st.inputs))
        env.update(zip(st.outputs, vals))
    return env["minibatch"]


def preprocess_pages(
    pages: Dict[str, jax.Array],
    spec: TransformSpec,
    *,
    mode="fused",
    interpret: bool | None = None,
) -> MiniBatch:
    """Full Transform for one partition shard. Returns the train-ready batch.

    Output:
      dense          (rows, n_dense) f32      — Log-normalized
      multi_hot_ids  (rows, n_sparse, L) i32  — SigridHashed raw sparse ids
      lengths        (rows, n_sparse) i32     — multi-hot lengths
      one_hot_ids    (rows, n_generated) i32  — Bucketize+SigridHash generated
      labels         (rows,) f32
    """
    placements = resolve_placements(mode, spec)
    plan = lower(build_transform_graph(spec), spec, placements, interpret=interpret)
    return execute_plan(plan, pages)


def minibatch_shape_dtypes(spec: TransformSpec, rows: int) -> MiniBatch:
    cfg = spec.cfg
    return {
        "dense": jax.ShapeDtypeStruct((rows, cfg.n_dense), jnp.float32),
        "multi_hot_ids": jax.ShapeDtypeStruct(
            (rows, cfg.n_sparse, cfg.max_sparse_len), jnp.int32
        ),
        "lengths": jax.ShapeDtypeStruct((rows, cfg.n_sparse), jnp.int32),
        "one_hot_ids": jax.ShapeDtypeStruct((rows, cfg.n_generated), jnp.int32),
        "labels": jax.ShapeDtypeStruct((rows,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Stage-split functions for the latency breakdown (Fig. 5 / Fig. 12)


def stage_functions(spec: TransformSpec, *, interpret: bool | None = None):
    """Individually jit-able callables per ETL stage, for stage timing.

    Thin adapter over the all-host lowering: every body is a lowered graph
    stage (no transform logic lives here), regrouped into the paper's
    stage names.
    """
    plan = lower(
        build_transform_graph(spec), spec, resolve_placements("unfused", spec),
        interpret=interpret,
    )
    fns = {st.name: st.fn for st in plan.stages}
    src = jnp.asarray(np.asarray(spec.generated_source, np.int32))

    def extract_decode(pages):
        dense_raw = fns["decode_dense"](pages["dense_words"])[0]
        sparse_raw = fns["decode_sparse"](pages["sparse_words"])[0]
        return dense_raw, sparse_raw

    def gen_bucketize(dense_raw):
        return fns["bucketize_gen"](jnp.take(dense_raw, src, axis=0))[0]

    def norm_sigridhash(sparse_raw, bucket_ids):
        return fns["hash_sparse"](sparse_raw)[0], fns["hash_gen"](bucket_ids)[0]

    def norm_log(dense_raw):
        return fns["lognorm_dense"](dense_raw)[0]

    def form_minibatch(pages, dense_norm, hashed, gen_hashed):
        lengths = fns["decode_lengths"](pages["length_words"])[0]
        labels = fns["decode_labels"](pages["label_words"])[0]
        return fns["form_batch"](dense_norm, hashed, lengths, labels, gen_hashed)[0]

    return {
        "extract_decode": jax.jit(extract_decode),
        "gen_bucketize": jax.jit(gen_bucketize),
        "norm_sigridhash": jax.jit(norm_sigridhash),
        "norm_log": jax.jit(norm_log),
        "form_minibatch": jax.jit(form_minibatch),
    }
