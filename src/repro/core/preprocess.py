"""The ETL Transform graph: encoded pages -> train-ready mini-batch.

Two execution modes over identical semantics:

* ``fused``   — the PreSto path: decode+transform fused per column family
                (one HBM read of encoded bytes, one write of tensors).
* ``unfused`` — the Disagg/CPU-style multi-step path (decode, then each
                transform as its own pass) used for the per-stage latency
                breakdown (paper Fig. 5 / Fig. 12) and as the ablation
                baseline.

Everything here is jit-able and shard_map-able; shapes are static given a
``PartitionSchema`` + ``TransformSpec``.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec import TransformSpec
from repro.data.columnar import Partition
from repro.kernels import ops as K
from repro.kernels import ref as R

MiniBatch = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Host-side page staging: Partition (numpy, flat pages) -> kernel layout


def pages_from_partition(part: Partition, spec: TransformSpec) -> Dict[str, np.ndarray]:
    """Stack per-column pages into the grouped arrays the kernels consume."""
    cfg = spec.cfg
    rows = part.schema.rows
    dense = []
    for i in range(cfg.n_dense):
        col = part.columns[f"d{i}"]
        dense.append(K.regroup_bytesplit(col.pages["data"], rows))
    sparse, lengths = [], []
    n_vals = rows * cfg.max_sparse_len
    for i in range(cfg.n_sparse):
        col = part.columns[f"s{i}"]
        sparse.append(K.regroup_bitpack(col.pages["values"], n_vals, cfg.id_width))
        lengths.append(K.regroup_bitpack(col.pages["lengths"], rows, cfg.len_width))
    label_words = part.columns["label"].pages["data"][:rows]
    return {
        "dense_words": np.stack(dense),  # (n_dense, rows/4, 4) u32
        "sparse_words": np.stack(sparse),  # (n_sparse, rows*L/32, w) u32
        "length_words": np.stack(lengths),  # (n_sparse, rows/32, lw) u32
        "label_words": label_words,  # (rows,) u32
    }


def pages_shape_dtypes(spec: TransformSpec, rows: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the page arrays (dry-run inputs)."""
    cfg = spec.cfg
    u32 = jnp.uint32
    return {
        "dense_words": jax.ShapeDtypeStruct((cfg.n_dense, rows // 4, 4), u32),
        "sparse_words": jax.ShapeDtypeStruct(
            (cfg.n_sparse, rows * cfg.max_sparse_len // 32, cfg.id_width), u32
        ),
        "length_words": jax.ShapeDtypeStruct(
            (cfg.n_sparse, rows // 32, cfg.len_width), u32
        ),
        "label_words": jax.ShapeDtypeStruct((rows,), u32),
    }


# ---------------------------------------------------------------------------
# Transform graph


def _decode_lengths(length_words: jax.Array, spec: TransformSpec, rows: int) -> jax.Array:
    """(n_sparse, rows/32, lw) -> (rows, n_sparse) i32.  Tiny; pure jnp."""
    lens = R.bitunpack_grouped(length_words, spec.cfg.len_width)  # (S, G, 32)
    return lens.reshape(spec.cfg.n_sparse, rows).T.astype(jnp.int32)


def _decode_labels(label_words: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(label_words, jnp.float32)


def preprocess_pages(
    pages: Dict[str, jax.Array],
    spec: TransformSpec,
    *,
    mode: str = "fused",
    interpret: bool | None = None,
) -> MiniBatch:
    """Full Transform for one partition shard. Returns the train-ready batch.

    Output:
      dense          (rows, n_dense) f32      — Log-normalized
      multi_hot_ids  (rows, n_sparse, L) i32  — SigridHashed raw sparse ids
      lengths        (rows, n_sparse) i32     — multi-hot lengths
      one_hot_ids    (rows, n_generated) i32  — Bucketize+SigridHash generated
      labels         (rows,) f32
    """
    cfg = spec.cfg
    rows = pages["label_words"].shape[0]
    L = cfg.max_sparse_len

    src = jnp.asarray(np.asarray(spec.generated_source, np.int32))
    if mode == "fused":
        # -- PreSto ISP path: decode fused with transform ---------------------
        dense_norm = K.fused_dense(pages["dense_words"], interpret=interpret)
        hashed = K.fused_sparse(
            pages["sparse_words"],
            spec.sparse_seeds,
            spec.sparse_max,
            width=cfg.id_width,
            interpret=interpret,
        )
        # feature GENERATION fully fused: decode+Bucketize+SigridHash in one
        # kernel over the sourced dense columns (SPerf preprocess it.1)
        gen_hashed = K.fused_gen(
            jnp.take(pages["dense_words"], src, axis=0),
            spec.bucket_boundaries,
            spec.gen_seeds,
            spec.gen_max,
            interpret=interpret,
        )
        return {
            "dense": dense_norm.T,
            "multi_hot_ids": hashed.reshape(cfg.n_sparse, rows, L).transpose(1, 0, 2),
            "lengths": _decode_lengths(pages["length_words"], spec, rows),
            "one_hot_ids": gen_hashed.T,
            "labels": _decode_labels(pages["label_words"]),
        }
    elif mode == "unfused":
        # -- Disagg-style multi-pass path ------------------------------------
        dense_raw = K.decode_bytesplit(pages["dense_words"], interpret=interpret)
        sparse_raw = K.decode_bitpack(
            pages["sparse_words"], width=cfg.id_width, interpret=interpret
        )
        dense_norm = K.lognorm(dense_raw, interpret=interpret)
        hashed = K.sigridhash(
            sparse_raw, spec.sparse_seeds, spec.sparse_max, interpret=interpret
        )
        gen_inputs = jnp.take(dense_raw, src, axis=0)  # (n_gen, rows) raw
    else:
        raise ValueError(mode)

    # -- Feature generation: Bucketize sourced dense cols, then normalize ----
    bucket_ids = K.bucketize(
        gen_inputs, spec.bucket_boundaries, interpret=interpret
    )  # (n_gen, rows) in [0, m]
    gen_hashed = K.sigridhash(
        bucket_ids, spec.gen_seeds, spec.gen_max, interpret=interpret
    )

    # -- Mini-batch formation (step 3 of Fig. 1) -------------------------------
    return {
        "dense": dense_norm.T,  # (rows, n_dense)
        "multi_hot_ids": hashed.reshape(cfg.n_sparse, rows, L).transpose(1, 0, 2),
        "lengths": _decode_lengths(pages["length_words"], spec, rows),
        "one_hot_ids": gen_hashed.T,  # (rows, n_gen)
        "labels": _decode_labels(pages["label_words"]),
    }


def minibatch_shape_dtypes(spec: TransformSpec, rows: int) -> MiniBatch:
    cfg = spec.cfg
    return {
        "dense": jax.ShapeDtypeStruct((rows, cfg.n_dense), jnp.float32),
        "multi_hot_ids": jax.ShapeDtypeStruct(
            (rows, cfg.n_sparse, cfg.max_sparse_len), jnp.int32
        ),
        "lengths": jax.ShapeDtypeStruct((rows, cfg.n_sparse), jnp.int32),
        "one_hot_ids": jax.ShapeDtypeStruct((rows, cfg.n_generated), jnp.int32),
        "labels": jax.ShapeDtypeStruct((rows,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Stage-split functions for the latency breakdown (Fig. 5 / Fig. 12)


def stage_functions(spec: TransformSpec, *, interpret: bool | None = None):
    """Individually jit-able callables per ETL stage, for stage timing."""
    cfg = spec.cfg

    def extract_decode(pages):
        dense_raw = K.decode_bytesplit(pages["dense_words"], interpret=interpret)
        sparse_raw = K.decode_bitpack(
            pages["sparse_words"], width=cfg.id_width, interpret=interpret
        )
        return dense_raw, sparse_raw

    def gen_bucketize(dense_raw):
        src = jnp.asarray(np.asarray(spec.generated_source, np.int32))
        return K.bucketize(
            jnp.take(dense_raw, src, axis=0),
            spec.bucket_boundaries,
            interpret=interpret,
        )

    def norm_sigridhash(sparse_raw, bucket_ids):
        h = K.sigridhash(
            sparse_raw, spec.sparse_seeds, spec.sparse_max, interpret=interpret
        )
        g = K.sigridhash(bucket_ids, spec.gen_seeds, spec.gen_max, interpret=interpret)
        return h, g

    def norm_log(dense_raw):
        return K.lognorm(dense_raw, interpret=interpret)

    def form_minibatch(pages, dense_norm, hashed, gen_hashed):
        rows = pages["label_words"].shape[0]
        return {
            "dense": dense_norm.T,
            "multi_hot_ids": hashed.reshape(
                cfg.n_sparse, rows, cfg.max_sparse_len
            ).transpose(1, 0, 2),
            "lengths": _decode_lengths(pages["length_words"], spec, rows),
            "one_hot_ids": gen_hashed.T,
            "labels": _decode_labels(pages["label_words"]),
        }

    return {
        "extract_decode": jax.jit(extract_decode),
        "gen_bucketize": jax.jit(gen_bucketize),
        "norm_sigridhash": jax.jit(norm_sigridhash),
        "norm_log": jax.jit(norm_log),
        "form_minibatch": jax.jit(form_minibatch),
    }
