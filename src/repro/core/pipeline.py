"""Producer-consumer training pipeline (paper Fig. 9).

TrainingPipeline glues together:
  train manager    — owns the input queue, feeds the accelerator step;
  preprocess mgr   — spawns preprocessing workers (PrefetchLoader threads)
                     that Extract partitions from the store and Transform
                     them via a PreStoEngine;
  provisioning     — T/P measurement then worker count (core.planner).

Utilization accounting mirrors the paper's Fig. 3: consumer utilization =
time spent inside train steps / wall time; starvation = time blocked on the
queue.  (On this 1-core container the absolute numbers are not TPU numbers —
the *pipeline mechanics* are what is exercised; fleet-scale throughput uses
the analytical model, exactly like the paper's §V-B methodology.)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional

import jax

from repro.core.opgraph import group_times_by_placement, time_stages
from repro.core.planner import (
    PlacementProvisioning,
    ProvisioningPlan,
    measure_throughput,
)
from repro.core.presto import PreStoEngine
from repro.data.loader import PrefetchLoader
from repro.data.storage import PartitionedStore


@dataclasses.dataclass
class PipelineStats:
    steps: int = 0
    train_time_s: float = 0.0
    starved_time_s: float = 0.0
    wall_time_s: float = 0.0
    reissues: int = 0

    @property
    def utilization(self) -> float:
        return self.train_time_s / max(self.wall_time_s, 1e-9)


class TrainingPipeline:
    def __init__(
        self,
        engine: PreStoEngine,
        store: PartitionedStore,
        train_step: Callable,  # (state, minibatch) -> (state, metrics)
        *,
        num_workers: int = 2,
        queue_depth: int = 4,
        straggler_timeout: float = 30.0,
    ):
        self.engine = engine
        self.store = store
        self.train_step = train_step
        self.num_workers = num_workers
        self.queue_depth = queue_depth
        self.straggler_timeout = straggler_timeout
        self._preprocess = engine.jit_preprocess()

    def _produce(self, pid: int):
        """One preprocessing worker's job: Extract + Transform one partition."""
        pages = self.engine.stage_partition(self.store, pid)
        pages = jax.tree.map(jax.numpy.asarray, pages)
        mb = self._preprocess(pages)
        jax.block_until_ready(mb)
        return mb

    def _measure_train_throughput(self, state, probe):
        """Paper step 2's T: stress the train step with one probe batch."""
        rows = int(probe["labels"].shape[0])
        state_holder = [state]

        def train_once():
            new_state, metrics = self.train_step(state_holder[0], probe)
            state_holder[0] = new_state
            return metrics

        return measure_throughput(train_once, rows, iters=5, warmup=2), rows

    def provision(self, state, partition_for_probe: int = 0) -> ProvisioningPlan:
        """Paper step 2: measure T with dummy batches, P per worker, plan T/P."""
        probe = self._produce(partition_for_probe)
        t_meas, rows = self._measure_train_throughput(state, probe)
        p_meas = measure_throughput(
            lambda: self._produce(partition_for_probe), rows, iters=3, warmup=1
        )
        return ProvisioningPlan.derive(t_meas.samples_per_s, p_meas.samples_per_s)

    def provision_by_placement(
        self, state, partition_for_probe: int = 0
    ) -> PlacementProvisioning:
        """Per-placement-group T/P: time the engine's lowered graph stages,
        aggregate per group (isp / host / local assembly), provision each
        group's units independently — ISP units and host workers are
        different resources in hybrid placement."""
        pages = self.engine.stage_partition(self.store, partition_for_probe)
        pages = jax.tree.map(jax.numpy.asarray, pages)
        probe = self._preprocess(pages)
        jax.block_until_ready(probe)
        t_meas, rows = self._measure_train_throughput(state, probe)
        plan = self.engine.lowered_plan
        groups = group_times_by_placement(plan, time_stages(plan, pages))
        group_P = {g: rows / max(t, 1e-9) for g, t in groups.items()}
        return PlacementProvisioning.derive(t_meas.samples_per_s, group_P)

    def run(
        self,
        state,
        partition_ids: Iterable[int],
        *,
        max_steps: Optional[int] = None,
    ) -> tuple[object, PipelineStats, list]:
        stats = PipelineStats()
        metrics_log: list = []
        loader = PrefetchLoader(
            partition_ids,
            self._produce,
            num_workers=self.num_workers,
            depth=self.queue_depth,
            straggler_timeout=self.straggler_timeout,
        ).start()
        wall0 = time.perf_counter()
        try:
            q0 = time.perf_counter()
            for pid, mb in loader:
                stats.starved_time_s += time.perf_counter() - q0
                t0 = time.perf_counter()
                state, metrics = self.train_step(state, mb)
                jax.block_until_ready(metrics)
                stats.train_time_s += time.perf_counter() - t0
                stats.steps += 1
                metrics_log.append(jax.tree.map(float, metrics))
                if max_steps is not None and stats.steps >= max_steps:
                    break
                q0 = time.perf_counter()
        finally:
            loader.stop()
        stats.wall_time_s = time.perf_counter() - wall0
        stats.reissues = loader.work.reissues
        return state, stats, metrics_log
