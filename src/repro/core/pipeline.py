"""Training-side client of a preprocessing Session (paper Fig. 9 consumer).

TrainingPipeline is the train manager: it drains one ``core.service.Session``
(the input queue) into the accelerator step and accounts utilization the way
the paper's Fig. 3 does — consumer utilization = time inside train steps /
wall time; starvation = time blocked on the queue.

New API (multi-tenant, shared pool):

    service = PreprocessingService(num_workers=4)
    session = service.submit(JobSpec(name="job", spec=spec, store=store,
                                     partitions=range(64)))
    pipe = TrainingPipeline(train_step=step)
    state, stats, metrics = pipe.run_session(state, session)

Deprecated single-job shim (identical behavior, warns): the original
``TrainingPipeline(engine, store, train_step)`` constructor plus ``run()``,
which now spins up a private one-job ``PreprocessingService`` per call.

Provisioning (paper §IV-B steps 2-3) stays here: ``provision`` measures T
with a probe batch and P per worker; ``provision_by_placement`` times the
lowered graph stages per placement group (core.planner does the ceil(T/P)).

(On this 1-core container the absolute numbers are not TPU numbers — the
*pipeline mechanics* are what is exercised; fleet-scale throughput uses the
analytical model, exactly like the paper's §V-B methodology.)
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Iterable, Optional

import jax

from repro.core.opgraph import group_times_by_placement, time_stages
from repro.core.planner import (
    PlacementProvisioning,
    ProvisioningPlan,
    measure_throughput,
)
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService, Session
from repro.data.storage import PartitionedStore


@dataclasses.dataclass
class PipelineStats:
    steps: int = 0
    train_time_s: float = 0.0
    starved_time_s: float = 0.0
    wall_time_s: float = 0.0
    reissues: int = 0

    @property
    def utilization(self) -> float:
        return self.train_time_s / max(self.wall_time_s, 1e-9)


class TrainingPipeline:
    def __init__(
        self,
        engine: Optional[PreStoEngine] = None,
        store: Optional[PartitionedStore] = None,
        train_step: Optional[Callable] = None,  # (state, minibatch) -> (state, metrics)
        *,
        num_workers: int = 2,
        queue_depth: int = 4,
        straggler_timeout: float = 30.0,
    ):
        self.engine = engine
        self.store = store
        self.train_step = train_step
        self.num_workers = num_workers
        self.queue_depth = queue_depth
        self.straggler_timeout = straggler_timeout

    def _produce(self, pid: int):
        """One preprocessing worker's job: Extract + Transform one partition."""
        assert self.engine is not None and self.store is not None
        return self.engine.produce_batch(self.store, pid)

    def _measure_train_throughput(self, state, probe):
        """Paper step 2's T: stress the train step with one probe batch."""
        rows = int(probe["labels"].shape[0])
        state_holder = [state]

        def train_once():
            new_state, metrics = self.train_step(state_holder[0], probe)
            state_holder[0] = new_state
            return metrics

        return measure_throughput(train_once, rows, iters=5, warmup=2), rows

    def provision(self, state, partition_for_probe: int = 0) -> ProvisioningPlan:
        """Paper step 2: measure T with dummy batches, P per worker, plan T/P."""
        probe = self._produce(partition_for_probe)
        t_meas, rows = self._measure_train_throughput(state, probe)
        p_meas = measure_throughput(
            lambda: self._produce(partition_for_probe), rows, iters=3, warmup=1
        )
        return ProvisioningPlan.derive(t_meas.samples_per_s, p_meas.samples_per_s)

    def provision_by_placement(
        self, state, partition_for_probe: int = 0
    ) -> PlacementProvisioning:
        """Per-placement-group T/P: time the engine's lowered graph stages,
        aggregate per group (isp / host / local assembly), provision each
        group's units independently — ISP units and host workers are
        different resources in hybrid placement."""
        pages = self.engine.stage_partition(self.store, partition_for_probe)
        # the shared executable may DONATE its page argument on gpu/tpu —
        # hand it a private device copy and keep the numpy pages for the
        # stage timing below
        probe = self.engine.jit_preprocess_cached()(jax.device_put(pages))
        jax.block_until_ready(probe)
        t_meas, rows = self._measure_train_throughput(state, probe)
        plan = self.engine.lowered_plan
        groups = group_times_by_placement(plan, time_stages(plan, pages))
        group_P = {g: rows / max(t, 1e-9) for g, t in groups.items()}
        return PlacementProvisioning.derive(t_meas.samples_per_s, group_P)

    # -- the train-manager loop ------------------------------------------------

    def run_session(
        self,
        state,
        session: Session,
        *,
        max_steps: Optional[int] = None,
    ) -> tuple[object, PipelineStats, list]:
        """Drain a Session into the train step (the Fig. 9 consumer loop).

        Stops after ``max_steps`` (cancelling the rest of the job so its pool
        units go back to other tenants) or when the session is exhausted.
        """
        assert self.train_step is not None, "run_session needs a train_step"
        stats = PipelineStats()
        metrics_log: list = []
        wall0 = time.perf_counter()
        try:
            q0 = time.perf_counter()
            for pid, mb in session:
                stats.starved_time_s += time.perf_counter() - q0
                t0 = time.perf_counter()
                state, metrics = self.train_step(state, mb)
                jax.block_until_ready(metrics)
                stats.train_time_s += time.perf_counter() - t0
                stats.steps += 1
                metrics_log.append(jax.tree.map(float, metrics))
                if max_steps is not None and stats.steps >= max_steps:
                    break
                q0 = time.perf_counter()
        finally:
            if not session.done:
                session.cancel()
        stats.wall_time_s = time.perf_counter() - wall0
        stats.reissues = session.stats().reissues
        return state, stats, metrics_log

    # -- deprecated single-job shim --------------------------------------------

    def run(
        self,
        state,
        partition_ids: Iterable[int],
        *,
        max_steps: Optional[int] = None,
    ) -> tuple[object, PipelineStats, list]:
        """Deprecated: private-pool single-job execution (identical behavior).

        Spins up an ephemeral one-job PreprocessingService; prefer submitting
        a JobSpec to a shared service and calling ``run_session``.
        """
        if self.engine is None or self.store is None:
            raise ValueError(
                "run() requires the deprecated TrainingPipeline(engine, store, "
                "train_step) construction; submit a JobSpec to a "
                "PreprocessingService and use run_session() instead"
            )
        warnings.warn(
            "TrainingPipeline.run(partition_ids) with a private worker pool is "
            "deprecated; submit a JobSpec to a PreprocessingService and use "
            "run_session()",
            DeprecationWarning,
            stacklevel=2,
        )
        with PreprocessingService(num_workers=self.num_workers) as service:
            session = service.submit(
                JobSpec(
                    name="training-pipeline",
                    partitions=list(partition_ids),
                    engine=self.engine,
                    store=self.store,
                    units=self.num_workers,
                    queue_depth=self.queue_depth,
                    straggler_timeout=self.straggler_timeout,
                )
            )
            return self.run_session(state, session, max_steps=max_steps)
