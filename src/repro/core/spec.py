"""TransformSpec: the declarative description of one RecSys ETL Transform.

Mirrors what the paper's preprocess manager receives from the train manager
at job launch (step 2 of Fig. 9): which dense features are Log-normalized,
which are Bucketized into new sparse features (with which boundaries), and
the (seed, table-size) pair for every SigridHash.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synth import RMDataConfig, SyntheticRecSysSource


@dataclasses.dataclass
class TransformSpec:
    cfg: RMDataConfig
    # feature generation (Bucketize): generated feature g reads dense column
    # generated_source[g] and digitizes against bucket_boundaries[g].
    bucket_boundaries: np.ndarray  # (n_generated, bucket_size) f32 sorted
    generated_source: tuple[int, ...]  # static dense-column index per gen feat
    # feature normalization (SigridHash): per-table seed + embedding rows.
    sparse_seeds: np.ndarray  # (n_sparse,) uint32
    sparse_max: np.ndarray  # (n_sparse,) uint32
    gen_seeds: np.ndarray  # (n_generated,) uint32
    gen_max: np.ndarray  # (n_generated,) uint32

    @staticmethod
    def from_source(src: SyntheticRecSysSource) -> "TransformSpec":
        cfg = src.cfg
        return TransformSpec(
            cfg=cfg,
            bucket_boundaries=src.bucket_boundaries,
            generated_source=tuple(int(i) for i in src.generated_source),
            sparse_seeds=(np.arange(cfg.n_sparse, dtype=np.uint32) * 2654435761 + 1),
            sparse_max=np.full(cfg.n_sparse, cfg.embedding_rows, np.uint32),
            gen_seeds=(np.arange(cfg.n_generated, dtype=np.uint32) * 40503 + 7),
            gen_max=np.full(cfg.n_generated, cfg.embedding_rows, np.uint32),
        )

    @property
    def n_tables(self) -> int:
        return self.cfg.n_tables

    def table_sizes(self) -> np.ndarray:
        """Embedding rows per table (multi-hot tables first, then generated)."""
        return np.concatenate([self.sparse_max, self.gen_max]).astype(np.int64)

    # -- operator-graph view ---------------------------------------------------

    def graph(self):
        """This Transform as the declarative operator graph (core.opgraph)."""
        from repro.core.opgraph import build_transform_graph

        return build_transform_graph(self)
