"""T/P provisioning planner (paper §IV-B, software steps 2-3).

The train manager stress-tests the accelerator's max training throughput T
(samples/s) with dummy mini-batches; the preprocess manager measures a single
preprocessing worker's throughput P; the job is provisioned ceil(T/P)
preprocessing workers so the trainer never starves.

With the operator-graph lowering, a job's Transform may span several
*placement groups* (ISP units vs host workers in hybrid placement); each
group is provisioned independently from its own measured group throughput
(``PlacementProvisioning``) — ISP units and CPU workers are separate
resources, so ceil(T/P) applies per group.

Also reproduces the paper's *CPU-baseline* provisioning (Fig. 4): cores
required = T / per-core-throughput, using per-RM per-core throughputs derived
from the paper's published breakdown.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict

import jax


@dataclasses.dataclass
class ThroughputMeasurement:
    samples_per_s: float
    iters: int
    wall_s: float


@dataclasses.dataclass
class ProvisioningPlan:
    train_throughput: float  # T (samples/s)
    worker_throughput: float  # P (samples/s per preprocessing worker)
    workers_required: int  # ceil(T/P)

    @staticmethod
    def derive(T: float, P: float) -> "ProvisioningPlan":
        return ProvisioningPlan(T, P, max(1, math.ceil(T / P)))


@dataclasses.dataclass
class PlacementProvisioning:
    """Per-placement-group provisioning for one job (hybrid-aware T/P)."""

    train_throughput: float  # T (samples/s)
    group_throughput: Dict[str, float]  # group -> P (samples/s per unit)
    group_units: Dict[str, int]  # group -> ceil(T/P)

    @staticmethod
    def derive(T: float, group_P: Dict[str, float]) -> "PlacementProvisioning":
        return PlacementProvisioning(
            T,
            dict(group_P),
            {g: max(1, math.ceil(T / P)) for g, P in group_P.items()},
        )

    @property
    def total_units(self) -> int:
        return sum(self.group_units.values())


def measure_throughput(
    step_fn: Callable[[], object], samples_per_step: int, *, iters: int = 10, warmup: int = 2
) -> ThroughputMeasurement:
    """Stress-test a compiled step with dummy inputs (paper's step 2)."""
    for _ in range(warmup):
        out = step_fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step_fn()
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    return ThroughputMeasurement(samples_per_step * iters / dt, iters, dt)


# -- Paper constants for the CPU-centric baseline (Fig. 4 / Fig. 14) ----------
# Cores required to saturate an 8xA100 node, as published (Fig. 4); RM-level
# per-core preprocessing throughput follows from the paper's training
# throughputs.  These anchor the cost/energy comparisons so the baseline is
# the PAPER's baseline, not a strawman.
PAPER_CORES_REQUIRED_8GPU = {"rm1": 124, "rm2": 243, "rm3": 297, "rm4": 321, "rm5": 367}
PAPER_ISP_UNITS_REQUIRED_8GPU = {"rm1": 3, "rm2": 6, "rm3": 8, "rm4": 8, "rm5": 9}
# Avg end-to-end preprocessing speedup of a single SmartSSD vs a single CPU
# core is implied by the two rows above scaling to the same T:
#   per-unit speedup(RM) = cores / isp_units


def paper_speedup_per_unit(rm: str) -> float:
    return PAPER_CORES_REQUIRED_8GPU[rm] / PAPER_ISP_UNITS_REQUIRED_8GPU[rm]
