"""T/P provisioning planner (paper §IV-B, software steps 2-3).

The train manager stress-tests the accelerator's max training throughput T
(samples/s) with dummy mini-batches; the preprocess manager measures a single
preprocessing worker's throughput P; the job is provisioned ceil(T/P)
preprocessing workers so the trainer never starves.

With the operator-graph lowering, a job's Transform may span several
*placement groups* (ISP units vs host workers in hybrid placement); each
group is provisioned independently from its own measured group throughput
(``PlacementProvisioning``) — ISP units and CPU workers are separate
resources, so ceil(T/P) applies per group.

At the service level (``core.service``) many jobs share ONE provisioned
pool: ``plan_pool`` performs admission control (every job is guaranteed one
unit or is rejected) and splits the pool's units across jobs proportionally
to their ceil(T/P) demands, re-planned whenever jobs join, leave, or
re-estimate P.  A job's demand is discounted by its observed feature-cache
hit rate (``effective_demand_units``): batches served by the shared
``core.featcache.FeatureCache`` need no produce units, so hot jobs free
capacity that rebalances to cold ones.

Units are not fungible: each pool worker models an ISP unit bound to one
storage device.  Passing a ``DeviceTopology`` (and per-job device weights —
the fraction of each job's partitions every device owns) makes ``plan_pool``
additionally provision PER DEVICE (``PoolPlan.device_shares``), so a job
whose partitions concentrate on a hot device cannot starve another job's
units on a cold one.

Also reproduces the paper's *CPU-baseline* provisioning (Fig. 4): cores
required = T / per-core-throughput, using per-RM per-core throughputs derived
from the paper's published breakdown.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, Optional

import jax


@dataclasses.dataclass
class ThroughputMeasurement:
    samples_per_s: float
    iters: int
    wall_s: float


@dataclasses.dataclass
class ProvisioningPlan:
    train_throughput: float  # T (samples/s)
    worker_throughput: float  # P (samples/s per preprocessing worker)
    workers_required: int  # ceil(T/P)

    @staticmethod
    def derive(T: float, P: float) -> "ProvisioningPlan":
        return ProvisioningPlan(T, P, max(1, math.ceil(T / P)))


@dataclasses.dataclass
class PlacementProvisioning:
    """Per-placement-group provisioning for one job (hybrid-aware T/P)."""

    train_throughput: float  # T (samples/s)
    group_throughput: Dict[str, float]  # group -> P (samples/s per unit)
    group_units: Dict[str, int]  # group -> ceil(T/P)

    @staticmethod
    def derive(T: float, group_P: Dict[str, float]) -> "PlacementProvisioning":
        return PlacementProvisioning(
            T,
            dict(group_P),
            {g: max(1, math.ceil(T / P)) for g, P in group_P.items()},
        )

    @property
    def total_units(self) -> int:
        return sum(self.group_units.values())


class AdmissionError(RuntimeError):
    """The shared pool cannot guarantee the 1-unit QoS floor for a new job."""


# -- QoS classes (the Meta DSI combo-job-peak regime) --------------------------
# Release-candidate jobs are the revenue-bearing tier: they may preempt
# exploratory capacity.  Exploratory jobs absorb contention: they are
# degraded to the 1-unit floor first and rejected first when floors no
# longer fit.  Lower rank = higher priority.
QOS_RELEASE_CANDIDATE = "rc"
QOS_EXPLORATORY = "exploratory"
QOS_RANK = {QOS_RELEASE_CANDIDATE: 0, QOS_EXPLORATORY: 1}


@dataclasses.dataclass(frozen=True)
class SloRequest:
    """One job's admission request: demand plus its QoS contract."""

    name: str
    demand_units: int
    qos_class: str = QOS_EXPLORATORY
    deadline_s: Optional[float] = None  # relative to the job's arrival

    @property
    def rank(self) -> int:
        return QOS_RANK.get(self.qos_class, max(QOS_RANK.values()) + 1)


@dataclasses.dataclass(frozen=True)
class SloDecision:
    """Per-job admission outcome: never silent starvation.

    ``admitted``  — granted its full (hit-rate-discounted) demand.
    ``degraded``  — admitted below demand (down to the 1-unit floor) because
                    higher-priority demand or aggregate contention took the
                    surplus; the job runs, slower, and the caller can tell.
    ``rejected``  — even the 1-unit floor does not fit (or a release-
                    candidate preempted this job's floor): the job is turned
                    away NOW instead of being admitted into starvation.
    """

    name: str
    status: str
    granted_units: int
    qos_class: str
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class DeviceTopology:
    """Which pool units are bound to which simulated ISP device.

    The pool is not a fungible bag of workers: each unit is an ISP unit
    bound to ONE storage device (`data.storage.IspDevice`), so provisioning
    must be computed per device — a device's units can only serve partitions
    resident there (or host-fallback work).  ``round_robin`` is the default
    binding the service uses: worker i -> device i % num_devices.
    """

    units_per_device: Dict[int, int]

    @staticmethod
    def round_robin(num_units: int, num_devices: int) -> "DeviceTopology":
        upd = {d: 0 for d in range(num_devices)}
        for i in range(num_units):
            upd[i % num_devices] += 1
        return DeviceTopology(upd)

    @property
    def total_units(self) -> int:
        return sum(self.units_per_device.values())

    @property
    def manned(self) -> set:
        """Devices with at least one bound unit.  Partitions owned by an
        unmanned device have no local ISP unit at all — they are always
        host-fallback eligible."""
        return {d for d, u in self.units_per_device.items() if u > 0}


def _largest_remainder(units: int, weights: Dict[str, float]) -> Dict[str, int]:
    """Split `units` across keys proportionally to non-negative weights."""
    total = sum(weights.values())
    if units <= 0 or total <= 0:
        return {j: 0 for j in weights}
    quotas = {j: units * w / total for j, w in weights.items()}
    out = {j: math.floor(q) for j, q in quotas.items()}
    left = units - sum(out.values())
    for j in sorted(weights, key=lambda j: quotas[j] - out[j], reverse=True):
        if left <= 0:
            break
        out[j] += 1
        left -= 1
    return out


@dataclasses.dataclass
class PoolPlan:
    """Unit allocation of one shared worker/ISP pool across admitted jobs.

    ``demand_units`` is each job's ceil(T/P) requirement (or an explicit
    hint); ``shares`` is what the pool actually grants: every admitted job is
    guaranteed one unit (the admission floor), and surplus capacity is split
    proportionally to residual demand, never exceeding a job's demand.

    With a ``DeviceTopology``, ``device_shares`` additionally splits each
    device's bound units across jobs proportionally to each job's demand ON
    THAT DEVICE (its effective demand weighted by the fraction of its
    partitions the device owns) — so a job whose partitions all sit on a hot
    device cannot starve another job's units on a cold one.
    """

    capacity: int
    demand_units: Dict[str, int]
    shares: Dict[str, int]
    effective_demand: Optional[Dict[str, int]] = None  # after hit-rate discount
    device_shares: Optional[Dict[int, Dict[str, int]]] = None  # device -> job -> units

    @property
    def oversubscribed(self) -> bool:
        """True when aggregate demand exceeds the pool — jobs run degraded."""
        demands = self.effective_demand or self.demand_units
        return sum(demands.values()) > self.capacity

    def device_utilized_units(self, device: int) -> int:
        return sum((self.device_shares or {}).get(device, {}).values())


def qos_demand_units(
    target_samples_per_s: float, worker_samples_per_s: float, *, cap: int = 64
) -> int:
    """ceil(T/P) with the 1-unit floor and a sanity cap: the demand a QoS
    job re-estimates whenever its measured per-worker P moves — on produce
    completions (``core.service.Session._on_produced``) and on tuned
    megabatch-K shifts (``Session._on_tuned_k_changed``), both of which
    funnel through the same re-plan trigger as the hit-rate discount."""
    if not worker_samples_per_s or worker_samples_per_s <= 0:
        return 1
    return max(
        1, min(int(cap), math.ceil(target_samples_per_s / worker_samples_per_s))
    )


def effective_demand_units(demand: int, hit_rate: float) -> int:
    """ceil(T/P) demand discounted by the job's observed feature-cache hit
    rate: a fraction `hit_rate` of the job's partitions arrive without a
    produce, so the units needed to keep its trainer fed shrink by the same
    fraction (never below the 1-unit QoS floor)."""
    rate = min(max(hit_rate, 0.0), 1.0)
    return max(1, math.ceil(max(1, int(demand)) * (1.0 - rate)))


def plan_pool(
    capacity: int,
    demand_units: Dict[str, int],
    hit_rates: Optional[Dict[str, float]] = None,
    *,
    topology: Optional[DeviceTopology] = None,
    device_weights: Optional[Dict[str, Dict[int, float]]] = None,
) -> PoolPlan:
    """Admission control + per-job unit allocation for a shared pool.

    Raises ``AdmissionError`` when the jobs cannot each be guaranteed one
    unit.  Otherwise allocates: 1 unit per job, then the surplus by largest
    remainder proportional to residual demand (capped at each job's demand —
    leftover capacity beyond aggregate demand stays idle for future jobs).

    ``hit_rates`` (job -> observed feature-cache hit rate) discounts each
    job's demand via ``effective_demand_units`` before allocation: a job
    whose partitions mostly arrive from the shared cache needs fewer produce
    units, so the surplus it frees rebalances to cold jobs.

    ``topology`` (which units are bound to which ISP device) switches on
    per-device provisioning: each device's units are split across jobs by
    largest remainder over ``effective demand x device weight``, where
    ``device_weights[job][device]`` is the fraction of the job's partitions
    that device owns (jobs without weights — e.g. produce_fn test hooks with
    no store — spread uniformly).  The per-device split is what isolates a
    cold device's jobs from a hot device's backlog.
    """
    if len(demand_units) > capacity:
        raise AdmissionError(
            f"pool of {capacity} unit(s) cannot guarantee 1 unit to each of "
            f"{len(demand_units)} job(s)"
        )
    demands = {j: max(1, int(d)) for j, d in demand_units.items()}
    if hit_rates:
        demands = {
            j: effective_demand_units(d, hit_rates.get(j, 0.0))
            for j, d in demands.items()
        }
    effective = dict(demands)
    shares = {j: 1 for j in demands}
    residual = {j: d - 1 for j, d in demands.items()}
    surplus = capacity - len(shares)
    total_res = sum(residual.values())
    alloc = min(surplus, total_res)
    if alloc > 0:
        quotas = {j: alloc * residual[j] / total_res for j in residual}
        floors = {j: math.floor(q) for j, q in quotas.items()}
        for j, f in floors.items():
            shares[j] += f
        leftover = alloc - sum(floors.values())
        for j in sorted(residual, key=lambda j: quotas[j] - floors[j], reverse=True):
            if leftover <= 0:
                break
            if shares[j] < demands[j]:
                shares[j] += 1
                leftover -= 1
    device_shares = _device_split(topology, demands, device_weights)
    return PoolPlan(capacity, dict(demand_units), shares, effective, device_shares)


def _device_split(
    topology: Optional[DeviceTopology],
    demands: Dict[str, int],
    device_weights: Optional[Dict[str, Dict[int, float]]],
) -> Optional[Dict[int, Dict[str, int]]]:
    """Per-device unit split across jobs (see ``plan_pool``'s docstring)."""
    if topology is None:
        return None
    ndev = max(len(topology.units_per_device), 1)
    device_shares: Dict[int, Dict[str, int]] = {}
    for d, units in sorted(topology.units_per_device.items()):
        w = {}
        for j in demands:
            jw = (device_weights or {}).get(j)
            frac = jw.get(d, 0.0) if jw is not None else 1.0 / ndev
            w[j] = demands[j] * frac
        device_shares[d] = _largest_remainder(units, w)
    return device_shares


def plan_pool_slo(
    capacity: int,
    requests: "list[SloRequest]",
    hit_rates: Optional[Dict[str, float]] = None,
    *,
    topology: Optional[DeviceTopology] = None,
    device_weights: Optional[Dict[str, Dict[int, float]]] = None,
) -> "tuple[PoolPlan, Dict[str, SloDecision]]":
    """QoS-tiered admission + allocation: reject/degrade, never starve.

    The SLO-aware twin of ``plan_pool``.  Jobs are considered in priority
    order (release-candidate before exploratory; arrival order within a
    tier).  The first ``capacity`` jobs in that order get the 1-unit floor;
    the rest are REJECTED with a decision the caller can surface — a
    release-candidate arriving into a full pool therefore preempts the
    youngest exploratory job's floor rather than being turned away behind
    it.  Surplus units are then granted tier by tier: the release-candidate
    tier's residual demand is satisfied before the exploratory tier sees a
    single surplus unit (proportional largest-remainder within each tier,
    capped at demand).  Every admitted job granted less than its effective
    demand is marked ``degraded`` — the caller knows it runs slow, which is
    the opposite of silent starvation.

    Returns ``(plan, decisions)``: the plan covers admitted jobs only and is
    shaped exactly like ``plan_pool``'s (drop-in for ``PoolPlan`` consumers);
    decisions cover every request, including the rejected ones.
    """
    order = sorted(range(len(requests)), key=lambda i: (requests[i].rank, i))
    admitted = [requests[i] for i in order[: max(capacity, 0)]]
    rejected = [requests[i] for i in order[max(capacity, 0):]]
    decisions: Dict[str, SloDecision] = {}
    for r in rejected:
        decisions[r.name] = SloDecision(
            r.name, "rejected", 0, r.qos_class,
            reason=f"no 1-unit floor in a {capacity}-unit pool "
                   f"({len(requests)} requests)",
        )
    demands = {r.name: max(1, int(r.demand_units)) for r in admitted}
    eff = dict(demands)
    if hit_rates:
        eff = {
            j: effective_demand_units(d, hit_rates.get(j, 0.0))
            for j, d in demands.items()
        }
    shares = {j: 1 for j in demands}
    surplus = capacity - len(shares)
    for rank in sorted({r.rank for r in admitted}):
        if surplus <= 0:
            break
        tier = [r.name for r in admitted if r.rank == rank]
        residual = {j: eff[j] - shares[j] for j in tier if eff[j] > shares[j]}
        total_res = sum(residual.values())
        alloc = min(surplus, total_res)
        if alloc <= 0:
            continue
        quotas = {j: alloc * residual[j] / total_res for j in residual}
        floors = {j: math.floor(q) for j, q in quotas.items()}
        for j, f in floors.items():
            shares[j] += f
        leftover = alloc - sum(floors.values())
        for j in sorted(residual, key=lambda j: quotas[j] - floors[j], reverse=True):
            if leftover <= 0:
                break
            if shares[j] < eff[j]:
                shares[j] += 1
                leftover -= 1
        surplus -= alloc
    for r in admitted:
        granted = shares[r.name]
        if granted >= eff[r.name]:
            decisions[r.name] = SloDecision(
                r.name, "admitted", granted, r.qos_class
            )
        else:
            decisions[r.name] = SloDecision(
                r.name, "degraded", granted, r.qos_class,
                reason=f"granted {granted} of {eff[r.name]} effective unit(s)",
            )
    plan = PoolPlan(
        capacity, demands, shares, eff,
        _device_split(topology, eff, device_weights),
    )
    return plan, decisions


def measure_throughput(
    step_fn: Callable[[], object], samples_per_step: int, *, iters: int = 10, warmup: int = 2
) -> ThroughputMeasurement:
    """Stress-test a compiled step with dummy inputs (paper's step 2)."""
    for _ in range(warmup):
        out = step_fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step_fn()
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    return ThroughputMeasurement(samples_per_step * iters / dt, iters, dt)


# -- Paper constants for the CPU-centric baseline (Fig. 4 / Fig. 14) ----------
# Cores required to saturate an 8xA100 node, as published (Fig. 4); RM-level
# per-core preprocessing throughput follows from the paper's training
# throughputs.  These anchor the cost/energy comparisons so the baseline is
# the PAPER's baseline, not a strawman.
PAPER_CORES_REQUIRED_8GPU = {"rm1": 124, "rm2": 243, "rm3": 297, "rm4": 321, "rm5": 367}
PAPER_ISP_UNITS_REQUIRED_8GPU = {"rm1": 3, "rm2": 6, "rm3": 8, "rm4": 8, "rm5": 9}
# Avg end-to-end preprocessing speedup of a single SmartSSD vs a single CPU
# core is implied by the two rows above scaling to the same T:
#   per-unit speedup(RM) = cores / isp_units


def paper_speedup_per_unit(rm: str) -> float:
    return PAPER_CORES_REQUIRED_8GPU[rm] / PAPER_ISP_UNITS_REQUIRED_8GPU[rm]
