"""Content-addressed cache of preprocessed (train-ready) mini-batches.

Production RecSys training re-preprocesses the *same* samples across jobs
constantly (RecD; Meta's ingestion characterization) — so once PreSto runs as
a multi-tenant service over one shared ISP pool, the highest-leverage saving
left is to not recompute a mini-batch any tenant already produced.  This
module is that saving:

* ``CacheKey`` — content addressing.  A batch is identified by what went in
  and what was done to it: the *partition fingerprint*
  (``data.storage.PartitionedStore.partition_fingerprint`` — equal encoded
  bytes ⇒ equal fingerprint, across store objects and tenants), the
  *lowered-opgraph hash* (``core.opgraph.LoweredPlan.structural_hash`` —
  stable across re-lowering), and the *placement* signature.  Because
  preprocessing is deterministic in the key, a hit is bitwise identical to a
  cold compute, which preserves the service's bitwise-identity guarantee
  (``tests/test_service.py``).

* ``FeatureCache`` — two tiers.  A bounded-memory LRU tier holds hot batches;
  on eviction a batch spills (optionally) to
  ``data.storage.CacheSpillStore``, which parks blocks on the simulated
  storage devices and charges every byte moved to the same cost model as ISP
  placement (``isp_stream_bytes_per_s``).  A spill hit is promoted back into
  the LRU tier.  Misses fall through to recompute.

* In-flight dedup.  Concurrent tenants racing to the same cold key would
  both miss and both produce; ``begin``/``fulfill`` close that window — the
  first prober becomes the *leader* (it produces), later probers *follow*
  (their claims resolve from the leader's in-flight future, no produce).

Wiring (see ``core.service``): the shared ``PreprocessingService`` owns ONE
``FeatureCache``; each session probes it at claim time
(``data.loader.SessionQueue`` short-circuits cached claims so pool workers
never spend a produce on a hit), winners populate it, and
``core.planner.plan_pool`` discounts a job's ceil(T/P) demand by its observed
hit rate so units freed by hits rebalance to cold jobs.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from concurrent.futures import Future
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.data.storage import CacheSpillStore

__all__ = [
    "BlockKey",
    "CacheKey",
    "CacheStats",
    "FeatureCache",
    "default_spill_store",
]


@dataclasses.dataclass(frozen=True)
class CacheKey:
    """Content address of one preprocessed mini-batch."""

    partition_fp: str  # PartitionedStore.partition_fingerprint(pid)
    plan_hash: str  # LoweredPlan.structural_hash() of the lowered Transform
    placement: str  # engine placement signature (comm placement included)

    def block_id(self) -> str:
        """Flat id used by the spill tier's per-device block files."""
        return f"{self.partition_fp}-{self.plan_hash}-{self.placement}"


@dataclasses.dataclass(frozen=True)
class BlockKey:
    """Content address of ONE hashed sparse block (dedup datasets).

    Sample-level dedup (RecD) shares sparse-feature blocks across sessions,
    partitions and tenants; the per-partition ``CacheKey`` cannot see that
    overlap.  A ``BlockKey`` addresses the train-ready form of one unique
    block — its SigridHashed ids + lengths — by the block's content
    fingerprint (``data.storage.PartitionedStore.block_fingerprints``) plus
    the same plan/placement components as ``CacheKey``, so two tenants whose
    partitions merely SHARE blocks (same session pool, different pids) reuse
    each other's hashed blocks at block granularity."""

    block_fp: str  # PartitionedStore.block_fingerprints(pid)[b]
    plan_hash: str  # LoweredPlan.structural_hash() of the lowered Transform
    placement: str  # engine placement signature (comm placement included)


@dataclasses.dataclass
class CacheStats:
    """Point-in-time accounting for one FeatureCache."""

    hits: int = 0  # total hits (memory tier + spill tier)
    spill_hits: int = 0  # hits served by the spill tier (subset of hits)
    follows: int = 0  # probes that joined a leader's in-flight produce
    misses: int = 0
    # predictive pre-warm probes (issued AHEAD of the claim cursor by the
    # service's peek-window walker); tallied apart from hits/misses so
    # hit_rate keeps meaning "fraction of CLAIMS needing no produce" — the
    # claim that later lands on a pre-warmed key still counts itself
    prewarm_hits: int = 0  # pre-warm probes that found the content cached
    prewarm_leases: int = 0  # pre-warm probes that took a produce lease
    insertions: int = 0
    evictions: int = 0  # LRU-tier evictions (spilled or dropped)
    entries: int = 0  # LRU-tier entries right now
    resident_bytes: int = 0  # LRU-tier bytes right now
    spilled_entries: int = 0
    spilled_bytes: int = 0
    bytes_served: int = 0  # batch bytes returned by hits
    # block tier (dedup datasets): hashed sparse blocks shared across
    # partitions/tenants at block granularity
    block_hits: int = 0
    block_misses: int = 0
    block_insertions: int = 0
    block_entries: int = 0
    block_resident_bytes: int = 0
    spill_io_s: float = 0.0  # modeled seconds of spill-tier byte movement
    # device -> modeled seconds: spill residency is charged to each block's
    # OWNING simulated device, not a global pot
    spill_io_s_by_device: Dict[int, float] = dataclasses.field(default_factory=dict)
    warm_started: int = 0  # blocks promoted into the LRU tier at boot

    @property
    def probes(self) -> int:
        return self.hits + self.follows + self.misses

    @property
    def hit_rate(self) -> float:
        """Fraction of probes that needed no produce (hits + follows)."""
        return (self.hits + self.follows) / self.probes if self.probes else 0.0


def default_spill_store(
    num_devices: int = 4,
    *,
    capacity_bytes: Optional[int] = None,
    root: Optional[str] = None,
    model=None,
    fleet=None,
) -> CacheSpillStore:
    """A spill tier charged at the ISP placement cost model's stream rate —
    cache residency moves bytes on the same simulated devices, priced the
    same way as the ISP units' own SSD->FPGA streams.  Pass the service's
    shared ``data.storage.DeviceFleet`` so spill traffic lands on the same
    per-device ledgers partition reads and ISP compute charge."""
    from repro.core.costmodel import DEFAULT_PLACEMENT_MODEL  # lazy: no cycle

    model = model or DEFAULT_PLACEMENT_MODEL
    return CacheSpillStore(
        num_devices,
        capacity_bytes=capacity_bytes,
        bytes_per_s=model.isp_stream_bytes_per_s,
        root=root,
        fleet=fleet,
    )


def batch_nbytes(batch: Any) -> int:
    """Size in bytes of one train-ready mini-batch (dict of arrays)."""
    try:
        return sum(int(np.asarray(v).nbytes) for v in batch.values())
    except Exception:
        return 0


class FeatureCache:
    """Bounded-memory LRU of train-ready batches, with an optional spill tier.

    Thread-safe; shared by every session of a ``PreprocessingService``.
    Sessions use ``begin``/``fulfill``/``abandon`` (claim-time probe with
    in-flight dedup); ``get``/``put``/``peek`` are the tier primitives.  The
    batch object is stored as produced (and spilled/restored as numpy), so a
    hit returns values bitwise identical to the cold compute that populated
    it.
    """

    def __init__(
        self,
        capacity_bytes: int = 256 << 20,
        *,
        spill: Optional[CacheSpillStore] = None,
        block_capacity_bytes: Optional[int] = None,
    ):
        assert capacity_bytes > 0
        self.capacity_bytes = capacity_bytes
        self.spill = spill
        self._lru: "OrderedDict[CacheKey, Tuple[Any, int]]" = OrderedDict()
        self._resident = 0
        # block tier: hashed sparse blocks of dedup datasets, its own small
        # LRU (memory-only — blocks are tiny next to batches and recompute
        # is one fused launch away)
        self.block_capacity_bytes = (
            block_capacity_bytes
            if block_capacity_bytes is not None
            else capacity_bytes // 4
        )
        self._blocks: "OrderedDict[BlockKey, Tuple[Any, int]]" = OrderedDict()
        self._block_resident = 0
        self._block_hits = 0
        self._block_misses = 0
        self._block_insertions = 0
        self._inflight: Dict[CacheKey, Future] = {}  # leader produces
        self._lock = threading.Lock()
        self._hits = 0
        self._spill_hits = 0
        self._follows = 0
        self._misses = 0
        self._prewarm_hits = 0
        self._prewarm_leases = 0
        self._insertions = 0
        self._evictions = 0
        self._bytes_served = 0
        self._warm_started = 0
        self._warmed = False

    def warm_start(self) -> int:
        """Rebuild the LRU index from the spill tier's restart-survivable
        blocks (newest first, up to the memory bound).

        After a service restart the spill tier rescans its ``.npz`` blocks
        from disk, but the memory tier starts cold; promoting the freshest
        blocks back at boot means a restarted service serves bitwise-
        identical hits without a single recompute.  Blocks past the memory
        bound stay spilled — they still hit through the spill tier.  The
        promotion I/O is real modeled byte movement (charged to each
        block's owning device).  Idempotent per cache; returns the number
        of blocks promoted."""
        if self._warmed or self.spill is None or self.spill.root is None:
            return 0
        self._warmed = True
        picked = []  # newest-first selection, bounded by the memory tier
        budget = self.capacity_bytes
        for block_id in reversed(self.spill.keys()):
            parts = block_id.split("-", 2)
            if len(parts) != 3:
                continue  # foreign file in the spill root: not ours
            key = CacheKey(*parts)
            with self._lock:
                if key in self._lru:
                    continue
            block = self.spill.read(block_id)
            if block is None:
                continue
            nbytes = batch_nbytes(block)
            if nbytes <= 0 or nbytes > budget:
                break  # memory tier full: the rest stays spilled (hit-able)
            budget -= nbytes
            picked.append((key, block))
        # insert OLDEST first so LRU recency matches block age: the newest
        # block ends most-recently-used, never the first eviction victim
        for key, block in reversed(picked):
            self.put(key, block)
        with self._lock:
            self._warm_started = len(picked)
        return len(picked)

    def flush_spill(self) -> int:
        """Write every memory-tier entry through to a ROOTED spill tier (the
        restart checkpoint ``warm_start`` rebuilds from).  Content-addressed,
        so blocks already spilled are skipped; returns blocks written.  The
        service calls this on ``close()`` so a graceful shutdown leaves the
        whole cache restart-survivable, not just the evicted part."""
        if self.spill is None or self.spill.root is None:
            return 0
        with self._lock:
            entries = [(k, b) for k, (b, _n) in self._lru.items()]
        written = 0
        for key, batch in entries:
            block_id = key.block_id()
            if block_id in self.spill:
                continue
            self.spill.write(
                block_id, {k: np.asarray(v) for k, v in batch.items()}
            )
            written += 1
        return written

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)

    def _lookup(self, key: CacheKey, *, record: bool) -> Optional[Any]:
        """Probe both tiers.  Tier effects (LRU recency, spill promotion)
        always happen; hit accounting only when ``record`` — pre-warm probes
        want the promotion without inflating the claim-path hit stats."""
        with self._lock:
            entry = self._lru.get(key)
            if entry is not None:
                self._lru.move_to_end(key)
                if record:
                    self._hits += 1
                    self._bytes_served += entry[1]
                # shallow copy: consumers may mutate their batch dict; the
                # array buffers are shared (jax arrays are immutable)
                return dict(entry[0])
        if self.spill is not None:
            block = self.spill.read(key.block_id())
            if block is not None:
                with self._lock:
                    if record:
                        self._hits += 1
                        self._spill_hits += 1
                        self._bytes_served += batch_nbytes(block)
                self.put(key, block)  # promote (insertion counted as such)
                return block
        return None

    def peek(self, key: CacheKey) -> Optional[Any]:
        """Probe both tiers, counting a hit but never a miss (used by
        straggler re-issues, which must fall through to a real produce
        rather than follow the possibly-stuck in-flight leader)."""
        return self._lookup(key, record=True)

    def get(self, key: CacheKey) -> Optional[Any]:
        """The batch for `key`, or None.  Hits refresh LRU recency; spill
        hits are promoted back into the memory tier."""
        batch = self.peek(key)
        if batch is None:
            with self._lock:
                self._misses += 1
        return batch

    def begin(self, key: CacheKey, *, prewarm: bool = False) -> Tuple[str, Any]:
        """Claim-time probe with in-flight dedup.  Returns one of

        * ``("hit", batch)``     — cached; use the batch, no produce.
        * ``("follow", future)`` — another tenant is producing this exact
          batch right now; resolve from its future, no produce.
        * ``("produce", None)``  — the caller is the leader: produce, then
          ``fulfill`` (or ``abandon`` on error) so followers resolve.

        ``prewarm=True`` marks a predictive probe issued AHEAD of the claim
        cursor (the service's peek-window pre-warmer).  Tier effects are
        identical — a spill hit is promoted so the upcoming claim lands in
        the memory tier, and a cold key takes the leader lease so
        concurrent tenants follow instead of duplicating the produce — but
        the probe is tallied under ``prewarm_hits``/``prewarm_leases``
        instead of hits/follows/misses, keeping ``hit_rate`` a claim-path
        statistic (the claim that follows the pre-warm counts itself).
        """
        batch = self._lookup(key, record=not prewarm)
        if batch is not None:
            if prewarm:
                with self._lock:
                    self._prewarm_hits += 1
            return "hit", batch
        with self._lock:
            fut = self._inflight.get(key)
            if fut is not None:
                if not prewarm:
                    self._follows += 1
                return "follow", fut
            self._inflight[key] = Future()
            if prewarm:
                self._prewarm_leases += 1
            else:
                self._misses += 1
            return "produce", None

    def fulfill(self, key: CacheKey, batch: Any) -> None:
        """A produce of `key` completed: insert and resolve any followers."""
        self.put(key, batch)
        with self._lock:
            fut = self._inflight.pop(key, None)
        if fut is not None:
            fut.set_result(batch)

    def abandon(self, key: CacheKey, exc: Optional[BaseException] = None) -> None:
        """The leader's produce failed (or was dropped): unblock followers.

        With `exc`, followers see the error (preprocessing is deterministic
        in the key, so their own produce would fail identically); without,
        the future is cancelled and followers' straggler machinery re-issues
        a real produce."""
        with self._lock:
            fut = self._inflight.pop(key, None)
        if fut is None:
            return
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.cancel()

    def put(self, key: CacheKey, batch: Any) -> None:
        """Insert (idempotent — concurrent winners of the same key collapse
        to one entry), evicting LRU entries past the memory bound."""
        nbytes = batch_nbytes(batch)
        if nbytes <= 0 or nbytes > self.capacity_bytes:
            return  # unsized or oversized batches are not cacheable
        batch = dict(batch)  # detach from the producer's mutable dict
        evicted = []
        with self._lock:
            old = self._lru.pop(key, None)
            if old is not None:
                self._resident -= old[1]
            self._lru[key] = (batch, nbytes)
            self._resident += nbytes
            self._insertions += 1
            while self._resident > self.capacity_bytes and len(self._lru) > 1:
                old_key, (old_batch, old_bytes) = self._lru.popitem(last=False)
                self._resident -= old_bytes
                self._evictions += 1
                evicted.append((old_key, old_batch))
        if self.spill is not None:
            for old_key, old_batch in evicted:
                block_id = old_key.block_id()
                if block_id in self.spill:
                    continue  # content-addressed: the spilled copy (kept on
                    # promote) is already byte-identical — skip the rewrite
                self.spill.write(
                    block_id,
                    {k: np.asarray(v) for k, v in old_batch.items()},
                )

    # -- block tier (dedup datasets) ----------------------------------------

    def put_block(self, key: BlockKey, ids: np.ndarray, lens: np.ndarray) -> None:
        """Insert one hashed sparse block: ``(ids (S, L) i32, lens (S,) i32)``.

        Idempotent by content address; evicts LRU blocks past the block
        tier's own byte bound.  Publishers pass slices of a produced batch
        (``PreStoEngine.extract_blocks``)."""
        ids = np.asarray(ids)
        lens = np.asarray(lens)
        nbytes = int(ids.nbytes) + int(lens.nbytes)
        if nbytes <= 0 or nbytes > self.block_capacity_bytes:
            return
        with self._lock:
            old = self._blocks.pop(key, None)
            if old is not None:
                self._block_resident -= old[1]
            self._blocks[key] = ((ids, lens), nbytes)
            self._block_resident += nbytes
            self._block_insertions += 1
            while (
                self._block_resident > self.block_capacity_bytes
                and len(self._blocks) > 1
            ):
                _, (_b, old_bytes) = self._blocks.popitem(last=False)
                self._block_resident -= old_bytes

    def get_block(self, key: BlockKey) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """One cached block's ``(ids, lens)``, or None.  Refreshes recency."""
        with self._lock:
            entry = self._blocks.get(key)
            if entry is None:
                self._block_misses += 1
                return None
            self._blocks.move_to_end(key)
            self._block_hits += 1
            return entry[0]

    def get_blocks(
        self, keys
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """All-or-nothing probe of a partition's block set.

        Full coverage returns the STACKED ``(ids (u, S, L), lens (u, S))``
        ready for ``PreStoEngine.assemble_from_blocks``; any absent block
        returns None (the partition cold-produces, then publishes).  Counts
        one block hit/miss per key."""
        keys = list(keys)
        out = []
        with self._lock:
            missing = [k for k in keys if k not in self._blocks]
            if missing:
                self._block_misses += len(missing)
                self._block_hits += len(keys) - len(missing)
                return None
            for k in keys:
                self._blocks.move_to_end(k)
                out.append(self._blocks[k][0])
            self._block_hits += len(keys)
        ids = np.stack([b[0] for b in out])
        lens = np.stack([b[1] for b in out])
        return ids, lens

    def stats(self) -> CacheStats:
        with self._lock:
            stats = CacheStats(
                hits=self._hits,
                spill_hits=self._spill_hits,
                follows=self._follows,
                misses=self._misses,
                prewarm_hits=self._prewarm_hits,
                prewarm_leases=self._prewarm_leases,
                insertions=self._insertions,
                evictions=self._evictions,
                entries=len(self._lru),
                resident_bytes=self._resident,
                bytes_served=self._bytes_served,
                block_hits=self._block_hits,
                block_misses=self._block_misses,
                block_insertions=self._block_insertions,
                block_entries=len(self._blocks),
                block_resident_bytes=self._block_resident,
                warm_started=self._warm_started,
            )
        if self.spill is not None:
            stats.spilled_entries = len(self.spill)
            stats.spilled_bytes = self.spill.resident_bytes
            stats.spill_io_s = self.spill.modeled_io_s
            stats.spill_io_s_by_device = {
                d: s for d, s in enumerate(self.spill.io_s_by_device) if s > 0.0
            }
        return stats
