# The paper's primary contribution: storage-centric (ISP) data preprocessing
# for RecSys training, as a composable JAX module.  The Transform itself is
# an operator graph (opgraph) lowered per placement; presto/disagg/hybrid
# placement and fusion are compiler decisions, not separate code paths.
from repro.core.autotune import DEFAULT_AUTOTUNE_KMAX, MegabatchTuner, k_ladder
from repro.core.costmodel import (
    Comparison,
    ContentionAwareCostModel,
    DeviceModel,
    PartitionCosts,
    PlacementCostModel,
    choose_placement,
    cost_efficiency,
    energy_efficiency,
    partition_costs,
    tco_usd,
)
from repro.core.ctrlplane import (
    Autoscaler,
    AutoscalePolicy,
    Event,
    EventLog,
    FailureInjector,
    SessionCheckpoint,
    SimulatedFailure,
    parse_kill_spec,
)
from repro.core.featcache import (
    BlockKey,
    CacheKey,
    CacheStats,
    FeatureCache,
    default_spill_store,
)
from repro.core.opgraph import (
    FAMILIES,
    OpGraph,
    build_transform_graph,
    lower,
    lower_transform,
    resolve_placements,
)
from repro.core.pipeline import PipelineStats, TrainingPipeline
from repro.core.planner import (
    AdmissionError,
    DeviceTopology,
    PlacementProvisioning,
    PoolPlan,
    ProvisioningPlan,
    measure_throughput,
    plan_pool,
)
from repro.core.preprocess import (
    execute_plan,
    minibatch_shape_dtypes,
    pages_from_partition,
    pages_shape_dtypes,
    preprocess_pages,
    stage_functions,
)
from repro.core.presto import PreStoEngine, minibatch_pspec, pages_pspec
from repro.core.service import (
    JobSpec,
    PreprocessingService,
    Session,
    SessionStats,
)
from repro.core.spec import TransformSpec

__all__ = [
    "AdmissionError",
    "Autoscaler",
    "AutoscalePolicy",
    "BlockKey",
    "CacheKey",
    "CacheStats",
    "Comparison",
    "ContentionAwareCostModel",
    "DEFAULT_AUTOTUNE_KMAX",
    "DeviceModel",
    "DeviceTopology",
    "Event",
    "EventLog",
    "FAMILIES",
    "FailureInjector",
    "FeatureCache",
    "JobSpec",
    "MegabatchTuner",
    "OpGraph",
    "PartitionCosts",
    "PipelineStats",
    "PlacementCostModel",
    "PlacementProvisioning",
    "PoolPlan",
    "PreStoEngine",
    "PreprocessingService",
    "ProvisioningPlan",
    "Session",
    "SessionCheckpoint",
    "SessionStats",
    "SimulatedFailure",
    "TrainingPipeline",
    "TransformSpec",
    "build_transform_graph",
    "choose_placement",
    "cost_efficiency",
    "default_spill_store",
    "energy_efficiency",
    "execute_plan",
    "k_ladder",
    "lower",
    "lower_transform",
    "measure_throughput",
    "minibatch_pspec",
    "minibatch_shape_dtypes",
    "pages_from_partition",
    "pages_pspec",
    "pages_shape_dtypes",
    "parse_kill_spec",
    "partition_costs",
    "plan_pool",
    "preprocess_pages",
    "resolve_placements",
    "stage_functions",
    "tco_usd",
]
