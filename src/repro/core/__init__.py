# The paper's primary contribution: storage-centric (ISP) data preprocessing
# for RecSys training, as a composable JAX module.
from repro.core.costmodel import (
    Comparison,
    DeviceModel,
    cost_efficiency,
    energy_efficiency,
    tco_usd,
)
from repro.core.pipeline import PipelineStats, TrainingPipeline
from repro.core.planner import ProvisioningPlan, measure_throughput
from repro.core.preprocess import (
    minibatch_shape_dtypes,
    pages_from_partition,
    pages_shape_dtypes,
    preprocess_pages,
    stage_functions,
)
from repro.core.presto import PreStoEngine, minibatch_pspec, pages_pspec
from repro.core.spec import TransformSpec

__all__ = [
    "Comparison",
    "DeviceModel",
    "PipelineStats",
    "PreStoEngine",
    "ProvisioningPlan",
    "TrainingPipeline",
    "TransformSpec",
    "cost_efficiency",
    "energy_efficiency",
    "measure_throughput",
    "minibatch_pspec",
    "minibatch_shape_dtypes",
    "pages_from_partition",
    "pages_pspec",
    "pages_shape_dtypes",
    "preprocess_pages",
    "stage_functions",
    "tco_usd",
]
