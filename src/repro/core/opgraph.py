"""Operator-graph IR for the ETL Transform, with placement-aware lowering.

The Transform (encoded pages -> train-ready mini-batch) is declared ONCE as a
graph of typed operators over *column families* — independent groups of
columns that flow through their own decode->transform chain:

    family    pages consumed     chain                              batch key
    dense     dense_words        Decode(bytesplit) -> LogNorm       dense
    sparse    sparse_words       Decode(bitpack)   -> SigridHash    multi_hot_ids
    gen       gen_words [1]      Decode -> Bucketize -> SigridHash  one_hot_ids
    lengths   length_words       Decode(lengths)                    lengths
    labels    label_words        Decode(labels)                     labels

    [1] gen_words = the sourced dense planes (``spec.generated_source``),
        bound by ``prepare_env`` so the family is independent of `dense`.

A *placement* assigns each family to ``"isp"`` (the in-storage unit) or
``"host"`` (a CPU-style preprocessing server).  ``lower`` turns graph +
placement into an ordered stage list:

* an ISP-placed chain whose kind tuple appears in the op->kernel registry
  (``repro.kernels.FUSED_KERNELS``) lowers to ONE fused Pallas kernel —
  one read of encoded bytes, one write of tensors (the PreSto pipeline);
* a host-placed chain lowers to one stage per operator (the Disagg-style
  multi-pass baseline, also what the per-stage latency breakdown times).

The lowered plan is what every public entry point executes:
``preprocess_pages(mode=...)``, ``stage_functions`` and ``PreStoEngine``
are thin wrappers that build/lower this graph.  ``PreStoEngine`` renders a
family's host placement as collective-permutes on the data axis for exactly
that family's pages and outputs — so a ``hybrid`` placement moves only the
bytes of the families it actually sends to hosts.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec import TransformSpec
from repro.kernels import FUSED_KERNELS
from repro.kernels import ops as K
from repro.kernels import ref as R

ISP = "isp"
HOST = "host"
FAMILIES = ("dense", "sparse", "gen", "lengths", "labels")

# column family -> page values consumed / mini-batch keys produced.  The
# PreStoEngine uses these to hop exactly one family's traffic when that
# family is host-placed.
FAMILY_PAGE_VALUES: Dict[str, Tuple[str, ...]] = {
    "dense": ("dense_words",),
    "sparse": ("sparse_words",),
    "gen": ("gen_words",),
    "lengths": ("length_words",),
    "labels": ("label_words",),
}
FAMILY_BATCH_KEYS: Dict[str, Tuple[str, ...]] = {
    "dense": ("dense",),
    "sparse": ("multi_hot_ids",),
    "gen": ("one_hot_ids",),
    "lengths": ("lengths",),
    "labels": ("labels",),
}


# ---------------------------------------------------------------------------
# Nodes


@dataclasses.dataclass(frozen=True)
class OpNode:
    """One typed operator: consumes named values, produces one named value."""

    name: str
    family: str
    inputs: Tuple[str, ...]
    output: str

    @property
    def kind(self) -> str:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Decode(OpNode):
    encoding: str = "bytesplit"  # bytesplit | bitpack | lengths | labels
    width: int = 0  # bits per value (bitpack / lengths)

    @property
    def kind(self) -> str:
        return f"decode.{self.encoding}"


@dataclasses.dataclass(frozen=True)
class Bucketize(OpNode):
    @property
    def kind(self) -> str:
        return "bucketize"


@dataclasses.dataclass(frozen=True)
class SigridHash(OpNode):
    table: str = "sparse"  # which (seeds, max) bank of the spec: sparse | gen

    @property
    def kind(self) -> str:
        return "sigridhash"


@dataclasses.dataclass(frozen=True)
class LogNorm(OpNode):
    @property
    def kind(self) -> str:
        return "lognorm"


@dataclasses.dataclass(frozen=True)
class FormBatch(OpNode):
    @property
    def kind(self) -> str:
        return "formbatch"


# ---------------------------------------------------------------------------
# Graph


@dataclasses.dataclass(frozen=True)
class OpGraph:
    """Nodes + the page values bound externally; edges are value names."""

    nodes: Tuple[OpNode, ...]
    page_inputs: Tuple[str, ...]

    def __post_init__(self):
        produced = set(self.page_inputs)
        for n in self.nodes:  # nodes must already be topo-ordered
            missing = [i for i in n.inputs if i not in produced]
            if missing:
                raise ValueError(f"node {n.name} consumes unknown values {missing}")
            if n.output in produced:
                raise ValueError(f"value {n.output} produced twice")
            produced.add(n.output)

    def node(self, name: str) -> OpNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def family_chain(self, family: str) -> Tuple[OpNode, ...]:
        """The family's operators, in dependency order (graph order)."""
        return tuple(n for n in self.nodes if n.family == family)

    @property
    def families(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for n in self.nodes:
            if n.family not in seen and not isinstance(n, FormBatch):
                seen.append(n.family)
        return tuple(seen)


def build_transform_graph(spec: TransformSpec) -> OpGraph:
    """The standard RecSys ETL Transform (paper Fig. 1) as an OpGraph."""
    cfg = spec.cfg
    nodes = (
        Decode("decode_dense", "dense", ("dense_words",), "dense_raw",
               encoding="bytesplit"),
        LogNorm("lognorm_dense", "dense", ("dense_raw",), "dense_norm"),
        Decode("decode_sparse", "sparse", ("sparse_words",), "sparse_raw",
               encoding="bitpack", width=cfg.id_width),
        SigridHash("hash_sparse", "sparse", ("sparse_raw",), "sparse_hashed",
                   table="sparse"),
        Decode("decode_gen", "gen", ("gen_words",), "gen_raw",
               encoding="bytesplit"),
        Bucketize("bucketize_gen", "gen", ("gen_raw",), "bucket_ids"),
        SigridHash("hash_gen", "gen", ("bucket_ids",), "gen_hashed",
                   table="gen"),
        Decode("decode_lengths", "lengths", ("length_words",), "lengths_i32",
               encoding="lengths", width=cfg.len_width),
        Decode("decode_labels", "labels", ("label_words",), "labels_f32",
               encoding="labels"),
        FormBatch(
            "form_batch", "batch",
            ("dense_norm", "sparse_hashed", "lengths_i32", "labels_f32",
             "gen_hashed"),
            "minibatch",
        ),
    )
    return OpGraph(
        nodes=nodes,
        page_inputs=("dense_words", "sparse_words", "length_words",
                     "label_words", "gen_words"),
    )


def prepare_env(pages: Dict[str, jax.Array], spec: TransformSpec) -> Dict[str, Any]:
    """Bind graph page inputs from the staged page arrays.

    ``gen_words`` (the generated features' source planes) is a static gather
    of dense pages — computed here so the gen family never depends on the
    dense family's placement.
    """
    env = dict(pages)
    src = jnp.asarray(np.asarray(spec.generated_source, np.int32))
    env["gen_words"] = jnp.take(pages["dense_words"], src, axis=0)
    return env


# ---------------------------------------------------------------------------
# Placement resolution


def resolve_placements(mode, spec: TransformSpec, rows: int | None = None) -> Dict[str, str]:
    """mode -> {family: "isp"|"host"}.

    str modes: "fused"/"presto"/"isp" (all ISP), "unfused"/"disagg"/"host"
    (all host), or "hybrid" (per-family choice by the cost model).  A dict is
    taken verbatim (validated).
    """
    if isinstance(mode, dict):
        unknown = set(mode) - set(FAMILIES)
        if unknown:
            raise ValueError(f"unknown column families {sorted(unknown)}")
        bad = {f: p for f, p in mode.items() if p not in (ISP, HOST)}
        if bad:
            raise ValueError(f"placements must be 'isp' or 'host', got {bad}")
        out = {f: ISP for f in FAMILIES}
        out.update(mode)
        return out
    if mode in ("fused", "presto", ISP):
        return {f: ISP for f in FAMILIES}
    if mode in ("unfused", "disagg", HOST):
        return {f: HOST for f in FAMILIES}
    if mode == "hybrid":
        from repro.core.costmodel import choose_placement  # lazy: avoids cycle

        return choose_placement(spec, rows)
    raise ValueError(f"unknown mode/placement {mode!r}")


# ---------------------------------------------------------------------------
# Byte accounting (shared by the cost model and the collective tests)


def family_page_bytes(spec: TransformSpec, rows: int) -> Dict[str, int]:
    """Encoded bytes each family reads, per partition of `rows`.

    Dedup datasets (``cfg.dup_factor > 1``) store sparse/length pages at
    unique-block geometry, so those families read ``rows / dup_factor``
    rows' worth of encoded words (plus the 4-byte-per-sample refs page,
    charged to the sparse family that consumes it).  Dense/gen/labels stay
    per-sample.
    """
    cfg = spec.cfg
    d = max(int(getattr(cfg, "dup_factor", 1)), 1)
    u = rows // d
    return {
        "dense": cfg.n_dense * rows * 4,  # bytesplit: 4 plane bytes / value
        "sparse": cfg.n_sparse * (u * cfg.max_sparse_len // 32)
        * cfg.id_width * 4
        + (rows * 4 if d > 1 else 0),
        "gen": cfg.n_generated * rows * 4,  # sourced dense planes
        "lengths": cfg.n_sparse * (u // 32) * cfg.len_width * 4,
        "labels": rows * 4,
    }


def family_batch_bytes(spec: TransformSpec, rows: int) -> Dict[str, int]:
    """Train-ready tensor bytes each family writes, per partition of `rows`."""
    cfg = spec.cfg
    return {
        "dense": rows * cfg.n_dense * 4,
        "sparse": rows * cfg.n_sparse * cfg.max_sparse_len * 4,
        "gen": rows * cfg.n_generated * 4,
        "lengths": rows * cfg.n_sparse * 4,
        "labels": rows * 4,
    }


# ---------------------------------------------------------------------------
# Lowering


@dataclasses.dataclass
class Stage:
    """One executable unit of the lowered plan (a fused kernel or one op)."""

    name: str
    kind: str
    family: str
    placement: str  # "isp" | "host" | "local" (pure assembly)
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    fn: Callable[..., tuple]
    node_names: Tuple[str, ...]


def _spec_digest(spec: TransformSpec) -> str:
    """Content digest of everything the Transform's output depends on."""
    h = hashlib.sha256()
    h.update(
        json.dumps(dataclasses.asdict(spec.cfg), sort_keys=True, default=str).encode()
    )
    h.update(json.dumps([int(i) for i in spec.generated_source]).encode())
    for arr in (
        spec.bucket_boundaries,
        spec.sparse_seeds,
        spec.sparse_max,
        spec.gen_seeds,
        spec.gen_max,
    ):
        a = np.ascontiguousarray(np.asarray(arr))
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class LoweredPlan:
    spec: TransformSpec
    placements: Dict[str, str]
    stages: List[Stage]
    graph: OpGraph

    def structural_hash(self) -> str:
        """Stable content hash of the lowered graph (survives re-lowering).

        Covers the spec's transform parameters (boundaries, seeds, table
        sizes, geometry), the per-family placements, and the lowered stage
        structure (names, kinds, wiring) — but NOT the bound Python callables,
        so two independent lowerings of the same spec+placement hash alike.
        This is the ``lowered-opgraph hash`` component of a feature-cache key
        (``core.featcache.CacheKey``)."""
        h = hashlib.sha256()
        h.update(_spec_digest(self.spec).encode())
        h.update(json.dumps(sorted(self.placements.items())).encode())
        for st in self.stages:
            h.update(
                json.dumps(
                    [st.name, st.kind, st.family, st.placement,
                     list(st.inputs), list(st.outputs), list(st.node_names)]
                ).encode()
            )
        return h.hexdigest()[:16]

    def execute_env(self, env: Dict[str, Any]) -> Dict[str, jax.Array]:
        env = dict(env)
        for st in self.stages:
            vals = st.fn(*(env[k] for k in st.inputs))
            env.update(zip(st.outputs, vals))
        return env["minibatch"]

    def execute(self, pages: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        return self.execute_env(prepare_env(pages, self.spec))

    def stage(self, name: str) -> Stage:
        for st in self.stages:
            if st.name == name:
                return st
        raise KeyError(name)

    def host_families(self) -> Tuple[str, ...]:
        return tuple(f for f in FAMILIES if self.placements.get(f) == HOST)

    def megabatch_safe(self) -> bool:
        """True iff every lowered stage is row-local (``kernels.
        ROW_LOCAL_KINDS``), i.e. stacking K partitions along the row axis
        and running one launch is bitwise identical to K solo launches.
        The megabatched produce path (``PreStoEngine.preprocess_megabatch``)
        refuses plans where this does not hold."""
        from repro.kernels import ROW_LOCAL_KINDS  # late: kernels import ops

        return all(st.kind in ROW_LOCAL_KINDS for st in self.stages)


def _op_fn(node: OpNode, spec: TransformSpec, interpret) -> Callable[..., tuple]:
    """Standalone pass for one operator (host lowering)."""
    if isinstance(node, Decode):
        if node.encoding == "bytesplit":
            return lambda w: (K.decode_bytesplit(w, interpret=interpret),)
        if node.encoding == "bitpack":
            width = node.width
            return lambda w: (K.decode_bitpack(w, width=width, interpret=interpret),)
        if node.encoding == "lengths":
            width = node.width

            def decode_lengths(w):
                lens = R.bitunpack_grouped(w, width)  # (S, G, 32)
                return (lens.reshape(lens.shape[0], -1).T.astype(jnp.int32),)

            return decode_lengths
        if node.encoding == "labels":
            return lambda w: (jax.lax.bitcast_convert_type(w, jnp.float32),)
        raise ValueError(f"unknown decode encoding {node.encoding}")
    if isinstance(node, Bucketize):
        return lambda v: (K.bucketize(v, spec.bucket_boundaries, interpret=interpret),)
    if isinstance(node, SigridHash):
        seeds, maxv = (
            (spec.sparse_seeds, spec.sparse_max)
            if node.table == "sparse"
            else (spec.gen_seeds, spec.gen_max)
        )
        return lambda v: (K.sigridhash(v, seeds, maxv, interpret=interpret),)
    if isinstance(node, LogNorm):
        return lambda v: (K.lognorm(v, interpret=interpret),)
    if isinstance(node, FormBatch):
        cfg = spec.cfg

        def form_batch(dense_norm, sparse_hashed, lengths_i32, labels_f32,
                       gen_hashed):
            rows = labels_f32.shape[0]
            return ({
                "dense": dense_norm.T,
                "multi_hot_ids": sparse_hashed.reshape(
                    cfg.n_sparse, rows, cfg.max_sparse_len
                ).transpose(1, 0, 2),
                "lengths": lengths_i32,
                "one_hot_ids": gen_hashed.T,
                "labels": labels_f32,
            },)

        return form_batch
    raise TypeError(f"unknown node type {type(node).__name__}")


def _fused_fn(kinds: Tuple[str, ...], family: str, spec: TransformSpec,
              interpret) -> Callable[..., tuple]:
    """Bind one fused Pallas kernel to the spec params its chain needs."""
    kernel = FUSED_KERNELS[kinds]
    cfg = spec.cfg
    if family == "dense":
        return lambda w: (kernel(w, interpret=interpret),)
    if family == "sparse":
        return lambda w: (
            kernel(w, spec.sparse_seeds, spec.sparse_max, width=cfg.id_width,
                   interpret=interpret),
        )
    if family == "gen":
        return lambda w: (
            kernel(w, spec.bucket_boundaries, spec.gen_seeds, spec.gen_max,
                   interpret=interpret),
        )
    raise ValueError(f"no fused binding for family {family}")


def lower(
    graph: OpGraph,
    spec: TransformSpec,
    placements: Dict[str, str],
    *,
    interpret: bool | None = None,
) -> LoweredPlan:
    """Graph + per-family placement -> ordered stage list.

    ISP-placed chains whose kind tuple is registered in FUSED_KERNELS become
    one fused-kernel stage; everything else lowers to one stage per op.
    """
    stages: List[Stage] = []
    for family in graph.families:
        chain = graph.family_chain(family)
        place = placements.get(family, ISP)
        kinds = tuple(n.kind for n in chain)
        if place == ISP and kinds in FUSED_KERNELS:
            stages.append(
                Stage(
                    name=f"fused_{family}",
                    kind="fused:" + "+".join(kinds),
                    family=family,
                    placement=ISP,
                    inputs=chain[0].inputs,
                    outputs=(chain[-1].output,),
                    fn=_fused_fn(kinds, family, spec, interpret),
                    node_names=tuple(n.name for n in chain),
                )
            )
        else:
            for n in chain:
                stages.append(
                    Stage(
                        name=n.name,
                        kind=n.kind,
                        family=family,
                        placement=place,
                        inputs=n.inputs,
                        outputs=(n.output,),
                        fn=_op_fn(n, spec, interpret),
                        node_names=(n.name,),
                    )
                )
    form = graph.node("form_batch")
    stages.append(
        Stage(
            name=form.name,
            kind=form.kind,
            family=form.family,
            placement="local",
            inputs=form.inputs,
            outputs=(form.output,),
            fn=_op_fn(form, spec, None),
            node_names=(form.name,),
        )
    )
    return LoweredPlan(spec=spec, placements=dict(placements), stages=stages,
                       graph=graph)


def lower_transform(
    spec: TransformSpec, mode="fused", *, interpret: bool | None = None
) -> LoweredPlan:
    """Convenience: build + lower the standard Transform in one call."""
    return lower(
        build_transform_graph(spec), spec, resolve_placements(mode, spec),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Stage timing (latency breakdown + per-placement-group provisioning)


def time_stages(
    plan: LoweredPlan,
    pages: Dict[str, jax.Array],
    *,
    iters: int = 3,
    warmup: int = 1,
) -> Dict[str, float]:
    """Best-of-`iters` wall time per lowered stage, threading real values."""
    env = prepare_env(pages, plan.spec)
    times: Dict[str, float] = {}
    for st in plan.stages:
        fn = jax.jit(st.fn)
        args = [env[k] for k in st.inputs]
        out = None
        for _ in range(max(warmup, 1)):
            out = fn(*args)
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(max(iters, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        times[st.name] = best
        env.update(zip(st.outputs, out))
    return times


def group_times_by_placement(plan: LoweredPlan, times: Dict[str, float]) -> Dict[str, float]:
    """Aggregate per-stage seconds into placement groups (isp/host/local)."""
    groups: Dict[str, float] = {}
    for st in plan.stages:
        groups[st.placement] = groups.get(st.placement, 0.0) + times[st.name]
    return groups
