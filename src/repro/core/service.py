"""Preprocessing-as-a-service: a shared worker/ISP pool serving many jobs.

The paper's deployment end-game — and the disaggregated-DPP model of Meta's
production ingestion stack — is preprocessing as a *service*: one provisioned
fleet of ISP units shared across training jobs, with per-job admission and
unit allocation, instead of a private worker pool hand-wired into each
trainer.  This module is that public surface:

    service = PreprocessingService(num_workers=8)
    session = service.submit(JobSpec(
        name="rm1", spec=spec, store=store, partitions=range(64),
        placement="presto", target_samples_per_s=50_000))
    for pid, minibatch in session:          # backpressured stream
        state, metrics = train_step(state, minibatch)

* ``JobSpec`` — what a train manager hands the service at job launch: the
  RecSys Transform (a ``TransformSpec`` or a prebuilt ``PreStoEngine``), the
  partition range, placement mode, and QoS target (samples/s).
* ``Session`` — a backpressured streaming iterator of mini-batch futures in
  claim order (``futures()`` for the raw future stream; iterating resolves
  them to ``(pid, minibatch)``), with ``stats()``, ``cancel()``, and
  ``drain()``.
* ``PreprocessingService`` — owns the one worker pool.  Admission control
  and per-job unit shares come from ``core.planner.plan_pool`` (ceil(T/P)
  demand per job, re-planned whenever jobs join, leave, or re-estimate their
  per-worker throughput P); pool workers feed every session's
  ``data.loader.SessionQueue``.  Shares are work-conserving: idle capacity
  may serve any job beyond its share, but a job with work never gets less
  than its share.
* The service may own ONE shared ``core.featcache.FeatureCache``
  (``PreprocessingService(cache=FeatureCache(...))``): every cacheable
  session probes it at claim time (a hit short-circuits the claim — no
  produce, same bitwise batch) and populates it on produce, so concurrent
  tenants over overlapping partitions deduplicate work; a job's planner
  demand is discounted by its observed hit rate, freeing units for cold
  jobs.  Jobs opt out per-``JobSpec`` (``use_cache=False``); produce_fn
  overrides are never cached (opaque identity).
* With ``PreprocessingService(devices=DeviceFleet(...))`` the pool's units
  are bound to the simulated storage devices and scheduling becomes
  device-aware: claims prefer the ISP unit of the partition's OWNING device
  and fall back to host placement only when the owning device's live queue
  prices the ISP path past the host path (contention-aware cost model).
  Routing never changes batch bytes — only where/when they are produced —
  so every bitwise-identity guarantee above survives skewed placements.
* The pool is ELASTIC (``core.ctrlplane``): workers can be killed
  (crash-simulated — their in-flight claims are force-expired and re-issued
  through the existing straggler path, so the consumer stream stays bitwise
  identical to a no-failure run), gracefully retired, or added at runtime
  (``kill_worker`` / ``remove_worker`` / ``add_worker``); device bindings
  and pool shares re-plan on every membership change.  Sessions snapshot
  their progress frontier (``Session.checkpoint``, periodic via
  ``JobSpec.checkpoint_path``) so a restarted service resumes a
  half-drained job (``submit(job, resume_from=ckpt)``) bitwise-identically;
  an ``Autoscaler`` policy loop may grow/shrink the pool from
  ``load_snapshot()`` backlog.  Every membership change, claim re-issue,
  checkpoint, scale decision, and plan change is published to the service's
  bounded ``EventLog`` (``service.events``, surfaced in ``stats()``).
* The produce hot path is ZERO-STALL by default (``pipeline=True``):
  engine-backed sessions are *stageable* — a pool worker coalesces up to
  ``JobSpec.megabatch`` compatible claims into ONE megabatched kernel
  launch (one dispatch, one process-wide compile via ``core.execcache``),
  dispatches it asynchronously, and stages the NEXT chunk's partition
  reads + numpy page-builds while the kernel executes, blocking only at
  delivery.  Modeled I/O, host staging, and kernel execution overlap;
  ledgers are still charged per partition to the right owners, and every
  delivered batch stays bitwise identical to its solo serial run.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeoutError
from queue import Empty
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.core.autotune import DEFAULT_AUTOTUNE_KMAX, MegabatchTuner
from repro.core.costmodel import ContentionAwareCostModel, PartitionCosts
from repro.core.ctrlplane import EventLog, SessionCheckpoint, SessionError
from repro.core.featcache import BlockKey, CacheKey, FeatureCache
from repro.core.planner import (
    QOS_EXPLORATORY,
    AdmissionError,
    DeviceTopology,
    PoolPlan,
    SloRequest,
    effective_demand_units,
    plan_pool,
    plan_pool_slo,
    qos_demand_units,
)
from repro.core.preprocess import stack_pages
from repro.core.presto import PreStoEngine
from repro.core.spec import TransformSpec
from repro.data.loader import SessionQueue
from repro.data.storage import (
    DeviceFleet,
    DeviceOfflineError,
    IoFaultError,
    IspDevice,
    PartitionedStore,
)

__all__ = [
    "AdmissionError",
    "DeviceFleet",
    "EventLog",
    "FeatureCache",
    "JobSpec",
    "PreprocessingService",
    "Session",
    "SessionCheckpoint",
    "SessionError",
    "SessionStats",
]

MAX_DEMAND_UNITS = 64  # sanity cap on a single job's ceil(T/P) estimate
# default byte budget for pages staged AHEAD of their claims (per session);
# deep-lookahead pre-staging stops, never stalls, when the budget is full
DEFAULT_STAGE_BUDGET_BYTES = 256 << 20


@dataclasses.dataclass
class JobSpec:
    """One training job's preprocessing contract with the service."""

    name: str
    partitions: Iterable[int]
    spec: Optional[TransformSpec] = None
    store: Optional[PartitionedStore] = None
    placement: Union[str, Dict[str, str]] = "presto"
    target_samples_per_s: Optional[float] = None  # QoS; None = best effort
    # -- SLO contract ---------------------------------------------------------
    # qos_class: admission priority tier (core.planner.QOS_*).  Under SLO-
    # aware admission, release-candidate ("rc") jobs take surplus units
    # before — and may preempt the floors of — exploratory jobs.
    qos_class: str = QOS_EXPLORATORY
    # deadline_s: completion SLO relative to submission/arrival.  Advisory
    # on the wall-clock path (surfaced through stats); the virtual-time
    # simulator (core.simclock) scores per-class SLO attainment against it.
    deadline_s: Optional[float] = None
    units: Optional[int] = None  # explicit demand override (else T/P estimate)
    queue_depth: int = 4
    straggler_timeout: float = 30.0
    engine: Optional[PreStoEngine] = None  # prebuilt (shares its jit cache)
    produce_fn: Optional[Callable[[int], Any]] = None  # override / test hook
    use_cache: bool = True  # opt out of the service's shared feature cache
    # megabatching: a pool worker may coalesce up to this many compatible
    # claims of this session into ONE megabatched kernel launch (amortized
    # dispatch; bitwise identical to solo launches).  Engine-backed sessions
    # only — produce_fn overrides are opaque and never coalesce.
    megabatch: int = 1
    # -- self-tuning produce path ---------------------------------------------
    # autotune: hill-climb megabatch K online from measured launches
    # (core.autotune.MegabatchTuner, seeded from the cost model's predicted
    # optimum).  ``megabatch`` then acts as the K CAP; left at 1 the tuner
    # climbs up to DEFAULT_AUTOTUNE_KMAX.
    autotune: bool = False
    # lookahead: how many chunks of partition reads + page-builds may be
    # staged beyond the in-flight kernel.  1 is the classic double buffer
    # (stage exactly the next chunk); deeper windows pre-stage FUTURE claims
    # from the queue's non-claiming peek window, budget permitting.
    lookahead: int = 1
    # byte budget for pages staged AHEAD of their claims (None = the
    # service default, 0 disables pre-staging).  Accounted in deterministic
    # page-geometry bytes — the same bytes the owning device's ledger is
    # charged when the read actually happens.
    stage_budget_bytes: Optional[int] = None
    # prewarm: walk the peek window and issue FeatureCache.begin() leases
    # ahead of the claim cursor — spill-tier entries get promoted before the
    # worker arrives, and cold keys take the leader lease early so
    # concurrent tenants follow instead of duplicating the produce.
    prewarm: bool = True
    # -- control plane --------------------------------------------------------
    # checkpoint_path: where the session periodically snapshots its progress
    # frontier (core.ctrlplane.SessionCheckpoint JSON) — every
    # ``checkpoint_every`` deliveries and at completion.  A restarted
    # service resumes the job bitwise-identically via
    # ``service.submit(job, resume_from=SessionCheckpoint.load(path))``.
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 8
    # -- storage fault domain --------------------------------------------------
    # io_retries: how many times one partition's claim may be re-issued after
    # a RETRYABLE I/O fault (transient read error, torn/bit-flipped block,
    # device knocked offline) before the partition is quarantined and the
    # session surfaces a structured ``SessionError`` through its future.
    # io_backoff_s: base delay before the n-th retry (exponential:
    # ``io_backoff_s * 2**(n-1)``), served by the queue's clock — real time
    # by default, virtual when the session runs under ``core.simclock``.
    io_retries: int = 3
    io_backoff_s: float = 0.01

    def build_produce(self) -> Tuple[Callable[[int], Any], Optional[PreStoEngine]]:
        """Resolve the per-partition production callable for this job."""
        if self.produce_fn is not None:
            return self.produce_fn, self.engine
        engine = self.engine
        if engine is None:
            if self.spec is None:
                raise ValueError(
                    f"JobSpec {self.name!r} needs a spec, an engine, or a produce_fn"
                )
            engine = PreStoEngine(self.spec, placement=self.placement)
        if self.store is None:
            raise ValueError(f"JobSpec {self.name!r} needs a store")
        store = self.store
        return (lambda pid: engine.produce_batch(store, pid)), engine

    def cache_key_fn(
        self, engine: Optional[PreStoEngine]
    ) -> Optional[Callable[[int], CacheKey]]:
        """Content-address builder for this job's batches, or None when the
        job is not cacheable (produce_fn overrides are opaque; no store means
        no partition fingerprints)."""
        if (
            not self.use_cache
            or self.produce_fn is not None
            or engine is None
            or self.store is None
        ):
            return None
        store, plan_hash = self.store, engine.cache_signature()
        placement = engine.placement

        def key(pid: int) -> CacheKey:
            return CacheKey(store.partition_fingerprint(pid), plan_hash, placement)

        return key


@dataclasses.dataclass
class SessionStats:
    """Point-in-time accounting for one session (paper Fig. 3 metrics)."""

    job: str
    total: int
    produced: int = 0  # winner completions by pool workers
    delivered: int = 0  # batches handed to the consumer
    reissues: int = 0  # straggler backup claims
    duplicates_dropped: int = 0  # straggler losers discarded
    cache_hits: int = 0  # claims short-circuited by the shared feature cache
    cache_misses: int = 0  # cache probes that fell through to a produce
    # block-granularity dedup (RecD): claims whose batch was ASSEMBLED from
    # cached shared sparse blocks (subset of cache_hits), and unique blocks
    # this session published after cold produces
    block_hits: int = 0
    blocks_published: int = 0
    effective_demand_units: int = 1  # demand after the hit-rate discount
    rows_delivered: int = 0
    produce_time_s: float = 0.0  # pool-worker seconds spent on this job
    wait_time_s: float = 0.0  # consumer seconds blocked on the stream
    wall_time_s: float = 0.0
    demand_units: int = 1
    share: int = 0
    target_samples_per_s: Optional[float] = None
    worker_samples_per_s: float = 0.0  # measured per-worker P
    cancelled: bool = False
    done: bool = False
    host_fallbacks: int = 0  # fresh claims routed off their owning device
    # -- storage fault domain observability --
    retries: int = 0  # claims re-issued after a retryable I/O fault
    failovers: int = 0  # claims re-routed off an offline device's replica path
    quarantined: int = 0  # partitions that exhausted their retry budget
    # device -> winner produces that ran ON that device (ISP route); the
    # skew surface: a hot device's count dwarfs the cold ones' under Zipf
    device_produced: Dict[int, int] = dataclasses.field(default_factory=dict)
    # -- self-tuning produce path observability --
    tuned_k: int = 1  # megabatch K currently in effect (autotuned or static)
    staged_bytes_peak: int = 0  # peak bytes pre-staged ahead of claims
    prewarm_hits: int = 0  # peek-window pre-warm probes that found content cached
    # -- SLO contract observability --
    qos_class: str = QOS_EXPLORATORY
    slo_status: str = "admitted"  # admitted / degraded / preempted
    deadline_s: Optional[float] = None  # completion SLO relative to submit

    @property
    def achieved_samples_per_s(self) -> float:
        return self.rows_delivered / max(self.wall_time_s, 1e-9)

    @property
    def starvation(self) -> float:
        """Fraction of the session's wall time the consumer spent blocked."""
        return self.wait_time_s / max(self.wall_time_s, 1e-9)

    @property
    def cache_hit_rate(self) -> float:
        probes = self.cache_hits + self.cache_misses
        return self.cache_hits / probes if probes else 0.0


def _batch_rows(batch: Any) -> int:
    try:
        return int(batch["labels"].shape[0])
    except Exception:
        return 0


@dataclasses.dataclass
class _Chunk:
    """Up to K coalesced claims of one session, staged for one launch.

    The unit the zero-stall worker loop moves through its pipeline: claims
    are coalesced and their pages staged (reads charged per-partition to the
    OWNING devices), the launch is dispatched asynchronously, the next
    chunk's staging overlaps the in-flight kernel, and ``block_until_ready``
    happens only at delivery.
    """

    session: "Session"
    claims: List[Tuple[int, Future, Optional[str]]]
    pages: Optional[Any]  # staged stacked pages; None = opaque produce_fn
    stage_s: float = 0.0  # read + page-build seconds (production cost)
    devs: List[Optional[IspDevice]] = dataclasses.field(default_factory=list)
    t0: float = 0.0  # dispatch instant


class Session:
    """One job's handle on the service: a backpressured mini-batch stream.

    Single-consumer: iterate the session (or its ``futures()``) from one
    thread.  Iteration yields ``(pid, minibatch)`` in claim order, ends after
    every partition is delivered, and re-raises a worker's production error.
    """

    def __init__(
        self,
        service: "PreprocessingService",
        job: JobSpec,
        resume_from: Optional[SessionCheckpoint] = None,
    ):
        self._service = service
        self.job = job
        self.name = job.name
        # latest SLO admission decision for this session ("admitted" /
        # "degraded" / "rejected"-i.e.-preempted); only the SLO admission
        # policy ever moves it off the default
        self.slo_status = "admitted"
        self._produce_fn, self.engine = job.build_produce()
        # materialize the dedup'd partition order ONCE (job.partitions may
        # be a one-shot iterable): the queue, the device-backlog binding,
        # and checkpoints all read this same list
        self._partitions: List[int] = list(dict.fromkeys(job.partitions))
        # -- zero-stall produce path eligibility --------------------------------
        # Stageable sessions run the pipelined worker path: reads/page-builds
        # are separable from the kernel launch, so workers can megabatch K
        # claims into one launch and overlap the next chunk's staging with
        # the in-flight kernel.  produce_fn overrides are opaque (no
        # separable stage), meshed engines launch globally (not per-unit).
        self._stageable = (
            service.pipeline
            and job.produce_fn is None
            and job.store is not None
            and self.engine is not None
            and self.engine.mesh is None
        )
        # coalescing additionally needs every lowered stage row-local —
        # plans with a cross-row operator degrade gracefully to solo
        # launches (still staged/overlapped) instead of failing claims
        self._megabatch_k = (
            max(1, int(job.megabatch))
            if self._stageable and self.engine.lowered_plan.megabatch_safe()
            else 1
        )
        # -- online megabatch-K autotuning ---------------------------------
        # One tuner per autotuned session, seeded from the cost model's
        # predicted amortization knee; every finished launch feeds its
        # overlap-corrected seconds back (``_finish_chunk``) and a K move
        # re-bases the planner's P estimate (``_on_tuned_k_changed``).
        self._tuner: Optional[MegabatchTuner] = None
        self._rows_hint = 0
        if self._stageable:
            self._rows_hint = int(
                getattr(job.store.source, "rows", None)
                or self.engine.spec.cfg.rows_per_partition
            )
        if (
            job.autotune
            and self._stageable
            and self.engine.lowered_plan.megabatch_safe()
        ):
            k_cap = (
                int(job.megabatch) if job.megabatch > 1 else DEFAULT_AUTOTUNE_KMAX
            )
            try:
                per_part = self.engine.route_costs(
                    rows=self._rows_hint or None, model=service.cost_model
                ).isp_s
            except Exception:
                per_part = None  # unseedable: the tuner starts at K=1
            self._tuner = MegabatchTuner(
                k_cap, per_partition_s=per_part, cost_model=service.cost_model
            )
            if resume_from is not None and resume_from.tuner:
                # resume: re-seed at the checkpointed rung (measured EMAs
                # and convergence carry over) instead of re-climbing
                self._tuner.restore(resume_from.tuner)
        # -- deep lookahead + cache pre-warm state -------------------------
        self._lookahead = max(1, int(job.lookahead))
        self._stage_budget = (
            DEFAULT_STAGE_BUDGET_BYTES
            if job.stage_budget_bytes is None
            else max(0, int(job.stage_budget_bytes))
        )
        # pages staged AHEAD of their claims: pid -> (pages, charged_bytes,
        # stage seconds).  Charged in deterministic page-geometry bytes
        # (``_page_nbytes``) so the budget check can run BEFORE the read.
        self._prestaged: Dict[int, Tuple[Any, int, float]] = {}
        self._staging_now: set = set()
        self._staged_bytes = 0
        self._staged_bytes_peak = 0
        self._page_nbytes = 0
        if self._stageable and self._rows_hint:
            try:
                structs = self.engine.pages_struct(self._rows_hint)
                self._page_nbytes = int(
                    sum(
                        math.prod(s.shape) * np.dtype(s.dtype).itemsize
                        for s in structs.values()
                    )
                )
            except Exception:
                self._page_nbytes = 0  # unsized pages: pre-staging disabled
        # cache pre-warm: pids probed ahead of the cursor (once each), the
        # leader leases we hold for them, and how many were already cached
        self._prewarmed: set = set()
        self._prewarm_cached: set = set()
        self._prewarm_leases: Dict[int, CacheKey] = {}
        self._prewarm_hits = 0
        self._cache = service.cache if job.use_cache else None
        self._cache_key = (
            job.cache_key_fn(self.engine) if self._cache is not None else None
        )
        # block-granularity dedup (RecD): cacheable, mesh-less, store-bound
        # jobs publish each cold produce's unique hashed sparse blocks and
        # assemble full-coverage misses from other tenants' blocks
        self._block_key_parts: Optional[Tuple[str, str]] = None
        if (
            self._cache_key is not None
            and self.engine is not None
            and self.engine.mesh is None
            and job.store is not None
        ):
            self._block_key_parts = (
                self.engine.cache_signature(),
                self.engine.placement,
            )
        self._block_hits = 0
        self._blocks_published = 0
        # -- device routing (fleet-backed services with a store-bound job) --
        self._fleet = service.fleet
        self._owner_of: Optional[Callable[[int], int]] = None
        self._costs: Optional[PartitionCosts] = None
        if self._fleet is not None and job.store is not None:
            store, ndev = job.store, len(self._fleet)
            self._owner_of = lambda pid: store.owner_of(pid) % ndev
            if self.engine is not None:
                # price the partitions the store ACTUALLY serves: a sourced
                # store's row count overrides the spec's default geometry
                rows = getattr(store.source, "rows", None)
                self._costs = self.engine.route_costs(
                    rows=rows, model=service.cost_model
                )
        self._queue = SessionQueue(
            self._partitions,
            depth=job.queue_depth,
            straggler_timeout=job.straggler_timeout,
            lookup=self._cache_probe if self._cache_key is not None else None,
            owner_of=self._owner_of,
            fallback_ok=self._host_ok if self._owner_of is not None else None,
            on_settled=self._release_backlog if self._owner_of is not None else None,
            on_offload=self._on_offload if self._owner_of is not None else None,
            on_reissue=self._on_reissue,
        )
        self.total = self._queue.total
        # guarded by service._lock:
        self.share = 0
        self._active_workers = 0
        self._active_by_dev: Dict[int, int] = {}  # worker device -> active
        self._demand = max(1, job.units or 1)
        # guarded by self._slock:
        self._slock = threading.Lock()
        self._produced = 0
        self._handed = 0  # futures taken off the delivery queue (any stream)
        self._delivered = 0
        self._delivered_pids: List[int] = []  # the checkpoint frontier
        self._duplicates = 0
        self._rows_delivered = 0
        self._produce_time = 0.0
        self._wait_time = 0.0
        self._cache_hits = 0
        self._cache_misses = 0
        self._cache_keys: Dict[int, CacheKey] = {}  # pid -> key, probe->produce
        # storage fault domain: per-partition retry attempts plus the
        # session-level counters stats() surfaces
        self._fault_attempts: Dict[int, int] = {}
        self._retries = 0
        self._failovers = 0
        self._quarantined = 0
        self._eff_demand = self._demand  # last hit-rate-discounted demand
        self._p_est: Optional[float] = None
        self._device_produced: Dict[int, int] = {}  # ISP-route winner counts
        # device backlog: every partition is bound to its owning device until
        # it completes or is offloaded to the host — the live queue_depth the
        # contention-aware router reads.  _backlogged makes release idempotent
        # (a pid can be both offloaded and later completed).
        self._backlogged: set = set()
        self.device_weights: Optional[Dict[int, float]] = None
        if self._owner_of is not None:
            pids = self._queue.work.pending_snapshot()  # pre-start snapshot
            counts: Dict[int, int] = {}
            for pid in pids:
                counts[self._owner_of(pid)] = counts.get(self._owner_of(pid), 0) + 1
            if pids:
                self.device_weights = {
                    d: c / len(pids) for d, c in counts.items()
                }
            self._backlogged = set(pids)
            for d, c in counts.items():
                self._fleet[d].enqueue(c)
        # a fault-injected store publishes io_fault/device_offline events
        # through the service's stream (duck-typed: data/ never imports core/)
        inj = getattr(job.store, "fault_injector", None) if job.store else None
        if inj is not None and getattr(inj, "events", None) is None:
            inj.events = service.events
        self._t0 = time.perf_counter()
        self._t_end: Optional[float] = None

    # -- consumer side ---------------------------------------------------------

    @property
    def cancelled(self) -> bool:
        return self._queue.cancelled.is_set()

    @property
    def done(self) -> bool:
        """Every partition delivered to the consumer."""
        with self._slock:
            return self._delivered >= self.total

    def _next_future(self) -> Optional[Future]:
        """Take the next undelivered future off the stream (None = stream end).

        The hand-off count is session state, not per-iterator, so a partially
        consumed session can be re-iterated (or ``drain()``-ed) and resumes
        where the previous loop stopped.
        """
        while not self.cancelled:
            with self._slock:
                if self._handed >= self.total:
                    return None
            try:
                fut = self._queue.out.get(timeout=0.25)
            except Empty:
                self._check_liveness()
                continue
            with self._slock:
                self._handed += 1
            return fut
        return None

    def futures(self) -> Iterator[Future]:
        """The raw stream: mini-batch futures in claim order.

        Taking a future transfers ownership: it counts as delivered for
        backpressure, so pacing beyond ``queue_depth`` outstanding claims is
        the raw consumer's responsibility.  Delivery stats (and ``done``)
        are recorded when each future resolves.  Shares the delivery queue
        with plain iteration — use one stream or the other.
        """
        while True:
            fut = self._next_future()
            if fut is None:
                return
            self._queue.mark_delivered()
            self._service._wake()
            fut.add_done_callback(self._account_delivery)
            yield fut

    def _account_delivery(self, fut: Future) -> None:
        """Delivery accounting for the raw-future stream (on resolution)."""
        if fut.cancelled() or fut.exception() is not None:
            return
        _pid, batch = fut.result()
        with self._slock:
            self._delivered += 1
            self._delivered_pids.append(_pid)
            self._rows_delivered += _batch_rows(batch)
            if self._delivered >= self.total:
                self._t_end = time.perf_counter()
        self._maybe_checkpoint()

    def __iter__(self) -> Iterator[Tuple[int, Any]]:
        while True:
            t0 = time.perf_counter()
            fut = self._next_future()
            if fut is None:
                return
            while True:
                if self.cancelled:
                    return
                try:
                    pid, batch = fut.result(timeout=0.25)
                    break
                except FutureTimeoutError:
                    self._check_liveness()
            # pacing signal only once the batch is resolved and in the
            # consumer's hands: at most queue_depth batches sit materialized
            self._queue.mark_delivered()
            self._service._wake()
            with self._slock:
                self._wait_time += time.perf_counter() - t0
                self._delivered += 1
                self._delivered_pids.append(pid)
                self._rows_delivered += _batch_rows(batch)
                if self._delivered >= self.total:
                    self._t_end = time.perf_counter()
            self._maybe_checkpoint()
            yield pid, batch

    def drain(self) -> int:
        """Consume and discard the rest of the stream; returns batches eaten.

        After ``cancel()`` this returns immediately; otherwise it blocks
        until the job's remaining partitions are produced (an end-of-job
        barrier that keeps pool accounting exact)."""
        n = 0
        for _ in self:
            n += 1
        return n

    def cancel(self) -> None:
        """Stop the stream: pool workers stop claiming for this session,
        undelivered results are discarded, and the pool is rebalanced."""
        if self.cancelled:
            return
        self._queue.cancel()
        with self._slock:
            if self._t_end is None:
                self._t_end = time.perf_counter()
        self._service._retire(self)

    def stats(self) -> SessionStats:
        with self._slock:
            wall = (self._t_end or time.perf_counter()) - self._t0
            return SessionStats(
                job=self.name,
                total=self.total,
                produced=self._produced,
                delivered=self._delivered,
                reissues=self._queue.work.reissues,
                duplicates_dropped=self._duplicates,
                rows_delivered=self._rows_delivered,
                produce_time_s=self._produce_time,
                wait_time_s=self._wait_time,
                wall_time_s=wall,
                demand_units=self._demand,
                cache_hits=self._cache_hits,
                cache_misses=self._cache_misses,
                block_hits=self._block_hits,
                blocks_published=self._blocks_published,
                effective_demand_units=effective_demand_units(
                    self._demand, self._hit_rate_locked()
                ),
                share=self.share,
                target_samples_per_s=self.job.target_samples_per_s,
                worker_samples_per_s=self._p_est or 0.0,
                cancelled=self.cancelled,
                done=self._delivered >= self.total,
                host_fallbacks=self._queue.host_fallbacks,
                retries=self._retries,
                failovers=self._failovers,
                quarantined=self._quarantined,
                device_produced=dict(self._device_produced),
                tuned_k=(
                    self._tuner.k if self._tuner is not None else self._megabatch_k
                ),
                staged_bytes_peak=self._staged_bytes_peak,
                prewarm_hits=self._prewarm_hits,
                qos_class=self.job.qos_class,
                slo_status=self.slo_status,
                deadline_s=self.job.deadline_s,
            )

    def _check_liveness(self) -> None:
        if self._service.closed:
            with self._slock:
                undelivered = self.total - self._delivered
            raise RuntimeError(
                f"preprocessing service closed with {undelivered} batches "
                f"undelivered for job {self.name!r}"
            )

    # -- control plane: checkpoint/resume + crash cleanup ----------------------

    def checkpoint(self) -> SessionCheckpoint:
        """Snapshot this session's progress frontier for restart/resume.

        The frontier is the DELIVERED pid set: produced-but-undelivered
        batches die with the service (their futures are service state), so
        resume must re-produce them — which is free of risk because
        partitions are deterministic.  Safe to call at any time, from any
        thread."""
        with self._slock:
            delivered = list(self._delivered_pids)
            stats = {
                "produced": self._produced,
                "delivered": self._delivered,
                "reissues": self._queue.work.reissues,
                "duplicates_dropped": self._duplicates,
                "cache_hits": self._cache_hits,
                "cache_misses": self._cache_misses,
                "rows_delivered": self._rows_delivered,
            }
        return SessionCheckpoint(
            job=self.name,
            partitions=list(self._partitions),
            delivered=delivered,
            stats=stats,
            tuner=self._tuner.summary() if self._tuner is not None else None,
        )

    def _maybe_checkpoint(self) -> None:
        """Periodic frontier snapshot (``JobSpec.checkpoint_path``): every
        ``checkpoint_every`` deliveries and at completion.  An unwritable
        path degrades to no checkpoint — it never breaks delivery."""
        path = self.job.checkpoint_path
        if not path:
            return
        with self._slock:
            n = self._delivered
        if n % max(1, int(self.job.checkpoint_every)) and n < self.total:
            return
        try:
            self.checkpoint().save(path)
        except Exception:
            return
        self._service.events.emit(
            "checkpoint", job=self.name, delivered=n, total=self.total, path=path
        )

    def _on_reissue(self, pid: int) -> None:
        """WorkQueue straggler-re-issue observer -> the event stream."""
        self._service.events.emit("claim_reissue", job=self.name, pid=pid)

    def _expire_claims(self, pids: Iterable[int]) -> None:
        """Force-expire claims a dead worker held so the next claim round
        re-issues them immediately through the straggler path."""
        for pid in pids:
            self._queue.expire(pid)
        self._service._wake()

    def _abandon_chunk(self, chunk: "_Chunk") -> None:
        """Crash cleanup for a chunk a killed worker held (staged or even
        dispatched — never finished): any results in hand die with the
        worker.  Leader cache leases are abandoned so cross-tenant followers
        re-issue real produces instead of waiting forever, ISP device
        occupancy is released, and every claim is expired back through the
        straggler path.  The claims' futures stay pending — the re-issued
        produce resolves them, so the consumer stream (and every delivered
        byte) is untouched by the crash."""
        for pid, _f, _r in chunk.claims:
            if self._cache_key is not None:
                with self._slock:
                    key = self._cache_keys.pop(pid, None)
                if key is not None:
                    try:
                        self._cache.abandon(key)
                    except Exception:
                        pass
        for dev in chunk.devs:
            self._route_end(dev)
        chunk.devs = []
        self._expire_claims(pid for pid, _f, _r in chunk.claims)

    # -- device routing --------------------------------------------------------

    def _host_ok(self, pid: int) -> bool:
        """Fallback eligibility for a foreign claim of `pid`: its owning
        device has no bound unit at all, or the contention-aware cost model
        says the live queue has priced the ISP path past the host path.
        The candidate itself is still in the device's backlog, so the wait
        it would experience is behind the OTHER queued claims."""
        owner = self._owner_of(pid)
        if getattr(self._fleet[owner], "offline", False):
            return True  # an offline device computes nothing: host is the
            # only route (reads go through the replica/failover path)
        if owner not in self._service._manned:
            return True
        return self._service.cost_model.should_offload(
            self._costs, self._fleet[owner].queue_depth - 1
        )

    def _release_backlog(self, pid: int) -> None:
        """`pid` stopped waiting on its owning device (completed, errored,
        served by the cache, or offloaded to the host).  Idempotent."""
        with self._slock:
            present = pid in self._backlogged
            self._backlogged.discard(pid)
        if present:
            self._fleet[self._owner_of(pid)].dequeue()

    def _on_offload(self, pid: int) -> None:
        """A fresh claim of `pid` was routed to the host: the owning device
        stops waiting on it and records the shed."""
        self._fleet[self._owner_of(pid)].shed()
        self._release_backlog(pid)

    def _release_all_backlog(self) -> None:
        with self._slock:
            pids = list(self._backlogged)
            self._backlogged.clear()
        for pid in pids:
            self._fleet[self._owner_of(pid)].dequeue()

    def _route_begin(self, pid: int, route: Optional[str]) -> Optional[IspDevice]:
        """An ISP-routed produce occupies the owning device for its duration
        (the in-flight ceiling ``tests/test_devices.py`` pins)."""
        if route == "isp" and self._owner_of is not None:
            dev = self._fleet[self._owner_of(pid)]
            dev.begin_claim()
            return dev
        return None

    @staticmethod
    def _route_end(dev: Optional[IspDevice]) -> None:
        if dev is not None:
            dev.end_claim()

    # -- pool-worker side: the zero-stall chunk pipeline -----------------------

    def _current_k(self) -> int:
        """Megabatch width for the next launch: the tuner's live proposal
        when autotuning, else the static ``JobSpec.megabatch``."""
        if self._tuner is not None:
            return self._tuner.k
        return self._megabatch_k

    def _stage_chunk(
        self, claim: Tuple[int, Future, Optional[str]], prefer: Optional[int]
    ) -> Optional["_Chunk"]:
        """Coalesce up to K compatible claims and stage their pages.

        Coalesced claims ride the one worker slot the scheduler already
        reserved (a megabatch is ONE launch occupying one unit); per-device
        plan slices bound the first claim, the ride-alongs are bounded by
        the session's own queue depth.  Every partition read is charged to
        its owning device inside ``store.read``.  Partitions the lookahead
        walker already pre-staged are consumed from the staging buffer
        (their read time was paid — and recorded — during a previous
        chunk's kernel); the rest are read here.  Returns None when staging
        fails — the claims' futures carry the error (deterministic in pid,
        so straggler twins would fail identically).
        """
        claims = [claim]
        for _ in range(self._current_k() - 1):
            extra = self._queue.claim(prefer_device=prefer)
            if extra is None:
                break
            claims.append(extra)
        if not self._stageable:
            return _Chunk(self, claims, None)
        t0 = time.perf_counter()
        pre_s = 0.0  # stage seconds already paid by the lookahead walker
        per: List[Any] = []
        kept: List[Tuple[int, Future, Optional[str]]] = []
        try:
            for pid, f, r in claims:
                entry = self._take_prestaged(pid)
                if entry is not None:
                    pages_i, _nb, s = entry
                    pre_s += s
                else:
                    try:
                        pages_i = self.engine.stage_partition(
                            self.job.store, pid
                        )
                    except IoFaultError as exc:
                        # a faulted read condemns ONLY its own claim (the
                        # retry/quarantine policy decides its fate) — its
                        # chunk mates stage on with their own budgets intact
                        self._on_produce_error(pid, exc)
                        continue
                per.append(pages_i)
                kept.append((pid, f, r))
            if not kept:
                return None
            pages = stack_pages(per)
        except BaseException as exc:  # noqa: BLE001 — consumer re-raises
            for pid, _f, _r in kept or claims:
                self._on_produce_error(pid, exc)
            return None
        return _Chunk(
            self, kept, pages, stage_s=time.perf_counter() - t0 + pre_s
        )

    # -- deep lookahead: pre-stage + pre-warm the peek window ------------------

    def _take_prestaged(self, pid: int) -> Optional[Tuple[Any, int, float]]:
        """Consume a pre-staged partition's pages (uncharging its bytes)."""
        with self._slock:
            entry = self._prestaged.pop(pid, None)
            if entry is not None:
                self._staged_bytes -= entry[1]
        return entry

    def _prefetch_ahead(self, prefer: Optional[int]) -> None:
        """Walk the non-claiming peek window behind the in-flight kernel.

        The claim queue is an oracle of future work (BagPipe's observation):
        ``peek_ahead`` exposes the next ``(lookahead - 1) * K`` partitions
        beyond the chunk already staged, without claiming them.  For each
        window pid this (1) pre-warms the shared feature cache — spill
        entries promote, cold keys take the leader lease early — and
        (2) pre-stages the partition read + page-build under the byte
        budget, so the claim that eventually lands only pays a stack.
        Depth 1 keeps the classic double buffer untouched (empty window).
        """
        depth = (self._lookahead - 1) * max(self._current_k(), 1)
        if depth <= 0 or not self._stageable:
            return
        window = self._queue.peek_ahead(depth, prefer_device=prefer)
        if not window:
            return
        for pid in window:
            if self.cancelled or self._service.closed:
                return
            self._prewarm(pid)
        # sweep orphans first: a pid pre-staged earlier but claimed (and
        # possibly already produced fresh) before consumption would pin its
        # budget bytes forever
        with self._slock:
            stale = [
                p for p in self._prestaged if not self._queue.work.is_pending(p)
            ]
            for p in stale:
                _pages, nb, _s = self._prestaged.pop(p)
                self._staged_bytes -= nb
        for pid in window:
            if self.cancelled or self._service.closed:
                return
            self._prestage(pid)

    def _prewarm(self, pid: int) -> None:
        """Predictive cache probe for a future claim of `pid` (once per pid).

        Holds ``_slock`` across the lease check AND ``cache.begin`` — the
        same atomicity ``_cache_probe`` relies on so a claim can never race
        into FOLLOWING this session's own pre-warm lease (which would stall
        it behind a produce that only happens after the claim)."""
        if self._cache_key is None or not self.job.prewarm:
            return
        with self._slock:
            if pid in self._prewarmed:
                return
        try:
            key = self._cache_key(pid)  # fingerprints memoize; cheap re-walk
        except Exception:
            return  # an unprobeable pid pre-warms nothing; the claim decides
        with self._slock:
            if pid in self._prewarmed:
                return
            self._prewarmed.add(pid)
            try:
                status, _found = self._cache.begin(key, prewarm=True)
            except Exception:
                return  # a broken cache degrades pre-warm to a no-op
            if status == "produce":
                self._prewarm_leases[pid] = key
            elif status == "hit":
                self._prewarm_hits += 1
                self._prewarm_cached.add(pid)
            else:  # follow: another tenant is producing it right now
                self._prewarm_cached.add(pid)

    def _prestage(self, pid: int) -> None:
        """Read + page-build a FUTURE claim's partition under the budget.

        The budget is reserved in deterministic page-geometry bytes BEFORE
        the read, so ``staged_bytes_peak <= stage_budget_bytes`` holds as an
        invariant (never exceeded mid-read, and a budget smaller than one
        partition pre-stages nothing).  Reads charge the owning device's
        ledger inside ``store.read`` exactly as claim-time reads do."""
        if self._page_nbytes <= 0:
            return
        with self._slock:
            if (
                pid in self._prestaged
                or pid in self._staging_now
                or pid in self._prewarm_cached  # its claim will short-circuit
            ):
                return
            if self._staged_bytes + self._page_nbytes > self._stage_budget:
                return  # budget full: the rest of the window reads on claim
            self._staging_now.add(pid)
            self._staged_bytes += self._page_nbytes
            self._staged_bytes_peak = max(
                self._staged_bytes_peak, self._staged_bytes
            )
        t0 = time.perf_counter()
        try:
            pages = self.engine.stage_partition(self.job.store, pid)
        except BaseException:  # noqa: BLE001
            with self._slock:
                self._staging_now.discard(pid)
                self._staged_bytes -= self._page_nbytes
            return  # the claim-time read will surface the error to the future
        dt = time.perf_counter() - t0
        with self._slock:
            self._staging_now.discard(pid)
            self._prestaged[pid] = (pages, self._page_nbytes, dt)

    def _clear_prefetch(self) -> None:
        """Retire/cancel cleanup: drop staged-ahead pages and abandon any
        pre-warm leases never consumed by a claim (so cross-tenant followers
        of those keys re-issue real produces instead of waiting forever)."""
        with self._slock:
            self._prestaged.clear()
            self._staged_bytes = 0
            leases = list(self._prewarm_leases.values())
            self._prewarm_leases.clear()
        for key in leases:
            try:
                self._cache.abandon(key)
            except Exception:
                pass

    def _dispatch_chunk(self, chunk: "_Chunk") -> Tuple[str, Any]:
        """Launch a staged chunk.  Engine chunks dispatch ASYNChronously —
        the compiled program executes while the worker stages the next chunk
        — so the return is a handle ``_finish_chunk`` resolves at delivery.
        Opaque produce_fn chunks run synchronously here (no separable
        stage), preserving the legacy path's semantics exactly."""
        chunk.devs = [
            self._route_begin(pid, route) for pid, _f, route in chunk.claims
        ]
        chunk.t0 = time.perf_counter()
        try:
            if chunk.pages is None:
                ((pid, _f, _r),) = chunk.claims
                return "value", [self._produce_fn(pid)]
            engine = self.engine
            if len(chunk.claims) == 1:
                # reuse the solo executable (one compile shared with every
                # produce_batch of this signature, process-wide)
                pages = {k: v[0] for k, v in chunk.pages.items()}
                return "async", engine.jit_preprocess_cached()(
                    engine._put_pages(pages)
                )
            return "async", engine.jit_preprocess_megabatch_cached()(
                engine._put_pages(chunk.pages)
            )
        except BaseException as exc:  # noqa: BLE001 — consumer re-raises
            return "error", exc

    def _finish_chunk(
        self, chunk: "_Chunk", handle: Tuple[str, Any], overlap_s: float = 0.0
    ) -> None:
        """Resolve a dispatched chunk: block (only) at delivery, complete
        every claim's future, and charge the ledgers per claim route.

        ``overlap_s`` is time the worker spent staging the NEXT chunk while
        this one's kernel ran; it is excluded from this chunk's produce time
        (it is charged to the next chunk's own ``stage_s``) so per-session
        ``produce_time_s`` and the planner's measured per-worker P never
        double-count the overlapped staging."""
        kind, payload = handle
        try:
            if kind == "error":
                for pid, _f, _r in chunk.claims:
                    self._on_produce_error(pid, payload)
                return
            if kind == "async":
                try:
                    jax.block_until_ready(payload)
                except BaseException as exc:  # noqa: BLE001
                    for pid, _f, _r in chunk.claims:
                        self._on_produce_error(pid, exc)
                    return
                batches = (
                    [payload] if len(chunk.claims) == 1 else list(payload)
                )
            else:
                batches = payload
            dt = chunk.stage_s + max(
                0.0, time.perf_counter() - chunk.t0 - overlap_s
            )
            share = dt / max(len(chunk.claims), 1)
            if self._tuner is not None and chunk.pages is not None:
                # the overlap-corrected launch seconds ARE the tuner's
                # signal: staging paid by this chunk plus kernel time not
                # hidden behind the next chunk's staging
                if self._tuner.record(len(chunk.claims), dt):
                    self._on_tuned_k_changed()
            for (pid, _f, route), batch in zip(chunk.claims, batches):
                self._on_produced(pid, batch, share, route)
        finally:
            for dev in chunk.devs:
                self._route_end(dev)

    def _cache_probe(self, pid: int, fresh: bool) -> Optional[Any]:
        """SessionQueue's claim-time lookup into the shared feature cache.

        A hit means another tenant (or an earlier run of this one) already
        produced this exact batch — same partition bytes, same lowered
        Transform, same placement — so the claim short-circuits without a
        produce; a follow means that batch is being produced right now, so
        the claim pends on the producer's future instead of duplicating the
        work.  Straggler re-issues (``fresh=False``) only accept finished
        hits: following the in-flight leader they are backing up would
        defeat the re-issue.  Hit/miss counts feed the planner's demand
        discount: when this session's discounted demand changes, the pool
        re-plans so the units its hits freed go to cold jobs."""
        key = self._cache_key(pid)
        if not fresh:
            # straggler backup: peek only (never follow the possibly-stuck
            # leader), and keep it out of the hit-rate tallies — the fresh
            # claim of this pid was already counted once
            return self._cache.peek(key)
        found: Optional[Any] = None
        with self._slock:
            # the lease check and the begin() probe are atomic under _slock
            # (mirrored by ``_prewarm``): the claim must CONSUME its own
            # session's pre-warm lease — following it would park the claim
            # behind a produce that only happens after the claim itself
            lease = self._prewarm_leases.pop(pid, None)
            if lease is not None:
                status = "produce"
                key = lease  # the lease's key IS this pid's key
            else:
                status, found = self._cache.begin(key)
            if status == "produce":
                self._cache_misses += 1
                # remembered for the produce's fulfill/abandon: the produce
                # path must never recompute (and possibly re-raise) the key
                self._cache_keys[pid] = key
            else:
                self._cache_hits += 1
            eff = effective_demand_units(self._demand, self._hit_rate_locked())
            changed = eff != self._eff_demand
            self._eff_demand = eff
        if changed:
            self._service._request_replan()
        if found is None and status == "produce":
            assembled = self._assemble_from_blocks(pid)
            if assembled is not None:
                # the claim is served without a produce after all: flip the
                # miss to a hit, release the leader lease by fulfilling it
                # (followers resolve, the full-batch key is now cached too)
                with self._slock:
                    self._cache_keys.pop(pid, None)
                    self._cache_misses -= 1
                    self._cache_hits += 1
                    self._block_hits += 1
                try:
                    self._cache.fulfill(key, assembled)
                except Exception:
                    self._cache.abandon(key)
                return assembled
        return found

    def _assemble_from_blocks(self, pid: int) -> Optional[Any]:
        """Serve one cold claim from the block tier, if fully covered.

        A dedup partition whose unique blocks are ALL cached (published by
        any tenant — same pool, different pids included) needs no sparse
        produce: the per-sample families run through the engine's compiled
        partial program over a fresh (unique-bytes-charged) page read, and
        the hashed sparse blocks gather-expand from the cache — bitwise
        identical to a cold produce.  Returns None on any miss or error
        (the claim then produces normally)."""
        if self._block_key_parts is None:
            return None
        store, engine = self.job.store, self.engine
        try:
            fps = store.block_fingerprints(pid)
            if not fps:
                return None
            plan_hash, placement = self._block_key_parts
            blocks = self._cache.get_blocks(
                BlockKey(fp, plan_hash, placement) for fp in fps
            )
            if blocks is None:
                return None
            pages = engine.stage_partition(store, pid)
            if "sparse_refs" not in pages:
                return None
            batch = engine.assemble_from_blocks(pages, *blocks)
            jax.block_until_ready(batch)
            return batch
        except Exception:
            return None

    def _publish_blocks(self, pid: int, batch: Any) -> None:
        """Publish a cold produce's unique hashed sparse blocks (winner path).

        Classic (dup-factor-1) data short-circuits on the store's None
        fingerprints.  Publishing must never take the worker thread down."""
        if self._block_key_parts is None:
            return
        try:
            store = self.job.store
            fps = store.block_fingerprints(pid)
            if not fps:
                return
            refs = store.block_refs(pid)
            if refs is None:
                return
            ids, lens = self.engine.extract_blocks(batch, refs)
            plan_hash, placement = self._block_key_parts
            for fp, bi, bl in zip(fps, ids, lens):
                self._cache.put_block(BlockKey(fp, plan_hash, placement), bi, bl)
        except Exception:
            return
        with self._slock:
            self._blocks_published += len(fps)

    def _hit_rate_locked(self) -> float:
        probes = self._cache_hits + self._cache_misses
        return self._cache_hits / probes if probes else 0.0

    def _hit_rate(self) -> float:
        with self._slock:
            return self._hit_rate_locked()

    def _on_produced(
        self, pid: int, batch: Any, dt: float, route: Optional[str] = None
    ) -> None:
        # the produce consumed real modeled resources wherever it ran —
        # winner or straggler duplicate alike (the work happened); the batch
        # BYTES are identical either way, only the ledgers differ
        if route is not None and self._costs is not None:
            if route == "isp":
                self._fleet[self._owner_of(pid)].charge_compute(self._costs.ops)
            else:
                self._fleet.charge_host(self._costs.link_bytes, self._costs.ops)
        winner = self._queue.complete(pid, batch)
        if winner and self._cache_key is not None:
            # winner-only pop: a straggler loser racing here must not steal
            # the key and suppress the winner's fulfill (which would leave
            # the in-flight future dangling for every follower)
            with self._slock:
                key = self._cache_keys.pop(pid, None)
            if key is not None:
                # the first completion populates the cache and resolves any
                # followers pending on this content's in-flight future; a
                # broken cache must never take the worker thread down
                try:
                    self._cache.fulfill(key, batch)
                except Exception:
                    self._cache.abandon(key)
                self._publish_blocks(pid, batch)
        rows = _batch_rows(batch)
        demand_changed = False
        with self._slock:
            self._produce_time += dt
            if not winner:
                self._duplicates += 1
            else:
                self._produced += 1
                if route == "isp" and self._owner_of is not None:
                    owner = self._owner_of(pid)
                    self._device_produced[owner] = (
                        self._device_produced.get(owner, 0) + 1
                    )
                if rows and dt > 0:
                    p = rows / dt
                    self._p_est = p if self._p_est is None else 0.5 * self._p_est + 0.5 * p
        if winner:
            demand_changed = self._maybe_reestimate_demand()
        if demand_changed:
            self._service._rebalance()

    def _maybe_reestimate_demand(self) -> bool:
        """QoS re-estimate: demand = ceil(target / measured per-worker P),
        capped.  Returns True when the demand actually moved (the caller
        then re-plans the pool)."""
        if not (self.job.target_samples_per_s and self._p_est):
            return False
        new_demand = qos_demand_units(
            self.job.target_samples_per_s, self._p_est, cap=MAX_DEMAND_UNITS
        )
        new_eff = effective_demand_units(new_demand, self._hit_rate())
        changed = False
        with self._service._lock:
            if new_demand != self._demand:
                self._demand = new_demand
                changed = True
        if changed:
            with self._slock:
                self._eff_demand = new_eff
        return changed

    def _on_tuned_k_changed(self) -> None:
        """The tuner moved K: fold the new rung's measured per-partition
        cost into the planner's per-worker P estimate and re-plan.

        A K move changes how many rows one worker slot produces per second
        (fewer dispatches amortized, different staging bulk), so waiting for
        the EMA in ``_on_produced`` to drift there lags the pool plan behind
        reality.  When the new rung already has a measurement, P is re-based
        on it directly; either way the pool re-plans through the same lazy
        trigger the feature-cache hit-rate discount uses, so
        ``planner.plan_pool`` re-balances unit shares as K converges."""
        tuner = self._tuner
        if tuner is None:
            return
        cost = tuner.arm_cost(tuner.k)
        if cost is not None and cost > 0 and self._rows_hint:
            with self._slock:
                self._p_est = self._rows_hint / cost
        if not self._maybe_reestimate_demand():
            # demand unchanged (or best-effort job): still nudge a lazy
            # re-plan so share math sees the refreshed P on its next round
            self._service._request_replan()
        else:
            self._service._rebalance()

    def _retry_claim(self, pid: int, exc: IoFaultError) -> bool:
        """Bounded-backoff recovery for one claim's retryable I/O fault.

        Returns True when the fault is absorbed: the claim is re-queued
        (embargoed ``io_backoff_s * 2**(attempt-1)`` on the queue's clock)
        and its still-pending future is resolved by a later re-produce, so
        the consumer only ever sees latency.  A ``DeviceOfflineError``
        additionally re-routes the partition's reads through the store's
        replica/failover path before the retry lands.  False means the
        retry budget is exhausted — the caller quarantines the partition.
        """
        budget = max(0, int(self.job.io_retries))
        with self._slock:
            attempt = self._fault_attempts.get(pid, 0) + 1
            if attempt > budget:
                return False
            self._fault_attempts[pid] = attempt
            self._retries += 1
        if isinstance(exc, DeviceOfflineError) and self.job.store is not None:
            store = self.job.store
            if pid not in store.failover_partitions:
                store.allow_failover(pid)
                with self._slock:
                    self._failovers += 1
                self._service.events.emit(
                    "failover", job=self.name, pid=pid,
                    device=getattr(exc, "device", None),
                )
        delay = max(0.0, float(self.job.io_backoff_s)) * (2.0 ** (attempt - 1))
        if not self._queue.requeue(pid, delay=delay):
            # a straggler twin settled (or already re-queued) this pid first;
            # this loser's error carries no new information — drop it
            with self._slock:
                self._retries -= 1
            return True
        self._service.events.emit(
            "retry", job=self.name, pid=pid, attempt=attempt,
            delay_s=round(delay, 6), fault=type(exc).__name__,
        )
        self._service._wake()
        return True

    def _on_produce_error(self, pid: int, exc: BaseException) -> None:
        if (
            isinstance(exc, IoFaultError)
            and getattr(exc, "retryable", True)
            and not self.cancelled
        ):
            if self._retry_claim(pid, exc):
                return  # absorbed: the future stays pending for the retry
        quarantine = isinstance(exc, IoFaultError)
        if quarantine:
            # budget exhausted (or the fault is non-retryable, e.g. verified
            # at-rest corruption): surface a structured error, never hang
            with self._slock:
                attempts = self._fault_attempts.get(pid, 0)
            exc = SessionError(
                f"partition {pid} of job {self.name!r} quarantined after "
                f"{attempts} I/O retr{'y' if attempts == 1 else 'ies'}: {exc}",
                job=self.name, pid=pid, attempts=attempts, cause=exc,
            )
        winner = self._queue.complete_error(pid, exc)  # duplicate losers drop
        if winner and quarantine:
            with self._slock:
                self._quarantined += 1
            self._service.events.emit(
                "quarantine", job=self.name, pid=pid, attempts=attempts,
                fault=type(exc.cause).__name__,
            )
        if winner and self._cache_key is not None:
            with self._slock:
                key = self._cache_keys.pop(pid, None)  # winner-only, as above
            if key is not None:
                # deterministic in the key: followers would fail identically
                self._cache.abandon(key, exc)


@dataclasses.dataclass
class _PoolWorker:
    """One pool worker's control-plane record (a simulated ISP unit).

    ``killed`` is the crash simulation: the thread notices at its next
    pipeline boundary, abandons whatever it holds (claims expire back
    through the straggler path), and exits without completing anything.
    ``retired`` is the graceful shrink: finish the chunk in hand, claim
    nothing new, exit.  ``chunk`` mirrors the claims currently in the
    worker's hands so ``kill_worker`` can expire them promptly even while
    the thread is deep inside a produce."""

    wid: int
    device: Optional[int]
    thread: Optional[threading.Thread] = None
    killed: threading.Event = dataclasses.field(default_factory=threading.Event)
    retired: threading.Event = dataclasses.field(default_factory=threading.Event)
    chunk: Optional[_Chunk] = None


class PreprocessingService:
    """The shared preprocessing pool: submit jobs, stream their batches.

    One fixed pool of ``num_workers`` worker threads (the provisioned
    ISP-unit fleet) serves every admitted session.  The scheduler is a
    two-pass round-robin: pass 1 respects each session's allocated share
    (QoS isolation), pass 2 is work-conserving (idle units serve any
    claimable session).  Backpressure is per-session (``SessionQueue``), so
    one slow consumer never idles the pool.

    With ``devices`` (a ``data.storage.DeviceFleet`` or a device count) the
    pool is no longer a fungible bag: each worker is an ISP unit bound to
    one device (round-robin), claims become locality-aware — a worker
    prefers partitions its own device owns, and takes a foreign partition
    only when the owning device's live queue prices the ISP path past the
    host path (``cost_model.should_offload``) or that device has no bound
    unit.  Foreign produces are HOST-fallback produces: same bytes, charged
    to the fleet's host ledger (link + host compute) instead of the device.
    ``locality=False`` keeps the fleet's ledgers but schedules blind (the
    round-robin baseline the skew bench compares against).
    """

    def __init__(
        self,
        num_workers: int = 2,
        *,
        cache: Optional[FeatureCache] = None,
        start: bool = True,
        devices: Optional[Union[int, DeviceFleet]] = None,
        locality: bool = True,
        cost_model: Optional[ContentionAwareCostModel] = None,
        pipeline: bool = True,
        admission: str = "strict",
    ):
        assert num_workers >= 1, "pool needs at least one worker"
        assert admission in ("strict", "slo"), admission
        self.cache = cache  # ONE shared feature cache across every tenant
        self.locality = locality
        # admission="slo": QoS-tiered admission (core.planner.plan_pool_slo).
        # Release-candidate jobs take surplus before exploratory ones and may
        # preempt exploratory floors; an existing session whose floor is
        # preempted keeps running on work-conserving backfill only (share 0)
        # and its slo_status says so — degrade/reject, never silent
        # starvation.  "strict" keeps the historical fail-fast behavior.
        self.admission = admission
        # pipeline=False disables the zero-stall worker path (megabatch
        # coalescing + stage/kernel overlap): every produce runs the legacy
        # synchronous claim->produce->complete loop.  The bench's serial
        # baseline and a safety hatch; batches are bitwise identical either
        # way.
        self.pipeline = pipeline
        self.cost_model = cost_model or ContentionAwareCostModel()
        if isinstance(devices, int):
            # budgets from the SAME model that prices routing decisions, so
            # the ledgers charge at the rates should_offload predicts with
            devices = (
                DeviceFleet.from_cost_model(devices, self.cost_model)
                if devices > 0 else None
            )
        self.fleet: Optional[DeviceFleet] = devices
        self._topology: Optional[DeviceTopology] = None
        self._manned: set = set()
        self._sessions: List[Session] = []
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._wake_cv = threading.Condition()
        self._rr = 0
        self._replan = False  # a session's hit-rate-discounted demand moved
        self.plan: Optional[PoolPlan] = None
        # the control plane's structured event stream: membership changes,
        # claim re-issues, checkpoints, scale decisions, plan changes
        self.events = EventLog()
        if cache is not None:
            # the spill tier publishes corrupt-block drops through the
            # service's event stream — wired BEFORE warm_start so a corrupt
            # block skipped at boot is observable too
            spill = getattr(cache, "spill", None)
            if spill is not None and getattr(spill, "events", None) is None:
                spill.events = self.events
            # feature-cache warm start: promote restart-survivable spilled
            # blocks back into the memory tier before any worker runs
            cache.warm_start()
        # pool membership is DYNAMIC (kill/join at runtime): wid -> record.
        # _all_threads keeps every thread ever spawned for join-on-close;
        # dead workers leave _workers (capacity) immediately on kill/retire.
        self._workers: Dict[int, _PoolWorker] = {}
        self._all_threads: List[threading.Thread] = []
        self._next_wid = 0
        self._started = False
        for _ in range(num_workers):
            self._spawn_worker()  # boot membership: no join events
        if start:
            self.start()

    @property
    def num_workers(self) -> int:
        """Live pool capacity (the planner's unit count) — moves with
        ``add_worker``/``remove_worker``/``kill_worker``."""
        with self._lock:
            return len(self._workers)

    def _refresh_topology(self) -> None:
        """Recompute device bindings from LIVE membership (caller holds
        ``_lock``): kill/join moves units between devices, and the planner's
        per-device shares plus host-fallback eligibility must follow."""
        if self.fleet is None:
            return
        upd = {d: 0 for d in range(len(self.fleet))}
        for w in self._workers.values():
            if w.device is not None:
                upd[w.device] += 1
        self._topology = DeviceTopology(upd)
        self._manned = self._topology.manned

    def _spawn_worker(self, device: Optional[int] = None) -> _PoolWorker:
        """Create (and, once started, launch) one pool worker.  With a
        fleet, an unpinned worker binds to the least-manned device (boot
        order reproduces the classic round-robin binding)."""
        with self._lock:
            wid = self._next_wid
            self._next_wid += 1
            if self.fleet is None:
                device = None
            elif device is None:
                counts = {d: 0 for d in range(len(self.fleet))}
                for w in self._workers.values():
                    if w.device is not None:
                        counts[w.device] += 1
                device = min(counts, key=lambda d: (counts[d], d))
            w = _PoolWorker(wid=wid, device=device)
            w.thread = threading.Thread(
                target=self._worker_loop, args=(w,), daemon=True,
                name=f"presto-pool-{wid}",
            )
            self._workers[wid] = w
            self._all_threads.append(w.thread)
            self._refresh_topology()
            started = self._started
        if started:
            w.thread.start()
        return w

    # -- elastic membership ----------------------------------------------------

    def add_worker(self, device: Optional[int] = None) -> int:
        """Grow the pool by one worker at runtime; returns its wid.  Device
        binding, topology, and pool shares re-plan immediately."""
        if self.closed:
            raise RuntimeError("preprocessing service is closed")
        w = self._spawn_worker(device)
        with self._lock:
            if self._sessions:
                self._rebalance()
        self.events.emit(
            "worker_join", worker=w.wid, device=w.device, pool=self.num_workers
        )
        self._wake()
        return w.wid

    def kill_worker(self, wid: int) -> bool:
        """Crash-simulate one pool worker (the chaos drill).

        The worker leaves capacity immediately (topology + shares re-plan);
        its in-flight claims are force-expired so the next claim round
        re-issues them through the existing straggler path — the claims'
        futures stay pending and resolve from the re-issued produce, so
        every consumer stream stays bitwise identical to a no-failure run.
        The thread itself notices at its next pipeline boundary and abandons
        whatever it holds (cache leases, device occupancy) on its way out."""
        with self._lock:
            w = self._workers.pop(wid, None)
            if w is None:
                return False
            w.killed.set()
            held = w.chunk
            self._refresh_topology()
            if self._sessions:
                self._rebalance()
        reissued = [pid for pid, _f, _r in held.claims] if held is not None else []
        if held is not None:
            held.session._expire_claims(reissued)
        self.events.emit(
            "worker_leave", worker=wid, device=w.device, reason="killed",
            pool=self.num_workers, reissued=reissued,
        )
        self._wake()
        return True

    def remove_worker(self, wid: Optional[int] = None) -> Optional[int]:
        """Gracefully retire one worker (autoscaler shrink): it finishes the
        chunk in hand, claims nothing new, and exits.  Refuses to shrink
        below one worker or below the admission floor (one schedulable unit
        per admitted session).  Returns the retired wid, or None."""
        with self._lock:
            if wid is None:
                wid = max(self._workers, default=None)  # LIFO: newest first
            if wid is None or wid not in self._workers:
                return None
            if len(self._workers) - 1 < max(1, len(self._sessions)):
                return None
            w = self._workers.pop(wid)
            w.retired.set()
            self._refresh_topology()
            if self._sessions:
                self._rebalance()
        self.events.emit(
            "worker_leave", worker=wid, device=w.device, reason="retired",
            pool=self.num_workers,
        )
        self._wake()
        return wid

    def load_snapshot(self) -> Dict[str, int]:
        """The autoscaler's policy inputs: live workers, admitted sessions,
        backlog (unfinished partitions across every session), and aggregate
        hit-rate-discounted demand units."""
        with self._lock:
            sessions = list(self._sessions)
            workers = len(self._workers)
        backlog = 0
        demand = 0
        for s in sessions:
            backlog += s._queue.work.remaining()
            demand += effective_demand_units(s._demand, s._hit_rate())
        return {
            "workers": workers,
            "sessions": len(sessions),
            "backlog": backlog,
            "demand_units": demand,
        }

    def start(self) -> "PreprocessingService":
        if not self._started:
            self._started = True
            with self._lock:
                threads = [w.thread for w in self._workers.values()]
            for t in threads:
                if t is not None and t.ident is None:
                    t.start()
        return self

    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def __enter__(self) -> "PreprocessingService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _wake(self) -> None:
        """Nudge idle pool workers (new work, freed slot, or pacing signal)."""
        with self._wake_cv:
            self._wake_cv.notify_all()

    def close(self) -> None:
        """Stop the pool.  Sessions still streaming see a RuntimeError.
        A rooted spill tier gets the memory tier flushed through to it so a
        restarted service can ``warm_start`` from the full cache."""
        self._stop.set()
        self._wake()
        me = threading.current_thread()
        with self._lock:
            threads = list(self._all_threads)
        for t in threads:
            if t.is_alive() and t is not me:
                t.join(timeout=5.0)
        if self.cache is not None:
            self.cache.flush_spill()  # no-op without a rooted spill tier

    # -- job lifecycle ---------------------------------------------------------

    def _device_weights(self, extra: Optional[Session] = None):
        """Per-job device-demand weights for the planner (fleet pools only)."""
        if self._topology is None:
            return None
        sessions = list(self._sessions)
        if extra is not None and extra not in sessions:
            sessions.append(extra)
        return {
            s.name: s.device_weights
            for s in sessions
            if s.device_weights is not None
        } or None

    def submit(
        self, job: JobSpec, *, resume_from: Optional[SessionCheckpoint] = None
    ) -> Session:
        """Admit a job and return its Session (raises AdmissionError).

        ``resume_from`` (a ``SessionCheckpoint`` from a previous service
        incarnation) narrows the job to its undelivered partitions and
        re-seeds the tuner: the resumed stream picks up exactly where the
        checkpointed one stopped, and the union of both streams is bitwise
        identical to one uninterrupted run."""
        if self.closed:
            raise RuntimeError("preprocessing service is closed")
        if resume_from is not None:
            job = resume_from.apply(job)
        # A finished session retires from the worker loop's finally block,
        # which may still be running when its consumer's drain() returns —
        # prune now so back-to-back submits never fail admission against a
        # tenant that is already done.
        self._prune()
        with self._lock:
            if any(s.name == job.name for s in self._sessions):
                raise ValueError(f"job name {job.name!r} already active")
            demands = {s.name: s._demand for s in self._sessions}
            demands[job.name] = max(1, job.units or 1)
            rates = {s.name: s._hit_rate() for s in self._sessions}
            # binds device backlog on the fleet
            session = Session(self, job, resume_from=resume_from)
            try:
                if self.admission == "slo":
                    plan = self._plan_slo(
                        demands, rates, joining=session,
                        device_weights=self._device_weights(session),
                    )
                else:
                    plan = plan_pool(  # admission
                        self.num_workers, demands, rates,
                        topology=self._topology,
                        device_weights=self._device_weights(session),
                    )
            except AdmissionError:
                session._release_all_backlog()  # rejected: unbind its backlog
                raise
            self._sessions.append(session)
            self._apply(plan)
        self.events.emit(
            "session_join", job=job.name, partitions=session.total,
            demand_units=session._demand, share=session.share,
        )
        if resume_from is not None:
            self.events.emit(
                "resume", job=job.name, remaining=session.total,
                skipped=len(resume_from.delivered),
            )
        self._wake()
        return session

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            out = {
                "workers": self.num_workers,
                "active_jobs": [s.name for s in self._sessions],
                "shares": dict(self.plan.shares) if self.plan else {},
                "oversubscribed": bool(self.plan and self.plan.oversubscribed),
            }
            if self.plan is not None and self.plan.device_shares is not None:
                out["device_shares"] = {
                    d: dict(js) for d, js in self.plan.device_shares.items()
                }
        if self.fleet is not None:
            out["devices"] = self.fleet.utilization()
            out["host"] = {
                "busy_s": self.fleet.host_busy_s,
                "link_bytes": self.fleet.host_link_bytes,
                "produces": self.fleet.host_produces,
            }
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        out["events"] = self.events.summary()
        return out

    def _apply(self, plan: PoolPlan) -> None:
        prev = self.plan.shares if self.plan is not None else None
        self.plan = plan
        for s in self._sessions:
            s.share = plan.shares.get(s.name, 0)
        if plan.shares != prev:
            self.events.emit(
                "plan", capacity=plan.capacity, shares=dict(plan.shares)
            )

    def _request_replan(self) -> None:
        """A session's effective demand moved (feature-cache hit rate shift);
        re-plan lazily on the next scheduling round rather than here — the
        caller may be deep inside a claim under several locks."""
        self._replan = True
        self._wake()

    def _plan_slo(
        self,
        demands: Dict[str, int],
        rates: Dict[str, float],
        *,
        joining: Optional[Session] = None,
        device_weights=None,
    ) -> PoolPlan:
        """QoS-tiered planning over the current sessions (plus an optionally
        joining one); caller holds ``_lock``.  Raises ``AdmissionError`` when
        the joining job itself is rejected.  An EXISTING session whose floor
        a release candidate preempted is marked ``slo_status="preempted"``
        and drops to share 0 — it keeps running on work-conserving backfill
        only until capacity returns, and the preemption is emitted as an
        event rather than happening silently."""
        sessions = list(self._sessions)
        if joining is not None:
            sessions.append(joining)
        reqs = [
            SloRequest(
                s.name, demands.get(s.name, s._demand),
                s.job.qos_class, s.job.deadline_s,
            )
            for s in sessions
        ]
        plan, decisions = plan_pool_slo(
            self.num_workers, reqs, rates,
            topology=self._topology, device_weights=device_weights,
        )
        if joining is not None:
            mine = decisions[joining.name]
            if mine.status == "rejected":
                raise AdmissionError(
                    f"job {joining.name!r} rejected: {mine.reason}"
                )
        for s in sessions:
            d = decisions.get(s.name)
            if d is None:
                continue
            prev = s.slo_status
            status = d.status
            if status == "rejected" and s is not joining:
                status = "preempted"
            s.slo_status = status
            if status == "preempted" and prev != "preempted":
                self.events.emit(
                    "preempt", job=s.name, qos_class=s.job.qos_class,
                    by=(joining.name if joining is not None else None),
                )
        return plan

    def _rebalance(self) -> None:
        with self._lock:
            self._replan = False
            demands = {s.name: s._demand for s in self._sessions}
            rates = {s.name: s._hit_rate() for s in self._sessions}
            try:
                if self.admission == "slo":
                    plan = self._plan_slo(
                        demands, rates, device_weights=self._device_weights()
                    )
                else:
                    plan = plan_pool(
                        self.num_workers, demands, rates,
                        topology=self._topology,
                        device_weights=self._device_weights(),
                    )
            except AdmissionError:
                # A crash dropped capacity below the admission floor for the
                # sessions already inside.  Degrade rather than evict: every
                # session keeps a 1-unit floor share (pass-2 work-conserving
                # scheduling keeps the pool live) until workers rejoin.
                plan = PoolPlan(
                    self.num_workers, dict(demands),
                    {j: 1 for j in demands}, effective_demand=dict(demands),
                )
            self._apply(plan)

    def _retire(self, session: Session) -> None:
        """Drop a finished/cancelled session from scheduling and rebalance."""
        session._clear_prefetch()  # staged-ahead pages + unconsumed leases
        if session._owner_of is not None:
            session._release_all_backlog()  # cancelled leftovers unbind
        removed = False
        with self._lock:
            if session in self._sessions:
                self._sessions.remove(session)
                removed = True
        if removed:
            self._rebalance()
            self.events.emit(
                "session_leave", job=session.name,
                done=session.done, cancelled=session.cancelled,
            )
        self._wake()  # freed units may unblock other tenants' pass-1 claims

    # -- the pool --------------------------------------------------------------

    def _release_slot(self, sess: Session, wdev: Optional[int]) -> None:
        with self._lock:
            sess._active_workers -= 1
            if wdev is not None:
                sess._active_by_dev[wdev] = sess._active_by_dev.get(wdev, 1) - 1

    def _next_task(
        self, wdev: Optional[int] = None, stageable_only: bool = False
    ) -> Optional[Tuple[Session, Tuple[int, Future, Optional[str]]]]:
        """Two-pass round-robin claim.  The claim itself — which may probe
        the feature cache, hash a disk partition's bytes, or read a spilled
        block — runs OUTSIDE the service lock: the worker reserves its
        session slot first (so shares stay enforced while it probes) and
        releases it if the claim comes back empty.

        ``wdev`` is the worker's bound device.  Pass 1 additionally enforces
        the plan's per-device shares (a hot device's job cannot occupy a
        cold device's units past its slice); pass 2 stays work-conserving.
        With ``locality`` on, the claim prefers partitions the worker's own
        device owns and may take foreign ones only via host fallback.
        """
        if self._replan:
            self._rebalance()  # pick up hit-rate-discounted demand shifts
        prefer = wdev if (self.locality and wdev is not None) else None
        for enforce_share in (True, False):
            with self._lock:
                n = len(self._sessions)
                candidates = [self._sessions[(self._rr + i) % n] for i in range(n)]
            for i, sess in enumerate(candidates):
                if stageable_only and not sess._stageable:
                    continue  # overlap prefetch: only separable-stage work
                with self._lock:
                    if sess.cancelled:
                        continue
                    if enforce_share and sess._active_workers >= max(sess.share, 1):
                        continue
                    if (
                        enforce_share
                        and wdev is not None
                        and self.plan is not None
                        and self.plan.device_shares is not None
                        and sess._owner_of is not None
                    ):
                        cap = self.plan.device_shares.get(wdev, {}).get(sess.name, 0)
                        if sess._active_by_dev.get(wdev, 0) >= cap:
                            continue  # this device's slice is spoken for
                    sess._active_workers += 1  # reserve before the claim
                    if wdev is not None:
                        sess._active_by_dev[wdev] = (
                            sess._active_by_dev.get(wdev, 0) + 1
                        )
                claimed = sess._queue.claim(prefer_device=prefer)
                if claimed is None:
                    self._release_slot(sess, wdev)
                    continue
                with self._lock:
                    self._rr = (self._rr + i + 1) % max(n, 1)
                return sess, claimed
        return None

    def _prune(self) -> None:
        with self._lock:
            finished = [
                s for s in self._sessions if s.cancelled or s._queue.exhausted
            ]
        for s in finished:
            self._retire(s)

    def _stage_task(
        self, sess: Session, claim, wdev: Optional[int]
    ) -> Optional[_Chunk]:
        """Coalesce + stage one claimed task into a launchable chunk.

        A failed staging has already errored its claims' futures; the
        worker's reserved slot is released here so shares stay exact."""
        prefer = wdev if (self.locality and wdev is not None) else None
        chunk = sess._stage_chunk(claim, prefer)
        if chunk is None:
            self._release_slot(sess, wdev)
            if sess._queue.exhausted:
                self._retire(sess)
            self._wake()
        return chunk

    def _worker_loop(self, w: _PoolWorker) -> None:
        """The zero-stall produce loop of one pool worker.

        Stageable (engine-backed) sessions run a double-buffered pipeline:
        claim -> coalesce up to ``JobSpec.megabatch`` compatible claims ->
        stage reads/page-builds -> dispatch ONE (mega)batched kernel launch
        asynchronously -> while it executes, claim + stage the NEXT chunk ->
        block only at delivery.  Per-partition cost tends to
        ``max(io, compute)`` instead of ``io + compute``, and K claims pay
        one dispatch.  Opaque produce_fn sessions run their legacy
        synchronous path through the same chunk machinery (no coalescing,
        no overlap — their stage is not separable).

        Elasticity (``core.ctrlplane``): the loop checks ``w.killed`` at
        pipeline boundaries.  A killed worker abandons whatever it holds —
        chunks in hand are un-routed, their cache leases dropped, and their
        claims expired back onto the straggler path so a live worker
        re-issues them; nothing it produced after the kill is delivered.
        ``w.retired`` is the graceful variant: finish the chunk in hand,
        take no new work.
        """
        wdev = w.device
        staged: Optional[_Chunk] = None
        while True:
            if w.killed.is_set():
                break  # crash: the staged chunk is abandoned after the loop
            if staged is None:
                if self._stop.is_set() or w.retired.is_set():
                    break
                task = self._next_task(wdev)
                if task is None:
                    self._prune()
                    # idle: sleep until nudged (submit / freed slot / pacing
                    # signal); the timeout keeps straggler scans alive
                    with self._wake_cv:
                        self._wake_cv.wait(timeout=0.05)
                    continue
                staged = self._stage_task(task[0], task[1], wdev)
                w.chunk = staged
                continue
            chunk, staged = staged, None
            sess = chunk.session
            try:
                handle = sess._dispatch_chunk(chunk)
                overlap_s = 0.0
                if (
                    handle[0] == "async"
                    and not self._stop.is_set()
                    and not w.killed.is_set()
                    and not w.retired.is_set()
                ):
                    # double buffering: the next chunk's partition read and
                    # numpy page-build overlap the in-flight kernel
                    t_ov = time.perf_counter()
                    nxt = self._next_task(wdev, stageable_only=True)
                    if nxt is not None:
                        staged = self._stage_task(nxt[0], nxt[1], wdev)
                    # deep lookahead: with the next chunk staged, walk the
                    # peek window further out — pre-warm the feature cache
                    # and pre-stage future claims' reads under the byte
                    # budget, all still hidden behind the in-flight kernel
                    prefer = wdev if (self.locality and wdev is not None) else None
                    (staged.session if staged is not None else sess)._prefetch_ahead(
                        prefer
                    )
                    overlap_s = time.perf_counter() - t_ov
                if w.killed.is_set():
                    # crash point: results in hand die with the worker —
                    # the claims go back through the straggler path and a
                    # live worker reproduces them (winner semantics drop
                    # any duplicate, so delivery stays bitwise identical)
                    sess._abandon_chunk(chunk)
                    if staged is not None:
                        staged.session._abandon_chunk(staged)
                        self._release_slot(staged.session, wdev)
                        staged = None
                    continue  # loop top exits on the killed flag
                sess._finish_chunk(chunk, handle, overlap_s)
            finally:
                w.chunk = staged
                self._release_slot(sess, wdev)
                if sess._queue.exhausted:
                    self._retire(sess)
                self._wake()  # a share slot freed (or the job just finished)
        if w.killed.is_set() and staged is not None:
            staged.session._abandon_chunk(staged)
            self._release_slot(staged.session, wdev)
        w.chunk = None
