"""Preprocessing-as-a-service: a shared worker/ISP pool serving many jobs.

The paper's deployment end-game — and the disaggregated-DPP model of Meta's
production ingestion stack — is preprocessing as a *service*: one provisioned
fleet of ISP units shared across training jobs, with per-job admission and
unit allocation, instead of a private worker pool hand-wired into each
trainer.  This module is that public surface:

    service = PreprocessingService(num_workers=8)
    session = service.submit(JobSpec(
        name="rm1", spec=spec, store=store, partitions=range(64),
        placement="presto", target_samples_per_s=50_000))
    for pid, minibatch in session:          # backpressured stream
        state, metrics = train_step(state, minibatch)

* ``JobSpec`` — what a train manager hands the service at job launch: the
  RecSys Transform (a ``TransformSpec`` or a prebuilt ``PreStoEngine``), the
  partition range, placement mode, and QoS target (samples/s).
* ``Session`` — a backpressured streaming iterator of mini-batch futures in
  claim order (``futures()`` for the raw future stream; iterating resolves
  them to ``(pid, minibatch)``), with ``stats()``, ``cancel()``, and
  ``drain()``.
* ``PreprocessingService`` — owns the one worker pool.  Admission control
  and per-job unit shares come from ``core.planner.plan_pool`` (ceil(T/P)
  demand per job, re-planned whenever jobs join, leave, or re-estimate their
  per-worker throughput P); pool workers feed every session's
  ``data.loader.SessionQueue``.  Shares are work-conserving: idle capacity
  may serve any job beyond its share, but a job with work never gets less
  than its share.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeoutError
from queue import Empty
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Tuple, Union

from repro.core.planner import AdmissionError, PoolPlan, plan_pool
from repro.core.presto import PreStoEngine
from repro.core.spec import TransformSpec
from repro.data.loader import SessionQueue
from repro.data.storage import PartitionedStore

__all__ = [
    "AdmissionError",
    "JobSpec",
    "PreprocessingService",
    "Session",
    "SessionStats",
]

MAX_DEMAND_UNITS = 64  # sanity cap on a single job's ceil(T/P) estimate


@dataclasses.dataclass
class JobSpec:
    """One training job's preprocessing contract with the service."""

    name: str
    partitions: Iterable[int]
    spec: Optional[TransformSpec] = None
    store: Optional[PartitionedStore] = None
    placement: Union[str, Dict[str, str]] = "presto"
    target_samples_per_s: Optional[float] = None  # QoS; None = best effort
    units: Optional[int] = None  # explicit demand override (else T/P estimate)
    queue_depth: int = 4
    straggler_timeout: float = 30.0
    engine: Optional[PreStoEngine] = None  # prebuilt (shares its jit cache)
    produce_fn: Optional[Callable[[int], Any]] = None  # override / test hook

    def build_produce(self) -> Tuple[Callable[[int], Any], Optional[PreStoEngine]]:
        """Resolve the per-partition production callable for this job."""
        if self.produce_fn is not None:
            return self.produce_fn, self.engine
        engine = self.engine
        if engine is None:
            if self.spec is None:
                raise ValueError(
                    f"JobSpec {self.name!r} needs a spec, an engine, or a produce_fn"
                )
            engine = PreStoEngine(self.spec, placement=self.placement)
        if self.store is None:
            raise ValueError(f"JobSpec {self.name!r} needs a store")
        store = self.store
        return (lambda pid: engine.produce_batch(store, pid)), engine


@dataclasses.dataclass
class SessionStats:
    """Point-in-time accounting for one session (paper Fig. 3 metrics)."""

    job: str
    total: int
    produced: int = 0  # winner completions by pool workers
    delivered: int = 0  # batches handed to the consumer
    reissues: int = 0  # straggler backup claims
    duplicates_dropped: int = 0  # straggler losers discarded
    rows_delivered: int = 0
    produce_time_s: float = 0.0  # pool-worker seconds spent on this job
    wait_time_s: float = 0.0  # consumer seconds blocked on the stream
    wall_time_s: float = 0.0
    demand_units: int = 1
    share: int = 0
    target_samples_per_s: Optional[float] = None
    worker_samples_per_s: float = 0.0  # measured per-worker P
    cancelled: bool = False
    done: bool = False

    @property
    def achieved_samples_per_s(self) -> float:
        return self.rows_delivered / max(self.wall_time_s, 1e-9)

    @property
    def starvation(self) -> float:
        """Fraction of the session's wall time the consumer spent blocked."""
        return self.wait_time_s / max(self.wall_time_s, 1e-9)


def _batch_rows(batch: Any) -> int:
    try:
        return int(batch["labels"].shape[0])
    except Exception:
        return 0


class Session:
    """One job's handle on the service: a backpressured mini-batch stream.

    Single-consumer: iterate the session (or its ``futures()``) from one
    thread.  Iteration yields ``(pid, minibatch)`` in claim order, ends after
    every partition is delivered, and re-raises a worker's production error.
    """

    def __init__(self, service: "PreprocessingService", job: JobSpec):
        self._service = service
        self.job = job
        self.name = job.name
        self._produce_fn, self.engine = job.build_produce()
        self._queue = SessionQueue(
            job.partitions,
            depth=job.queue_depth,
            straggler_timeout=job.straggler_timeout,
        )
        self.total = self._queue.total
        # guarded by service._lock:
        self.share = 0
        self._active_workers = 0
        self._demand = max(1, job.units or 1)
        # guarded by self._slock:
        self._slock = threading.Lock()
        self._produced = 0
        self._handed = 0  # futures taken off the delivery queue (any stream)
        self._delivered = 0
        self._duplicates = 0
        self._rows_delivered = 0
        self._produce_time = 0.0
        self._wait_time = 0.0
        self._p_est: Optional[float] = None
        self._t0 = time.perf_counter()
        self._t_end: Optional[float] = None

    # -- consumer side ---------------------------------------------------------

    @property
    def cancelled(self) -> bool:
        return self._queue.cancelled.is_set()

    @property
    def done(self) -> bool:
        """Every partition delivered to the consumer."""
        with self._slock:
            return self._delivered >= self.total

    def _next_future(self) -> Optional[Future]:
        """Take the next undelivered future off the stream (None = stream end).

        The hand-off count is session state, not per-iterator, so a partially
        consumed session can be re-iterated (or ``drain()``-ed) and resumes
        where the previous loop stopped.
        """
        while not self.cancelled:
            with self._slock:
                if self._handed >= self.total:
                    return None
            try:
                fut = self._queue.out.get(timeout=0.25)
            except Empty:
                self._check_liveness()
                continue
            with self._slock:
                self._handed += 1
            return fut
        return None

    def futures(self) -> Iterator[Future]:
        """The raw stream: mini-batch futures in claim order.

        Taking a future transfers ownership: it counts as delivered for
        backpressure, so pacing beyond ``queue_depth`` outstanding claims is
        the raw consumer's responsibility.  Delivery stats (and ``done``)
        are recorded when each future resolves.  Shares the delivery queue
        with plain iteration — use one stream or the other.
        """
        while True:
            fut = self._next_future()
            if fut is None:
                return
            self._queue.mark_delivered()
            self._service._wake()
            fut.add_done_callback(self._account_delivery)
            yield fut

    def _account_delivery(self, fut: Future) -> None:
        """Delivery accounting for the raw-future stream (on resolution)."""
        if fut.cancelled() or fut.exception() is not None:
            return
        _pid, batch = fut.result()
        with self._slock:
            self._delivered += 1
            self._rows_delivered += _batch_rows(batch)
            if self._delivered >= self.total:
                self._t_end = time.perf_counter()

    def __iter__(self) -> Iterator[Tuple[int, Any]]:
        while True:
            t0 = time.perf_counter()
            fut = self._next_future()
            if fut is None:
                return
            while True:
                if self.cancelled:
                    return
                try:
                    pid, batch = fut.result(timeout=0.25)
                    break
                except FutureTimeoutError:
                    self._check_liveness()
            # pacing signal only once the batch is resolved and in the
            # consumer's hands: at most queue_depth batches sit materialized
            self._queue.mark_delivered()
            self._service._wake()
            with self._slock:
                self._wait_time += time.perf_counter() - t0
                self._delivered += 1
                self._rows_delivered += _batch_rows(batch)
                if self._delivered >= self.total:
                    self._t_end = time.perf_counter()
            yield pid, batch

    def drain(self) -> int:
        """Consume and discard the rest of the stream; returns batches eaten.

        After ``cancel()`` this returns immediately; otherwise it blocks
        until the job's remaining partitions are produced (an end-of-job
        barrier that keeps pool accounting exact)."""
        n = 0
        for _ in self:
            n += 1
        return n

    def cancel(self) -> None:
        """Stop the stream: pool workers stop claiming for this session,
        undelivered results are discarded, and the pool is rebalanced."""
        if self.cancelled:
            return
        self._queue.cancel()
        with self._slock:
            if self._t_end is None:
                self._t_end = time.perf_counter()
        self._service._retire(self)

    def stats(self) -> SessionStats:
        with self._slock:
            wall = (self._t_end or time.perf_counter()) - self._t0
            return SessionStats(
                job=self.name,
                total=self.total,
                produced=self._produced,
                delivered=self._delivered,
                reissues=self._queue.work.reissues,
                duplicates_dropped=self._duplicates,
                rows_delivered=self._rows_delivered,
                produce_time_s=self._produce_time,
                wait_time_s=self._wait_time,
                wall_time_s=wall,
                demand_units=self._demand,
                share=self.share,
                target_samples_per_s=self.job.target_samples_per_s,
                worker_samples_per_s=self._p_est or 0.0,
                cancelled=self.cancelled,
                done=self._delivered >= self.total,
            )

    def _check_liveness(self) -> None:
        if self._service.closed:
            with self._slock:
                undelivered = self.total - self._delivered
            raise RuntimeError(
                f"preprocessing service closed with {undelivered} batches "
                f"undelivered for job {self.name!r}"
            )

    # -- pool-worker side ------------------------------------------------------

    def _on_produced(self, pid: int, batch: Any, dt: float) -> None:
        winner = self._queue.complete(pid, batch)
        rows = _batch_rows(batch)
        demand_changed = False
        with self._slock:
            self._produce_time += dt
            if not winner:
                self._duplicates += 1
            else:
                self._produced += 1
                if rows and dt > 0:
                    p = rows / dt
                    self._p_est = p if self._p_est is None else 0.5 * self._p_est + 0.5 * p
        if winner and self.job.target_samples_per_s and self._p_est:
            # QoS re-estimate: demand = ceil(target / measured per-worker P)
            new_demand = max(
                1,
                min(
                    MAX_DEMAND_UNITS,
                    math.ceil(self.job.target_samples_per_s / self._p_est),
                ),
            )
            with self._service._lock:
                if new_demand != self._demand:
                    self._demand = new_demand
                    demand_changed = True
        if demand_changed:
            self._service._rebalance()

    def _on_produce_error(self, pid: int, exc: BaseException) -> None:
        self._queue.complete_error(pid, exc)  # duplicate losers are dropped


class PreprocessingService:
    """The shared preprocessing pool: submit jobs, stream their batches.

    One fixed pool of ``num_workers`` worker threads (the provisioned
    ISP-unit fleet) serves every admitted session.  The scheduler is a
    two-pass round-robin: pass 1 respects each session's allocated share
    (QoS isolation), pass 2 is work-conserving (idle units serve any
    claimable session).  Backpressure is per-session (``SessionQueue``), so
    one slow consumer never idles the pool.
    """

    def __init__(self, num_workers: int = 2, *, start: bool = True):
        assert num_workers >= 1, "pool needs at least one worker"
        self.num_workers = num_workers
        self._sessions: List[Session] = []
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._wake_cv = threading.Condition()
        self._rr = 0
        self.plan: Optional[PoolPlan] = None
        self._threads = [
            threading.Thread(target=self._worker_loop, daemon=True, name=f"presto-pool-{i}")
            for i in range(num_workers)
        ]
        self._started = False
        if start:
            self.start()

    def start(self) -> "PreprocessingService":
        if not self._started:
            self._started = True
            for t in self._threads:
                t.start()
        return self

    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def __enter__(self) -> "PreprocessingService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _wake(self) -> None:
        """Nudge idle pool workers (new work, freed slot, or pacing signal)."""
        with self._wake_cv:
            self._wake_cv.notify_all()

    def close(self) -> None:
        """Stop the pool.  Sessions still streaming see a RuntimeError."""
        self._stop.set()
        self._wake()
        me = threading.current_thread()
        for t in self._threads:
            if t.is_alive() and t is not me:
                t.join(timeout=5.0)

    # -- job lifecycle ---------------------------------------------------------

    def submit(self, job: JobSpec) -> Session:
        """Admit a job and return its Session (raises AdmissionError)."""
        if self.closed:
            raise RuntimeError("preprocessing service is closed")
        with self._lock:
            if any(s.name == job.name for s in self._sessions):
                raise ValueError(f"job name {job.name!r} already active")
            demands = {s.name: s._demand for s in self._sessions}
            demands[job.name] = max(1, job.units or 1)
            plan = plan_pool(self.num_workers, demands)  # admission control
            session = Session(self, job)
            self._sessions.append(session)
            self._apply(plan)
        self._wake()
        return session

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "workers": self.num_workers,
                "active_jobs": [s.name for s in self._sessions],
                "shares": dict(self.plan.shares) if self.plan else {},
                "oversubscribed": bool(self.plan and self.plan.oversubscribed),
            }

    def _apply(self, plan: PoolPlan) -> None:
        self.plan = plan
        for s in self._sessions:
            s.share = plan.shares.get(s.name, 0)

    def _rebalance(self) -> None:
        with self._lock:
            demands = {s.name: s._demand for s in self._sessions}
            self._apply(plan_pool(self.num_workers, demands))

    def _retire(self, session: Session) -> None:
        """Drop a finished/cancelled session from scheduling and rebalance."""
        with self._lock:
            if session in self._sessions:
                self._sessions.remove(session)
                self._rebalance()
        self._wake()  # freed units may unblock other tenants' pass-1 claims

    # -- the pool --------------------------------------------------------------

    def _next_task(self) -> Optional[Tuple[Session, Tuple[int, Future]]]:
        with self._lock:
            n = len(self._sessions)
            for enforce_share in (True, False):
                for i in range(n):
                    sess = self._sessions[(self._rr + i) % n]
                    if sess.cancelled:
                        continue
                    if enforce_share and sess._active_workers >= max(sess.share, 1):
                        continue
                    claimed = sess._queue.claim()
                    if claimed is None:
                        continue
                    sess._active_workers += 1
                    self._rr = (self._rr + i + 1) % n
                    return sess, claimed
            return None

    def _prune(self) -> None:
        with self._lock:
            finished = [
                s for s in self._sessions if s.cancelled or s._queue.exhausted
            ]
        for s in finished:
            self._retire(s)

    def _worker_loop(self) -> None:
        while not self._stop.is_set():
            task = self._next_task()
            if task is None:
                self._prune()
                # idle: sleep until nudged (submit / freed slot / pacing
                # signal); the timeout keeps straggler-timeout scans alive
                with self._wake_cv:
                    self._wake_cv.wait(timeout=0.05)
                continue
            sess, (pid, _fut) = task
            t0 = time.perf_counter()
            try:
                batch = sess._produce_fn(pid)
            except BaseException as exc:  # noqa: BLE001 — consumer re-raises
                sess._on_produce_error(pid, exc)
            else:
                sess._on_produced(pid, batch, time.perf_counter() - t0)
            finally:
                with self._lock:
                    sess._active_workers -= 1
                if sess._queue.exhausted:
                    self._retire(sess)
                self._wake()  # a share slot freed (or the job just finished)
