"""Process-wide cache of compiled preprocessing executables.

The multi-tenant norm is many independently built ``PreStoEngine``s over the
*same* Transform — every tenant of a shared ``PreprocessingService`` builds
its own engine from an equal spec, every bench run builds a fresh one, and
each used to pay its own XLA compile even though the compiled program is
byte-for-byte the work of every other.  This registry closes that hole: a
compiled entry is keyed by the engine's *cache signature* (the lowered
opgraph's structural hash plus the per-family comm placement — exactly the
identity that makes two engines produce bitwise-equal batches) together with
the execution mode (solo vs megabatched launch) and the mesh identity, so
engines with equal signatures share ONE executable instead of recompiling
per engine.  Megabatch width K and partition rows specialize *inside* an
entry through jit's own shape cache; the registry records every trace with
its ``(k, rows)`` so compile-count discipline is observable
(``tests/test_execcache.py``).

Two guarantees the produce path leans on:

* **Exactly-once build per key** — ``get_or_build`` races collapse to one
  jit wrapper (the bug the old per-engine ``_jit_lock`` guarded against, now
  enforced process-wide).
* **Exactly-once trace per (key, arg shapes)** — ``_SharedExecutable``
  serializes the *first* call for each new shape signature, so concurrent
  pool workers hitting a cold executable trigger one compile, not a
  thundering herd of tracers.  Warm calls take a lock-free path.

Entries live for the process lifetime (no eviction): each one holds the
first engine of its signature alive through the traced body's closure, the
same order of residency as jit's own compilation cache — bounded by the
number of DISTINCT Transforms the process runs, not by engine count.
``EXECUTABLES.clear()`` drops everything when that bound is wrong for you.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["EXECUTABLES", "ExecKey", "ExecutableCache", "mesh_key"]


@dataclasses.dataclass(frozen=True)
class ExecKey:
    """Identity of one compiled preprocessing program.

    ``signature`` is ``PreStoEngine.cache_signature()`` — the lowered plan's
    structural hash plus the per-family comm placement, the same identity the
    feature cache trusts for bitwise equality.  ``mode`` separates the solo
    launch from the megabatched one (different traced bodies).  ``mesh`` pins
    sharded programs to their mesh *content* (axis names/sizes + device
    ids — stable across mesh objects, unlike ``id()``); mesh-less engines
    (the service norm) all share ``None``.  ``interpret`` keys the Pallas
    interpret-mode override: it changes the compiled program (interpreted
    vs native kernels), not the output bytes, so it lives here and NOT in
    the feature-cache signature.
    """

    signature: str
    mode: str  # "solo" | "mega"
    mesh: Optional[Tuple] = None  # mesh_key(mesh) for sharded programs
    interpret: Optional[bool] = None  # engine's Pallas interpret override


def mesh_key(mesh) -> Optional[Tuple]:
    """Stable content identity of a jax Mesh (None for mesh-less engines).

    Two distinct Mesh objects over the same axes and devices compile to the
    same program, so they share; keying by ``id()`` instead would both miss
    that sharing and — worse — alias a garbage-collected mesh's reused
    address to a different live one."""
    if mesh is None:
        return None
    return (
        tuple(mesh.axis_names),
        tuple(int(mesh.shape[a]) for a in mesh.axis_names),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def _shape_signature(pages: Dict[str, Any]) -> Tuple:
    """Hashable (name, shape, dtype) summary of one pages pytree."""
    return tuple(
        (k, tuple(v.shape), str(v.dtype)) for k, v in sorted(pages.items())
    )


class _SharedExecutable:
    """One jitted program shared by every engine with the same ExecKey.

    The first call for each new input-shape signature runs under a lock so
    concurrent cold callers produce exactly one trace/compile; once a shape
    is warm, calls go straight through.
    """

    __slots__ = ("_fn", "_lock", "_warm")

    def __init__(self, fn: Callable):
        self._fn = fn
        self._lock = threading.Lock()
        self._warm: set = set()

    def __call__(self, pages: Dict[str, Any]):
        sig = _shape_signature(pages)
        if sig in self._warm:
            return self._fn(pages)
        with self._lock:
            out = self._fn(pages)
            self._warm.add(sig)
        return out


class ExecutableCache:
    """The registry: ExecKey -> shared executable, with trace accounting."""

    def __init__(self):
        self._lock = threading.Lock()
        self._fns: Dict[ExecKey, _SharedExecutable] = {}
        self._traces: Dict[ExecKey, List[Dict[str, Any]]] = {}
        self.hits = 0  # get_or_build calls served by an existing entry
        self.builds = 0  # jit wrappers actually constructed

    def get_or_build(self, key: ExecKey, build: Callable[[], Callable]):
        """The executable for `key`, building (once) on first demand.

        ``build()`` returns the jitted callable; it runs under the registry
        lock, which is fine because building a jit wrapper traces nothing —
        tracing happens at first *call*, serialized by _SharedExecutable.
        """
        with self._lock:
            fn = self._fns.get(key)
            if fn is not None:
                self.hits += 1
                return fn
            self.builds += 1
            fn = _SharedExecutable(build())
            self._fns[key] = fn
            return fn

    def note_trace(self, key: ExecKey, *, k: int, rows: int) -> None:
        """Called from inside a traced body: records one (re)compile.

        Runs once per (key, shapes) — jit only re-enters the Python body
        when it traces — so the per-key list is the compile history."""
        with self._lock:
            self._traces.setdefault(key, []).append({"k": k, "rows": rows})

    def traces(self, key: ExecKey) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._traces.get(key, []))

    def trace_count(self, key: Optional[ExecKey] = None) -> int:
        with self._lock:
            if key is not None:
                return len(self._traces.get(key, []))
            return sum(len(v) for v in self._traces.values())

    def clear(self) -> None:
        """Drop every entry (tests / benchmarks wanting a cold registry).

        Engines that already resolved their executable keep working — they
        hold a direct reference; only future lookups rebuild."""
        with self._lock:
            self._fns.clear()
            self._traces.clear()
            self.hits = 0
            self.builds = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "entries": len(self._fns),
                "hits": self.hits,
                "builds": self.builds,
                "traces": sum(len(v) for v in self._traces.values()),
            }


# The process-wide registry every PreStoEngine consults by default.
EXECUTABLES = ExecutableCache()
