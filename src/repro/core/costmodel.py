"""CapEx/OpEx cost-efficiency + energy models (paper §V-C, Fig. 14/15).

    cost_efficiency = throughput x duration / (CapEx + OpEx)
    OpEx            = sum(power x duration x electricity)

Constants follow the paper: 3-year duration [7], $0.0733/kWh [42,43], 25 W
per SmartSSD, vendor-list CapEx for servers/cards.  The same machinery
expresses the TPU-adapted deployment (preprocessing shards co-resident with
training chips) so Fig. 15's conclusions can be checked under our hardware
assumptions, separately from the paper-faithful constants.
"""

from __future__ import annotations

import dataclasses

HOURS_3Y = 3 * 365 * 24
ELECTRICITY_USD_PER_KWH = 0.0733  # [42], [43]


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    capex_usd: float
    power_w: float  # sustained system power attributable to the unit
    note: str = ""


# CapEx anchors (vendor list prices at paper time; Dell R640 per [12]).
# Power is sustained under preprocessing load (the paper measures with
# Intel PCM, below TDP); SmartSSD CapEx calibrated to street pricing
# (~$2.8k) — with these the model lands on the paper's 4.3x/11.3x averages.
CPU_SERVER = DeviceModel("xeon-6242-2s", 8000.0, 250.0, "32 cores, 2-socket [12]")
CPU_CORE = DeviceModel("xeon-core", CPU_SERVER.capex_usd / 32, CPU_SERVER.power_w / 32)
SMARTSSD = DeviceModel("smartssd", 2800.0, 25.0, "NVMe U.2 FPGA+SSD [59]")
A100 = DeviceModel("a100", 10000.0, 250.0, "[52]")
U280 = DeviceModel("u280", 7500.0, 225.0, "[67]")
# TPU-adaptation entry: a v5e chip slice amortized per preprocessing shard.
TPU_V5E_SHARD = DeviceModel("v5e-shard", 4500.0, 200.0, "per-chip, list-ish")

DEVICES = {d.name: d for d in (CPU_SERVER, CPU_CORE, SMARTSSD, A100, U280, TPU_V5E_SHARD)}


def opex_usd(power_w: float, hours: float = HOURS_3Y) -> float:
    return power_w / 1000.0 * hours * ELECTRICITY_USD_PER_KWH


def tco_usd(device: DeviceModel, units: int, hours: float = HOURS_3Y) -> float:
    return units * (device.capex_usd + opex_usd(device.power_w, hours))


def cost_efficiency(
    throughput: float, device: DeviceModel, units: int, hours: float = HOURS_3Y
) -> float:
    """throughput x duration / (CapEx + OpEx); throughput in samples/s."""
    return throughput * hours * 3600.0 / tco_usd(device, units, hours)


def energy_kwh(device: DeviceModel, units: int, hours: float = HOURS_3Y) -> float:
    return units * device.power_w / 1000.0 * hours


def energy_efficiency(
    throughput: float, device: DeviceModel, units: int, hours: float = HOURS_3Y
) -> float:
    """samples per joule (throughput/W), the Fig. 15(a) metric."""
    return throughput / max(units * device.power_w, 1e-9)


@dataclasses.dataclass
class Comparison:
    """PreSto vs Disagg for one RM model at matched throughput T."""

    rm: str
    T: float  # matched preprocessing throughput (samples/s)
    cpu_cores: int
    isp_units: int

    def summary(self) -> dict:
        cpu_servers = -(-self.cpu_cores // 32)  # servers of 32 cores
        disagg_tco = tco_usd(CPU_SERVER, cpu_servers)
        presto_tco = tco_usd(SMARTSSD, self.isp_units)
        disagg_e = energy_kwh(CPU_SERVER, cpu_servers)
        presto_e = energy_kwh(SMARTSSD, self.isp_units)
        return {
            "rm": self.rm,
            "cpu_servers": cpu_servers,
            "isp_units": self.isp_units,
            "disagg_tco_usd": disagg_tco,
            "presto_tco_usd": presto_tco,
            "cost_efficiency_gain": disagg_tco / presto_tco,
            "disagg_energy_kwh": disagg_e,
            "presto_energy_kwh": presto_e,
            "energy_efficiency_gain": (self.T / (cpu_servers * CPU_SERVER.power_w))
            and (cpu_servers * CPU_SERVER.power_w) / (self.isp_units * SMARTSSD.power_w),
        }
