"""Cost models: CapEx/OpEx efficiency (paper §V-C, Fig. 14/15) and the
per-column-family placement chooser used by the ``hybrid`` execution mode.

    cost_efficiency = throughput x duration / (CapEx + OpEx)
    OpEx            = sum(power x duration x electricity)

Constants follow the paper: 3-year duration [7], $0.0733/kWh [42,43], 25 W
per SmartSSD, vendor-list CapEx for servers/cards.  The same machinery
expresses the TPU-adapted deployment (preprocessing shards co-resident with
training chips) so Fig. 15's conclusions can be checked under our hardware
assumptions, separately from the paper-faithful constants.

Placement choice (``choose_placement``): per column family, compare the ISP
roofline — max(stream the encoded pages, run the chain at the ISP unit's
compute rate) — against the host alternative — move encoded pages in and
train-ready tensors out over the link, then run at host compute rate.  The
family goes wherever it finishes first.  Byte-heavy/compute-light chains
(decode-dominated) favor ISP; compute-heavy/byte-light chains (Bucketize's
binary search over large boundary tables) favor the host, mirroring the
per-operator CPU-vs-accelerator selection of Zhu et al.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core import opgraph
from repro.core.spec import TransformSpec

HOURS_3Y = 3 * 365 * 24
ELECTRICITY_USD_PER_KWH = 0.0733  # [42], [43]


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    capex_usd: float
    power_w: float  # sustained system power attributable to the unit
    note: str = ""


# CapEx anchors (vendor list prices at paper time; Dell R640 per [12]).
# Power is sustained under preprocessing load (the paper measures with
# Intel PCM, below TDP); SmartSSD CapEx calibrated to street pricing
# (~$2.8k) — with these the model lands on the paper's 4.3x/11.3x averages.
CPU_SERVER = DeviceModel("xeon-6242-2s", 8000.0, 250.0, "32 cores, 2-socket [12]")
CPU_CORE = DeviceModel("xeon-core", CPU_SERVER.capex_usd / 32, CPU_SERVER.power_w / 32)
SMARTSSD = DeviceModel("smartssd", 2800.0, 25.0, "NVMe U.2 FPGA+SSD [59]")
A100 = DeviceModel("a100", 10000.0, 250.0, "[52]")
U280 = DeviceModel("u280", 7500.0, 225.0, "[67]")
# TPU-adaptation entry: a v5e chip slice amortized per preprocessing shard.
TPU_V5E_SHARD = DeviceModel("v5e-shard", 4500.0, 200.0, "per-chip, list-ish")

DEVICES = {d.name: d for d in (CPU_SERVER, CPU_CORE, SMARTSSD, A100, U280, TPU_V5E_SHARD)}


def opex_usd(power_w: float, hours: float = HOURS_3Y) -> float:
    return power_w / 1000.0 * hours * ELECTRICITY_USD_PER_KWH


def tco_usd(device: DeviceModel, units: int, hours: float = HOURS_3Y) -> float:
    return units * (device.capex_usd + opex_usd(device.power_w, hours))


def cost_efficiency(
    throughput: float, device: DeviceModel, units: int, hours: float = HOURS_3Y
) -> float:
    """throughput x duration / (CapEx + OpEx); throughput in samples/s."""
    return throughput * hours * 3600.0 / tco_usd(device, units, hours)


def energy_kwh(device: DeviceModel, units: int, hours: float = HOURS_3Y) -> float:
    return units * device.power_w / 1000.0 * hours


def energy_efficiency(
    throughput: float, device: DeviceModel, units: int, hours: float = HOURS_3Y
) -> float:
    """samples per joule (throughput/W), the Fig. 15(a) metric."""
    return throughput / max(units * device.power_w, 1e-9)


# ---------------------------------------------------------------------------
# Per-column-family placement (hybrid mode)


@dataclasses.dataclass(frozen=True)
class PlacementCostModel:
    """Bytes-moved vs compute roofline constants for one deployment.

    Defaults sketch a SmartSSD-class ISP unit behind a 25 Gb/s effective
    link to CPU preprocessing servers; they are deliberately round numbers —
    the *shape* of the decision (decode-heavy -> ISP, search-heavy -> host)
    is what the tests pin down, not the constants.
    """

    link_bytes_per_s: float = 3e9  # host hop: NIC, per direction
    isp_stream_bytes_per_s: float = 8e9  # SSD->FPGA internal stream
    isp_ops_per_s: float = 5e9  # ISP unit compute roofline
    host_ops_per_s: float = 100e9  # one provisioned CPU worker
    # fixed per-kernel-launch overhead (dispatch + program setup), the cost
    # a megabatched launch amortizes over its K partitions
    launch_overhead_s: float = 2e-4

    def megabatch_launch_s(self, per_partition_s: float, k: int) -> float:
        """Modeled seconds for ONE megabatched launch of K partitions."""
        return self.launch_overhead_s + max(k, 1) * per_partition_s

    def megabatch_amortization(self, per_partition_s: float, k: int) -> float:
        """Modeled speedup of one K-megabatch over K solo launches.

        K solo launches pay K overheads; the megabatch pays one.  This is
        the dispatch-amortization half of the zero-stall produce path (the
        other half, read/compute overlap, turns ``io + compute`` into
        ``max(io, compute)`` and is benched, not modeled)."""
        k = max(k, 1)
        solo = k * (self.launch_overhead_s + per_partition_s)
        return solo / self.megabatch_launch_s(per_partition_s, k)

    def predicted_megabatch_k(
        self,
        per_partition_s: float,
        k_max: int,
        *,
        rel_tolerance: float = 0.05,
        candidates=None,
    ) -> int:
        """The modeled optimum the online tuner seeds from: the smallest K
        (among ``candidates``, default 1..k_max) whose per-partition launch
        cost is within ``rel_tolerance`` of the best achievable — the knee
        of the ``megabatch_amortization`` curve.  Measured hill-climbing
        (``core.autotune.MegabatchTuner``) owns the final say; this just
        starts it near the right rung so convergence is cheap."""
        ks = sorted(
            {int(k) for k in (candidates or range(1, max(1, int(k_max)) + 1)) if int(k) >= 1}
        )
        if not ks:
            return 1
        if per_partition_s <= 0.0:
            return ks[-1]  # overhead-only: the biggest amortization wins
        cost = {k: self.megabatch_launch_s(per_partition_s, k) / k for k in ks}
        best = min(cost.values())
        for k in ks:
            if cost[k] <= best * (1.0 + rel_tolerance):
                return k
        return ks[-1]


DEFAULT_PLACEMENT_MODEL = PlacementCostModel()

# abstract op weights (ops per produced value) per operator kind; bucketize
# is a binary search so its weight is log2 of the boundary-table size.
_DECODE_OPS = 1.0
_LOGNORM_OPS = 2.0
_SIGRIDHASH_OPS = 8.0
_GATHER_OPS = 0.5  # dedup expand: one indexed copy per logical value


def family_compute_ops(spec: TransformSpec, rows: int) -> Dict[str, float]:
    """Abstract compute ops per family for one partition of `rows`.

    Dedup datasets (``cfg.dup_factor > 1``) decode + hash each shared sparse
    block ONCE (``rows / dup_factor`` unique rows) and pay a cheap gather op
    per logical value to expand back — the RecD savings axis the planner and
    router price through these numbers.
    """
    cfg = spec.cfg
    d = max(int(getattr(cfg, "dup_factor", 1)), 1)
    u = rows // d
    bucket_ops = math.log2(max(cfg.bucket_size, 2))
    sparse_ops = u * cfg.n_sparse * cfg.max_sparse_len * (
        _DECODE_OPS + _SIGRIDHASH_OPS
    )
    length_ops = u * cfg.n_sparse * _DECODE_OPS
    if d > 1:  # gather-expand to logical rows inside the program
        sparse_ops += rows * cfg.n_sparse * cfg.max_sparse_len * _GATHER_OPS
        length_ops += rows * cfg.n_sparse * _GATHER_OPS
    return {
        "dense": rows * cfg.n_dense * (_DECODE_OPS + _LOGNORM_OPS),
        "sparse": sparse_ops,
        "gen": rows * cfg.n_generated
        * (_DECODE_OPS + bucket_ops + _SIGRIDHASH_OPS),
        "lengths": length_ops,
        "labels": rows * _DECODE_OPS,
    }


def placement_costs(
    spec: TransformSpec,
    rows: Optional[int] = None,
    model: PlacementCostModel = DEFAULT_PLACEMENT_MODEL,
) -> Dict[str, Dict[str, float]]:
    """Per family: modeled seconds under each placement ({family: {isp, host}})."""
    rows = rows or spec.cfg.rows_per_partition
    page_b = opgraph.family_page_bytes(spec, rows)
    out_b = opgraph.family_batch_bytes(spec, rows)
    ops = family_compute_ops(spec, rows)
    costs = {}
    for fam in opgraph.FAMILIES:
        isp = max(
            page_b[fam] / model.isp_stream_bytes_per_s,
            ops[fam] / model.isp_ops_per_s,
        )
        host = (page_b[fam] + out_b[fam]) / model.link_bytes_per_s + (
            ops[fam] / model.host_ops_per_s
        )
        costs[fam] = {"isp": isp, "host": host}
    return costs


def choose_placement(
    spec: TransformSpec,
    rows: Optional[int] = None,
    model: PlacementCostModel = DEFAULT_PLACEMENT_MODEL,
) -> Dict[str, str]:
    """The hybrid placement: each family goes wherever it finishes first."""
    return {
        fam: min(c, key=c.get) for fam, c in placement_costs(spec, rows, model).items()
    }


# ---------------------------------------------------------------------------
# Contention-aware routing (device-aware scheduling)


@dataclasses.dataclass(frozen=True)
class PartitionCosts:
    """Whole-partition cost summary for one Transform: the inputs the
    device-aware router and the device ledgers need, precomputed once per
    session instead of per claim."""

    isp_s: float  # modeled seconds on an idle ISP unit (all families)
    host_s: float  # modeled seconds via the host path (link + host compute)
    ops: float  # abstract Transform ops (charged to whoever computes)
    page_bytes: int  # encoded pages (host path: moved over the link, in)
    batch_bytes: int  # train-ready tensors (host path: moved back, out)

    @property
    def link_bytes(self) -> int:
        """Copy-in/copy-out traffic of one host-fallback produce."""
        return self.page_bytes + self.batch_bytes


def partition_costs(
    spec: TransformSpec,
    rows: Optional[int] = None,
    model: PlacementCostModel = DEFAULT_PLACEMENT_MODEL,
) -> PartitionCosts:
    """Aggregate ``placement_costs`` over every family of one partition."""
    rows = rows or spec.cfg.rows_per_partition
    per_family = placement_costs(spec, rows, model)
    page_b = opgraph.family_page_bytes(spec, rows)
    out_b = opgraph.family_batch_bytes(spec, rows)
    ops = family_compute_ops(spec, rows)
    return PartitionCosts(
        isp_s=sum(c["isp"] for c in per_family.values()),
        host_s=sum(c["host"] for c in per_family.values()),
        ops=sum(ops.values()),
        page_bytes=int(sum(page_b.values())),
        batch_bytes=int(sum(out_b.values())),
    )


@dataclasses.dataclass(frozen=True)
class ContentionAwareCostModel(PlacementCostModel):
    """``PlacementCostModel`` that prices queue wait, not just bytes.

    The static model compares an IDLE ISP unit against the host path; at
    fleet scale the owning device is rarely idle — partition popularity is
    heavily skewed (Meta's ingestion characterization), so the live queue
    depth of the device is part of the price.  A claim arriving at a device
    with ``q`` partitions already bound waits ~``q`` service times before
    its own, so the contended ISP cost is ``(1+q) * isp_s``; the claim is
    offloaded to the host exactly when ``q`` reaches ``queue_threshold`` or
    more AND the contended ISP price exceeds the host price.  Below the
    threshold locality always wins (the whole point of in-storage
    preprocessing), so host fallback can never fire on an idle fleet.
    """

    queue_threshold: int = 4  # bound claims AHEAD before fallback may fire

    def queue_wait_s(self, isp_s: float, queue_depth: int) -> float:
        """Modeled wait behind `queue_depth` earlier claims of ~equal cost."""
        return max(queue_depth, 0) * isp_s

    def contended_isp_s(self, isp_s: float, queue_depth: int) -> float:
        return isp_s + self.queue_wait_s(isp_s, queue_depth)

    def should_offload(
        self, costs: Optional[PartitionCosts], queue_depth: int
    ) -> bool:
        """The dynamic routing decision, fed by live occupancy."""
        if queue_depth < self.queue_threshold:
            return False
        if costs is None or costs.isp_s <= 0.0:
            return True  # cost-less work (test hooks): threshold alone rules
        return self.contended_isp_s(costs.isp_s, queue_depth) > costs.host_s


DEFAULT_CONTENTION_MODEL = ContentionAwareCostModel()


@dataclasses.dataclass
class Comparison:
    """PreSto vs Disagg for one RM model at matched throughput T."""

    rm: str
    T: float  # matched preprocessing throughput (samples/s)
    cpu_cores: int
    isp_units: int

    def summary(self) -> dict:
        cpu_servers = -(-self.cpu_cores // 32)  # servers of 32 cores
        disagg_tco = tco_usd(CPU_SERVER, cpu_servers)
        presto_tco = tco_usd(SMARTSSD, self.isp_units)
        disagg_e = energy_kwh(CPU_SERVER, cpu_servers)
        presto_e = energy_kwh(SMARTSSD, self.isp_units)
        return {
            "rm": self.rm,
            "cpu_servers": cpu_servers,
            "isp_units": self.isp_units,
            "disagg_tco_usd": disagg_tco,
            "presto_tco_usd": presto_tco,
            "cost_efficiency_gain": disagg_tco / presto_tco,
            "disagg_energy_kwh": disagg_e,
            "presto_energy_kwh": presto_e,
            "energy_efficiency_gain": (self.T / (cpu_servers * CPU_SERVER.power_w))
            and (cpu_servers * CPU_SERVER.power_w) / (self.isp_units * SMARTSSD.power_w),
        }
