"""Online megabatch-K autotuning for the zero-stall produce path.

The zero-stall pipeline made the produce hot path megabatched (K claims,
one kernel dispatch) and double-buffered, but K itself stayed a static
per-``JobSpec`` knob.  The right K is workload-dependent: the dispatch
overhead a megabatch amortizes is fixed, while the per-partition produce
time moves with the operator mix, partition geometry, and device
contention — so a K hand-picked on one shape reintroduces launch stalls
(K too small) or delivery latency and staging bulk (K too large) on
another.  Meta's production preprocessing service (DPP) re-tunes itself
continuously for exactly this reason; this module is that loop for the
simulated ISP pool.

``MegabatchTuner`` makes the choice online and *measured*:

* It is seeded from the cost model's predicted optimum
  (``core.costmodel.PlacementCostModel.predicted_megabatch_k`` — the knee
  of the modeled ``megabatch_amortization`` curve), so the first launches
  already run near the right rung.
* Every launch reports its overlap-corrected wall seconds (the same
  ``produce_time_s`` share accounting the pipelined worker loop records);
  the tuner hill-climbs the measured per-partition cost ``launch_s / K``
  over a power-of-two ladder — one rung at a time, ``min_samples``
  launches per rung, moving only on a strict relative improvement — and
  provably stops moving: exploration visits each rung at most once, and
  improvement moves are hard-capped by ``max_moves``.
* K values are restricted to the ladder so the jit shape cache compiles
  O(log K_max) megabatch shapes, not one per arbitrary K.

``core.service.Session`` owns one tuner per autotuned session and feeds
the chosen K back into the planner's per-worker P estimate
(``Session._on_tuned_k_changed``): a K move re-bases P from the new
rung's measured cost and re-plans the pool — the same lazy re-plan
trigger the feature-cache hit-rate discount uses — so unit shares
re-balance as K converges.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

__all__ = ["DEFAULT_AUTOTUNE_KMAX", "MegabatchTuner", "k_ladder"]

DEFAULT_AUTOTUNE_KMAX = 8  # K cap when a JobSpec enables autotune without one


def k_ladder(k_max: int) -> List[int]:
    """Power-of-two megabatch candidates in [1, k_max].

    Every rung is a distinct compiled (K, rows) shape; a power-of-two
    ladder bounds the jit shape cache at O(log K_max) megabatch programs
    while keeping neighboring rungs a constant factor apart (so "within
    one step of the best static K" is a meaningful convergence bound).
    """
    k_max = max(1, int(k_max))
    ks = [1]
    while ks[-1] * 2 <= k_max:
        ks.append(ks[-1] * 2)
    return ks


@dataclasses.dataclass
class _Arm:
    """Measured state of one ladder rung."""

    cost_s: Optional[float] = None  # EMA per-partition seconds at this K
    samples: int = 0


class MegabatchTuner:
    """Hill-climbs megabatch K from measured per-launch seconds.

    Thread-safe (pool workers of one session record concurrently).  The
    proposal ``k`` is the K the session should coalesce for its NEXT
    launch; ``record(k, launch_s)`` feeds one finished launch back and
    returns True when the proposal moved (the session then re-bases its
    planner P estimate).  Launches whose actual K is off the proposal —
    tail chunks, backpressure truncations — still update that rung's EMA
    when it exists but never advance the climb, so partial chunks cannot
    steer the tuner off measured ground.
    """

    def __init__(
        self,
        k_max: int = DEFAULT_AUTOTUNE_KMAX,
        *,
        per_partition_s: Optional[float] = None,
        cost_model=None,
        min_samples: int = 2,
        rel_tolerance: float = 0.05,
        ema: float = 0.5,
        max_moves: Optional[int] = None,
    ):
        self.ladder = k_ladder(k_max)
        self.min_samples = max(1, int(min_samples))
        self.rel_tolerance = float(rel_tolerance)
        self.ema = float(ema)
        self._arms: Dict[int, _Arm] = {k: _Arm() for k in self.ladder}
        self._lock = threading.Lock()
        self._moves = 0
        self._max_moves = (
            2 * len(self.ladder) if max_moves is None else max(0, int(max_moves))
        )
        self._converged = len(self.ladder) == 1
        seed = 1
        if per_partition_s is not None and per_partition_s > 0:
            if cost_model is None:
                from repro.core.costmodel import DEFAULT_PLACEMENT_MODEL

                cost_model = DEFAULT_PLACEMENT_MODEL
            seed = cost_model.predicted_megabatch_k(
                per_partition_s,
                self.ladder[-1],
                rel_tolerance=self.rel_tolerance,
                candidates=self.ladder,
            )
        self.seeded_k = seed if seed in self.ladder else 1
        self._idx = self.ladder.index(self.seeded_k)

    @property
    def k(self) -> int:
        """The K the session should coalesce for its next launch."""
        with self._lock:
            return self.ladder[self._idx]

    @property
    def converged(self) -> bool:
        with self._lock:
            return self._converged

    @property
    def moves(self) -> int:
        with self._lock:
            return self._moves

    def arm_cost(self, k: int) -> Optional[float]:
        """Measured EMA per-partition seconds at rung `k`, or None."""
        with self._lock:
            arm = self._arms.get(int(k))
            return arm.cost_s if arm is not None and arm.samples else None

    def record(self, k: int, launch_s: float) -> bool:
        """Feed one finished launch of `k` partitions taking `launch_s`
        overlap-corrected seconds.  Returns True when the proposal K
        changed (explore step or improvement move); after convergence the
        proposal never changes again, only EMAs keep tracking."""
        k = int(k)
        if k <= 0 or launch_s <= 0.0:
            return False
        with self._lock:
            arm = self._arms.get(k)
            if arm is None:
                return False  # off-ladder partial chunk: no rung to credit
            cost = launch_s / k
            arm.cost_s = (
                cost
                if arm.cost_s is None
                else self.ema * arm.cost_s + (1.0 - self.ema) * cost
            )
            arm.samples += 1
            if self._converged:
                return False
            if k != self.ladder[self._idx]:
                return False  # partial/foreign launch never advances the climb
            if arm.samples < self.min_samples:
                return False
            return self._advance()

    def _advance(self) -> bool:
        """One climb step, current rung fully measured.  Caller holds the
        lock.  Order of play: (1) a measured neighbor strictly better than
        the current rung (beyond the tolerance) wins an improvement move;
        (2) otherwise the current rung is locally best among measured
        rungs, so explore an unmeasured neighbor — uphill first, because
        the modeled amortization curve improves with K until it plateaus;
        (3) nothing left to try: converge, permanently."""
        n = len(self.ladder)

        def cost(j: int) -> float:
            return self._arms[self.ladder[j]].cost_s

        def measured(j: int) -> bool:
            return 0 <= j < n and self._arms[self.ladder[j]].samples >= self.min_samples

        best = self._idx
        for j in (self._idx - 1, self._idx + 1):
            if measured(j) and cost(j) < cost(best) * (1.0 - self.rel_tolerance):
                best = j
        if best != self._idx:
            if self._moves >= self._max_moves:
                self._converged = True  # oscillation backstop: freeze here
                return False
            self._moves += 1
            self._idx = best
            return True
        for j in (self._idx + 1, self._idx - 1):
            if 0 <= j < n and not measured(j):
                self._idx = j
                return True
        self._converged = True
        return False

    def restore(self, state: dict) -> None:
        """Re-seed from a checkpointed ``summary()`` dict (the control
        plane's session resume path): measured per-rung EMAs, the move
        count, convergence, and the proposal rung all carry over, so a
        resumed session of the same Transform starts at its converged K
        instead of re-climbing.  Off-ladder rungs in the snapshot (a
        different ``k_max``) are ignored; JSON round-trips stringify arm
        keys, so keys are coerced back to ints."""
        with self._lock:
            for key, arm in (state.get("arms") or {}).items():
                k = int(key)
                ours = self._arms.get(k)
                if ours is None or not arm.get("samples"):
                    continue
                ours.cost_s = arm.get("cost_s")
                ours.samples = int(arm["samples"])
            k = int(state.get("k", self.ladder[self._idx]))
            if k in self.ladder:
                self._idx = self.ladder.index(k)
            self._moves = int(state.get("moves", self._moves))
            self._converged = (
                bool(state.get("converged", self._converged))
                or len(self.ladder) == 1
            )

    def summary(self) -> dict:
        """Point-in-time view for stats tables and bench artifacts."""
        with self._lock:
            return {
                "k": self.ladder[self._idx],
                "seeded_k": self.seeded_k,
                "converged": self._converged,
                "moves": self._moves,
                "arms": {
                    k: {"cost_s": a.cost_s, "samples": a.samples}
                    for k, a in self._arms.items()
                    if a.samples
                },
            }
