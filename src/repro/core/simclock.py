"""Virtual-time discrete-event engine + SLO-aware multi-tenant simulation.

The contention model (``core.costmodel.ContentionAwareCostModel``) prices
queue depth statically, but a wall-clock bench can never exhibit the
thousand-tenant contention regimes Meta's DSI characterization identifies as
the production bottleneck: real threads cannot be 1000 tenants, and real
sleeps make every race nondeterministic.  This module makes the existing
ledgers busy *in time* instead:

* ``VirtualClock`` / ``SimEngine`` — a classic discrete-event core: an event
  heap ordered by ``(time, seq)`` (seq breaks ties deterministically, so two
  events at the same modeled instant always run in schedule order), a clock
  that jumps from event to event, and no real sleeps anywhere.  A
  1000-session schedule is just tens of thousands of heap pops — wall-clock
  seconds.
* ``SimService`` — the virtual-time twin of
  ``core.service.PreprocessingService``, run over the REAL building blocks:
  claims come from ``data.loader.WorkQueue`` (with the virtual clock
  injected, so straggler re-issue is deterministic), device occupancy is the
  REAL ``data.storage.IspDevice``/``DeviceFleet`` ledgers via their
  ``reserve``/``reserve_host`` virtual-time API, routing prices through the
  same ``ContentionAwareCostModel.should_offload``, and admission/allocation
  is ``core.planner.plan_pool_slo`` (QoS tiers, reject/degrade-instead-of-
  starve, release-candidate preemption) or a FIFO baseline that admits
  everything and starves the tail.  Every decision lands in a
  ``core.ctrlplane.EventLog`` stamped with the VIRTUAL instant — same seed,
  byte-identical trace.
* ``SimHarness`` — the deterministic-simulation test fixture: seeded
  scenario -> report + trace bytes; replaying the seed must reproduce the
  trace byte for byte, which is what the FoundationDB-style tests diff.
  Worker kill/join at modeled instants re-issues in-flight claims through
  the same straggler path the threaded service uses — the previously
  wall-clock-only chaos drills run deterministically here.
* ``zipf_sessions`` — the workload generator: hundreds-to-thousands of
  Zipf-skewed sessions (a few huge jobs, a long tail of small ones), seeded
  arrivals, a release-candidate fraction, and per-session deadlines.

``bench_throughput --sim --sessions N`` drives this end to end and reports
per-QoS-class SLO attainment, modeled makespan, and starvation counts for
the SLO policy against the FIFO baseline.
"""

from __future__ import annotations

import dataclasses
import heapq
import json
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.costmodel import ContentionAwareCostModel, PartitionCosts
from repro.core.ctrlplane import EventLog
from repro.core.planner import (
    QOS_EXPLORATORY,
    QOS_RANK,
    QOS_RELEASE_CANDIDATE,
    DeviceTopology,
    SloRequest,
    plan_pool_slo,
)
from repro.data.loader import WorkQueue
from repro.data.storage import DeviceFleet

__all__ = [
    "SimEngine",
    "SimHarness",
    "SimJob",
    "SimReport",
    "SimService",
    "VirtualClock",
    "synthetic_costs",
    "zipf_sessions",
]


# -- the discrete-event core ---------------------------------------------------


class VirtualClock:
    """Modeled time: a float that only the event loop advances.

    ``now`` is a bound-method time source, drop-in wherever the wall-clock
    paths take a ``clock`` callable (``WorkQueue``, ``EventLog``)."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        if t < self._now:
            raise ValueError(f"virtual time cannot rewind: {t} < {self._now}")
        self._now = float(t)

    def sleep(self, dt: float) -> None:
        """Advance modeled time by ``dt`` — the drop-in ``sleep`` callable
        for virtual-clock-aware paths (``IoFaultInjector`` slow reads, the
        claim path's fault-retry backoff), so a simulated run models fault
        latency without ever blocking a real thread."""
        if dt > 0:
            self._now += float(dt)


class SimEngine:
    """Event-heap scheduler over a ``VirtualClock``.

    Events are ``(time, seq, fn)``: the monotone ``seq`` makes same-instant
    events pop in schedule order, so the whole run is a pure function of the
    schedule — the determinism every replay test leans on.  ``rng`` is the
    run's single seeded generator; anything random (workload shapes, chaos
    schedules) must draw from it and only it.
    """

    def __init__(self, seed: int = 0):
        self.clock = VirtualClock()
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = 0
        self.processed = 0

    @property
    def now(self) -> float:
        return self.clock.now()

    def at(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` for virtual instant ``t`` (>= now)."""
        if t < self.now:
            raise ValueError(f"cannot schedule into the past: {t} < {self.now}")
        heapq.heappush(self._heap, (float(t), self._seq, fn))
        self._seq += 1

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.now + max(dt, 0.0), fn)

    def step(self) -> bool:
        """Run the earliest event; False when the heap is empty."""
        if not self._heap:
            return False
        t, _, fn = heapq.heappop(self._heap)
        self.clock.advance_to(t)
        self.processed += 1
        fn()
        return True

    def run(self, until: Optional[float] = None) -> int:
        """Drain the heap (optionally stopping past ``until``); returns the
        number of events processed by this call."""
        n0 = self.processed
        while self._heap:
            if until is not None and self._heap[0][0] > until:
                break
            self.step()
        return self.processed - n0


# -- workload ------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SimJob:
    """One simulated tenant: a partition count plus its SLO contract."""

    name: str
    partitions: int
    arrival_s: float = 0.0
    qos_class: str = QOS_EXPLORATORY
    deadline_s: Optional[float] = None  # relative to arrival
    demand_units: Optional[int] = None  # explicit ceil(T/P); default: size-derived

    @property
    def rank(self) -> int:
        return QOS_RANK.get(self.qos_class, max(QOS_RANK.values()) + 1)


def synthetic_costs(
    model: ContentionAwareCostModel,
    *,
    page_bytes: int = 48 << 20,
    batch_bytes: int = 16 << 20,
    ops: float = 2e7,
    spec=None,
    rows: Optional[int] = None,
) -> PartitionCosts:
    """Self-consistent per-partition costs at the model's modeled rates —
    the byte-bound RecSys regime where in-storage wins: pages stream at the
    device's internal rate instead of crossing the 3 GB/s link.

    Pass ``spec`` (a ``core.spec.TransformSpec``, optionally with ``rows``)
    to CALIBRATE the sim against the real cost model instead of the round
    default constants: the returned costs are ``costmodel.partition_costs``
    for that Transform — including the dedup-aware unique-bytes/ops pricing
    (``RMDataConfig.dup_factor``) — so modeled sim makespans track what the
    threaded service's ledgers would charge for the same partitions.
    """
    if spec is not None:
        from repro.core.costmodel import partition_costs  # lazy: no cycle

        return partition_costs(spec, rows, model)
    isp_s = page_bytes / model.isp_stream_bytes_per_s + ops / model.isp_ops_per_s
    host_s = (page_bytes + batch_bytes) / model.link_bytes_per_s + ops / model.host_ops_per_s
    return PartitionCosts(
        isp_s=isp_s, host_s=host_s, ops=ops,
        page_bytes=page_bytes, batch_bytes=batch_bytes,
    )


def zipf_sessions(
    n: int,
    *,
    rng: np.random.Generator,
    alpha: float = 1.3,
    max_partitions: int = 64,
    rc_fraction: float = 0.1,
    arrival_window_s: float = 60.0,
    per_partition_s: float = 0.011,
    deadline_slack: float = 6.0,
    rc_deadline_slack: float = 4.0,
) -> List[SimJob]:
    """Generate ``n`` Zipf-skewed sessions: a few huge jobs, a long tail of
    small ones (Meta's session-size skew), seeded arrivals over a window, a
    ``rc_fraction`` of release candidates, and per-session deadlines scaled
    to each job's ideal single-unit service time (release candidates get the
    tighter slack — they are the tier the SLO report watches)."""
    sizes = np.minimum(rng.zipf(alpha, size=n), max_partitions).astype(int)
    arrivals = np.sort(rng.uniform(0.0, arrival_window_s, size=n))
    is_rc = rng.random(n) < rc_fraction
    jobs = []
    for i in range(n):
        size = max(1, int(sizes[i]))
        rc = bool(is_rc[i])
        slack = rc_deadline_slack if rc else deadline_slack
        jobs.append(
            SimJob(
                name=f"s{i:05d}",
                partitions=size,
                arrival_s=float(arrivals[i]),
                qos_class=QOS_RELEASE_CANDIDATE if rc else QOS_EXPLORATORY,
                deadline_s=max(slack * size * per_partition_s, 1.0),
                demand_units=min(4, size),
            )
        )
    return jobs


# -- outcomes ------------------------------------------------------------------


@dataclasses.dataclass
class JobOutcome:
    """The sim's verdict on one job — explicit, never silent starvation."""

    name: str
    qos_class: str
    partitions: int
    arrival_s: float
    deadline_s: Optional[float]
    status: str  # "admitted" | "degraded" | "rejected"
    granted_units: int = 0
    finish_s: Optional[float] = None
    reissues: int = 0
    host_fallbacks: int = 0

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s

    @property
    def slo_met(self) -> Optional[bool]:
        """None for rejected jobs (they have no completion to score)."""
        if self.status == "rejected":
            return None
        if self.deadline_s is None:
            return True
        lat = self.latency_s
        return lat is not None and lat <= self.deadline_s

    def starved(self, factor: float = 10.0) -> bool:
        """An ADMITTED job that blew past ``factor`` x its deadline (or
        never finished) was starved — the outcome SLO-aware admission
        converts into an up-front reject/degrade."""
        if self.status == "rejected":
            return False
        if self.finish_s is None:
            return True
        if self.deadline_s is None:
            return False
        return self.latency_s > factor * self.deadline_s

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "qos_class": self.qos_class,
            "partitions": self.partitions,
            "arrival_s": self.arrival_s,
            "deadline_s": self.deadline_s,
            "status": self.status,
            "granted_units": self.granted_units,
            "finish_s": self.finish_s,
            "latency_s": self.latency_s,
            "slo_met": self.slo_met,
            "reissues": self.reissues,
            "host_fallbacks": self.host_fallbacks,
        }


@dataclasses.dataclass
class SimReport:
    """Whole-schedule summary: per-class SLO attainment + modeled makespan."""

    policy: str
    seed: int
    outcomes: List[JobOutcome]
    makespan_s: float
    events_processed: int
    device_utilization: List[Dict[str, float]]
    host_busy_s: float
    starvation_factor: float = 10.0

    def by_class(self) -> Dict[str, Dict[str, Any]]:
        out: Dict[str, Dict[str, Any]] = {}
        for cls in sorted({o.qos_class for o in self.outcomes}):
            jobs = [o for o in self.outcomes if o.qos_class == cls]
            scored = [o for o in jobs if o.slo_met is not None]
            met = sum(1 for o in scored if o.slo_met)
            lats = sorted(
                o.latency_s for o in jobs if o.latency_s is not None
            )
            out[cls] = {
                "jobs": len(jobs),
                "admitted": sum(1 for o in jobs if o.status == "admitted"),
                "degraded": sum(1 for o in jobs if o.status == "degraded"),
                "rejected": sum(1 for o in jobs if o.status == "rejected"),
                "starved": sum(
                    1 for o in jobs if o.starved(self.starvation_factor)
                ),
                "slo_attainment": met / len(scored) if scored else 1.0,
                "p50_latency_s": lats[len(lats) // 2] if lats else None,
                "p99_latency_s": (
                    lats[min(len(lats) - 1, int(0.99 * len(lats)))] if lats else None
                ),
            }
        return out

    @property
    def starved_count(self) -> int:
        return sum(1 for o in self.outcomes if o.starved(self.starvation_factor))

    def to_dict(self) -> Dict[str, Any]:
        return {
            "policy": self.policy,
            "seed": self.seed,
            "sessions": len(self.outcomes),
            "makespan_s": self.makespan_s,
            "events_processed": self.events_processed,
            "starved": self.starved_count,
            "by_class": self.by_class(),
            "host_busy_s": self.host_busy_s,
            "devices": self.device_utilization,
        }


# -- the virtual-time service --------------------------------------------------


class _SimWorker:
    """One pool unit bound to a device, busy between modeled instants."""

    __slots__ = ("wid", "device", "alive", "busy", "task_seq")

    def __init__(self, wid: int, device: int):
        self.wid = wid
        self.device = device
        self.alive = True
        self.busy = False
        self.task_seq = 0  # bumps per assignment: stale completions drop


class _SimSession:
    """Virtual-time session state: a real WorkQueue + SLO bookkeeping."""

    def __init__(
        self,
        job: SimJob,
        *,
        clock: Callable[[], float],
        owner_of: Callable[[int], int],
        fallback_ok: Callable[["_SimSession", int], bool],
        on_reissue: Callable[[int], None],
        straggler_timeout: float,
    ):
        self.job = job
        self.name = job.name
        self.owner_of = owner_of
        self.work = WorkQueue(
            range(job.partitions),
            straggler_timeout,
            owner_of=owner_of,
            on_reissue=on_reissue,
            clock=clock,
        )
        self._fallback = fallback_ok
        self.share = 0
        self.inflight = 0
        self.status = "admitted"  # live scheduling status (may degrade)
        self.outcome_status = "admitted"  # sticky: degraded once => degraded
        self.delivered = 0
        self.host_fallbacks = 0
        self.finish_s: Optional[float] = None

    def fallback_ok(self, pid: int) -> bool:
        return self._fallback(self, pid)

    @property
    def done(self) -> bool:
        return self.work.exhausted


class SimService:
    """Multi-tenant preprocessing schedule in virtual time — no sleeps.

    The claim/produce path mirrors ``core.service.PreprocessingService``:
    pool units bound round-robin to devices, locality-first claims with
    contention-aware host fallback, straggler re-issue on kill, QoS-tiered
    shares.  Where the threaded service blocks a worker on a real produce,
    the sim reserves the owning device's ledger *in time*
    (``IspDevice.reserve``) and schedules the completion event at the
    modeled instant — so a thousand tenants cost heap pops, not threads.

    ``policy="slo"``: admission via ``core.planner.plan_pool_slo`` —
    reject/degrade instead of starve, release candidates first.
    ``policy="fifo"``: the baseline — everything is admitted and served in
    strict arrival order; under load the tail starves, which is exactly the
    contrast the SLO report quantifies.
    """

    def __init__(
        self,
        engine: SimEngine,
        *,
        num_workers: int = 8,
        num_devices: int = 4,
        host_parallelism: int = 2,
        policy: str = "slo",
        cost_model: Optional[ContentionAwareCostModel] = None,
        costs: Optional[
            "PartitionCosts | Callable[[SimJob, int], PartitionCosts]"
        ] = None,
        owner_of: Optional[Callable[[SimJob, int], int]] = None,
        straggler_timeout: float = 1e9,
        event_capacity: int = 1 << 20,
    ):
        assert policy in ("slo", "fifo"), policy
        self.engine = engine
        self.policy = policy
        self.cost_model = cost_model or ContentionAwareCostModel()
        self.fleet = DeviceFleet.from_cost_model(
            max(1, num_devices), self.cost_model
        )
        self.host_parallelism = max(1, host_parallelism)
        self._costs = costs or synthetic_costs(self.cost_model)
        self._owner_fn = owner_of
        self.straggler_timeout = straggler_timeout
        self.events = EventLog(event_capacity, clock=engine.clock.now)
        self.workers: List[_SimWorker] = [
            _SimWorker(w, w % len(self.fleet)) for w in range(max(1, num_workers))
        ]
        self.sessions: List[_SimSession] = []  # active, arrival order
        self.outcomes: Dict[str, JobOutcome] = {}
        self._job_index: Dict[str, int] = {}
        self._submitted = 0
        # wid -> (session, pid, route, owner) for the claim each busy worker
        # holds: a kill must expire exactly that claim back onto the
        # straggler path, nothing else
        self._held: Dict[int, Tuple[_SimSession, int, str, int]] = {}

    # -- inputs ----------------------------------------------------------------

    def costs_of(self, job: SimJob, pid: int) -> PartitionCosts:
        c = self._costs
        return c(job, pid) if callable(c) else c

    def _owner(self, job: SimJob, pid: int) -> int:
        if self._owner_fn is not None:
            return self._owner_fn(job, pid)
        # default: spread each job's partitions from a job-specific offset,
        # so concurrent tenants don't all hammer device 0 first
        return (self._job_index[job.name] + pid) % len(self.fleet)

    def submit(self, job: SimJob) -> None:
        """Schedule a job's arrival at its virtual instant."""
        self._job_index.setdefault(job.name, self._submitted)
        self._submitted += 1
        self.engine.at(max(job.arrival_s, self.engine.now), lambda: self._arrive(job))

    def submit_all(self, jobs: List[SimJob]) -> None:
        for j in jobs:
            self.submit(j)

    # -- chaos -----------------------------------------------------------------

    def kill_worker_at(self, t: float, wid: int) -> None:
        self.engine.at(t, lambda: self.kill_worker(wid))

    def join_worker_at(self, t: float, device: Optional[int] = None) -> None:
        self.engine.at(t, lambda: self._join(device))

    def kill_worker(self, wid: int) -> None:
        """Kill at the current virtual instant: the worker's in-flight claim
        is force-expired back onto the straggler path (its scheduled
        completion event goes stale via the task_seq bump and is dropped),
        capacity shrinks, and shares re-plan — the same crash drill the
        threaded service runs, now deterministic."""
        w = next((x for x in self.workers if x.wid == wid and x.alive), None)
        if w is None:
            return
        held = self._held.pop(wid, None)
        w.alive = False
        w.task_seq += 1  # in-flight completion (if any) is now stale
        self.events.emit("kill", wid=wid, device=w.device)
        if held is not None:
            sess, pid, route, owner = held
            sess.inflight -= 1
            if route == "isp":
                self.fleet[owner].end_claim()
            if sess.work.expire(pid):
                self.events.emit("claim_expired", job=sess.name, pid=pid)
        self._replan(trigger="kill")
        self._dispatch_idle()

    def _join(self, device: Optional[int]) -> None:
        if device is None:
            counts = {d: 0 for d in range(len(self.fleet))}
            for w in self.workers:
                if w.alive:
                    counts[w.device] += 1
            device = min(counts, key=lambda d: (counts[d], d))
        wid = max((w.wid for w in self.workers), default=-1) + 1
        self.workers.append(_SimWorker(wid, device))
        self.events.emit("join", wid=wid, device=device)
        self._replan(trigger="join")
        self._dispatch_idle()

    # -- admission -------------------------------------------------------------

    @property
    def capacity(self) -> int:
        return sum(1 for w in self.workers if w.alive)

    def _topology(self) -> DeviceTopology:
        upd = {d: 0 for d in range(len(self.fleet))}
        for w in self.workers:
            if w.alive:
                upd[w.device] += 1
        return DeviceTopology(upd)

    def _manned(self) -> set:
        return self._topology().manned

    def _arrive(self, job: SimJob) -> None:
        self.events.emit(
            "job_arrive", job=job.name, qos_class=job.qos_class,
            partitions=job.partitions, deadline_s=job.deadline_s,
        )
        outcome = JobOutcome(
            name=job.name, qos_class=job.qos_class, partitions=job.partitions,
            arrival_s=self.engine.now, deadline_s=job.deadline_s,
            status="admitted",
        )
        self.outcomes[job.name] = outcome
        if self.policy == "slo":
            reqs = [
                SloRequest(s.name, self._demand(s.job), s.job.qos_class,
                           s.job.deadline_s)
                for s in self.sessions
            ]
            reqs.append(
                SloRequest(job.name, self._demand(job), job.qos_class,
                           job.deadline_s)
            )
            _plan, decisions = plan_pool_slo(self.capacity, reqs)
            mine = decisions[job.name]
            if mine.status == "rejected":
                outcome.status = "rejected"
                self.events.emit(
                    "reject", job=job.name, qos_class=job.qos_class,
                    reason=mine.reason,
                )
                return
            outcome.status = mine.status
            outcome.granted_units = mine.granted_units
            self._admit(job)
            self._apply_decisions(decisions, joining=job.name)
        else:
            outcome.granted_units = 1
            self._admit(job)
            self.events.emit("admit", job=job.name, status="admitted", units=1)
        self._dispatch_idle()

    def _demand(self, job: SimJob) -> int:
        if job.demand_units is not None:
            return max(1, int(job.demand_units))
        return max(1, min(4, int(math.ceil(job.partitions / 4))))

    def _admit(self, job: SimJob) -> None:
        sess = _SimSession(
            job,
            clock=self.engine.clock.now,
            owner_of=lambda pid, j=job: self._owner(j, pid),
            fallback_ok=self._fallback_ok,
            on_reissue=lambda pid, name=job.name: self.events.emit(
                "claim_reissue", job=name, pid=pid
            ),
            straggler_timeout=self.straggler_timeout,
        )
        self.sessions.append(sess)
        # bind the job's backlog on the owning devices' ledgers (the live
        # queue-depth signal the contention model prices)
        for pid in range(job.partitions):
            self.fleet[self._owner(job, pid)].enqueue()

    def _apply_decisions(self, decisions, *, joining: Optional[str]) -> None:
        for s in self.sessions:
            d = decisions.get(s.name)
            if d is None:
                continue
            prev = s.status
            if d.status == "rejected" and s.name != joining:
                s.status, s.share = "preempted", 0
                if prev != "preempted":
                    self.events.emit(
                        "preempt", job=s.name, qos_class=s.job.qos_class,
                        by=joining,
                    )
            else:
                s.status, s.share = d.status, d.granted_units
                if d.status == "degraded":
                    out = self.outcomes[s.name]
                    if out.status == "admitted":
                        out.status = "degraded"
            if s.name == joining:
                self.events.emit(
                    "admit", job=s.name, status=d.status,
                    units=d.granted_units, qos_class=s.job.qos_class,
                )

    def _replan(self, *, trigger: str) -> None:
        """Re-run QoS-tiered allocation over the active sessions (a floor
        freed, a worker died/joined) — preempted tenants may regain shares."""
        if self.policy != "slo" or not self.sessions:
            return
        reqs = [
            SloRequest(s.name, self._demand(s.job), s.job.qos_class,
                       s.job.deadline_s)
            for s in self.sessions
        ]
        _plan, decisions = plan_pool_slo(self.capacity, reqs)
        self._apply_decisions(decisions, joining=None)
        self.events.emit(
            "plan", trigger=trigger, capacity=self.capacity,
            sessions=len(self.sessions),
        )

    # -- the claim/produce path ------------------------------------------------

    def _fallback_ok(self, sess: _SimSession, pid: int) -> bool:
        dev = sess.owner_of(pid)
        if dev not in self._manned():
            return True  # unmanned device: host fallback is the only path
        device = self.fleet[dev]
        return self.cost_model.should_offload(
            self.costs_of(sess.job, pid), device.queue_depth
        )

    def _candidates(self) -> List[_SimSession]:
        live = [s for s in self.sessions if not s.done]
        if self.policy == "fifo":
            return live  # arrival order: strict FIFO service
        return sorted(
            live, key=lambda s: (s.job.rank, self._job_index[s.name])
        )

    def _dispatch_idle(self) -> None:
        for w in sorted(self.workers, key=lambda w: w.wid):
            if w.alive and not w.busy:
                self._dispatch(w)

    def _dispatch(self, worker: _SimWorker) -> None:
        """Give one idle worker its next claim; mirrors the threaded pool's
        two passes — share-enforced first, then work-conserving."""
        if not worker.alive or worker.busy:
            return
        candidates = self._candidates()
        passes = (
            (True, False) if self.policy == "slo" else (False,)
        )
        for enforce_share in passes:
            for sess in candidates:
                if enforce_share and sess.inflight >= max(sess.share, 0):
                    continue
                if enforce_share and sess.share <= 0:
                    continue  # preempted: backfill pass only
                claimed = sess.work.claim(
                    prefer_device=worker.device,
                    fallback_ok=sess.fallback_ok,
                )
                if claimed is None:
                    continue
                self._launch(worker, sess, claimed)
                return

    def _launch(self, worker: _SimWorker, sess: _SimSession, pid: int) -> None:
        now = self.engine.now
        job = sess.job
        costs = self.costs_of(job, pid)
        owner = sess.owner_of(pid)
        local = owner == worker.device
        if local:
            route = "isp"
            start, end = self.fleet[owner].reserve(
                now, costs.isp_s, nbytes=costs.page_bytes, ops=costs.ops
            )
            self.fleet[owner].begin_claim()
        else:
            route = "host"
            sess.host_fallbacks += 1
            self.outcomes[sess.name].host_fallbacks += 1
            self.fleet[owner].shed()
            start, end = self.fleet.reserve_host(
                now, costs.host_s, link_bytes=costs.link_bytes,
                ops=costs.ops, parallelism=self.host_parallelism,
            )
        sess.inflight += 1
        worker.busy = True
        worker.task_seq += 1
        seq = worker.task_seq
        self._held[worker.wid] = (sess, pid, route, owner)
        self.events.emit(
            "claim", job=sess.name, pid=pid, wid=worker.wid, route=route,
            start=round(start, 9), end=round(end, 9),
        )
        self.engine.at(
            end, lambda: self._complete(worker, seq, sess, pid, route, owner)
        )

    def _complete(
        self,
        worker: _SimWorker,
        seq: int,
        sess: _SimSession,
        pid: int,
        route: str,
        owner: int,
    ) -> None:
        if worker.task_seq != seq:
            return  # the worker died mid-produce: the result dies with it
        self._held.pop(worker.wid, None)
        worker.busy = False
        sess.inflight -= 1
        if route == "isp":
            self.fleet[owner].end_claim()
        won = sess.work.complete(pid)
        if won:
            sess.delivered += 1
            self.fleet[owner].dequeue()
            self.events.emit(
                "complete", job=sess.name, pid=pid, wid=worker.wid,
                route=route,
            )
        if sess.done and sess.finish_s is None:
            self._finish(sess)
        self._dispatch_idle()

    def _finish(self, sess: _SimSession) -> None:
        now = self.engine.now
        sess.finish_s = now
        out = self.outcomes[sess.name]
        out.finish_s = now
        out.reissues = sess.work.reissues
        self.sessions.remove(sess)
        self.events.emit(
            "job_done", job=sess.name, qos_class=sess.job.qos_class,
            latency_s=round(now - out.arrival_s, 9),
            slo_met=out.slo_met, reissues=out.reissues,
        )
        self._replan(trigger="job_done")

    # -- reports ---------------------------------------------------------------

    def report(self, *, starvation_factor: float = 10.0) -> SimReport:
        makespan = max(
            (o.finish_s for o in self.outcomes.values() if o.finish_s is not None),
            default=0.0,
        )
        return SimReport(
            policy=self.policy,
            seed=self.engine.seed,
            outcomes=[
                self.outcomes[k] for k in sorted(self.outcomes)
            ],
            makespan_s=makespan,
            events_processed=self.engine.processed,
            device_utilization=self.fleet.utilization(),
            host_busy_s=self.fleet.host_busy_s,
            starvation_factor=starvation_factor,
        )

    def trace_bytes(self) -> bytes:
        """The run's full event trace, canonically serialized — two runs of
        the same seeded schedule must produce EQUAL bytes."""
        return json.dumps(
            self.events.to_dicts(), sort_keys=True, separators=(",", ":")
        ).encode()


class SimHarness:
    """Seeded, replayable virtual-time scenario runner (the test fixture).

    Build a harness, submit jobs (or a ``zipf_sessions`` workload), schedule
    chaos (``kill_at``/``join_at``), ``run()`` — everything happens in
    virtual time, and ``trace_bytes()`` is a pure function of the seed and
    the schedule: replaying the same seed MUST produce equal bytes.
    """

    def __init__(self, seed: int = 0, **service_kwargs: Any):
        self.engine = SimEngine(seed=seed)
        self.service = SimService(self.engine, **service_kwargs)

    def submit(self, *jobs: SimJob) -> "SimHarness":
        for j in jobs:
            self.service.submit(j)
        return self

    def workload(self, n: int, **kwargs: Any) -> List[SimJob]:
        jobs = zipf_sessions(n, rng=self.engine.rng, **kwargs)
        self.service.submit_all(jobs)
        return jobs

    def kill_at(self, t: float, wid: int) -> "SimHarness":
        self.engine.at(t, lambda: self.service.kill_worker(wid))
        return self

    def join_at(self, t: float, device: Optional[int] = None) -> "SimHarness":
        self.service.join_worker_at(t, device)
        return self

    def run(self, until: Optional[float] = None) -> SimReport:
        self.engine.run(until)
        return self.service.report()

    def trace_bytes(self) -> bytes:
        return self.service.trace_bytes()
