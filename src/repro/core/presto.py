"""PreStoEngine: storage-centric vs. disaggregated preprocessing placement.

The paper's two system design points, rendered in SPMD:

* ``presto`` (Fig. 8)   — every mesh shard preprocesses the partition rows it
  already owns; output batch sharding == input page sharding, so the compiled
  program contains **zero collectives** between Extract and Load.

* ``disagg`` (Fig. 7b)  — preprocessing happens on a *different* shard than
  both the storage shard and the consuming trainer shard.  We render the two
  network hops of server disaggregation as explicit ``ppermute``s on the
  ``data`` axis: raw pages hop storage→preprocessor, train-ready tensors hop
  preprocessor→trainer.  Their operand bytes are exactly the paper's
  copy-in/copy-out traffic and are measurable in the compiled HLO
  (see benchmarks/bench_comm.py and EXPERIMENTS.md §Dry-run).

Both modes compose with the training step into ONE jit program
(`repro.train.step.make_train_step_with_ingest`), which is the end-to-end
"online preprocessing feeds training" pipeline of Fig. 1.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.preprocess import (
    MiniBatch,
    pages_from_partition,
    pages_shape_dtypes,
    preprocess_pages,
)
from repro.core.spec import TransformSpec
from repro.data.storage import PartitionedStore


def pages_pspec() -> Dict[str, P]:
    """Row-group axis of every page array is sharded over the data axis."""
    return {
        "dense_words": P(None, "data", None),
        "sparse_words": P(None, "data", None),
        "length_words": P(None, "data", None),
        "label_words": P("data"),
    }


def minibatch_pspec() -> Dict[str, P]:
    return {
        "dense": P("data", None),
        "multi_hot_ids": P("data", None, None),
        "lengths": P("data", None),
        "one_hot_ids": P("data", None),
        "labels": P("data"),
    }


class PreStoEngine:
    """Owns a TransformSpec and compiles the sharded preprocessing program."""

    def __init__(
        self,
        spec: TransformSpec,
        mesh: Optional[Mesh] = None,
        *,
        placement: str = "presto",
        kernel_mode: str = "fused",
        interpret: bool | None = None,
    ):
        assert placement in ("presto", "disagg")
        self.spec = spec
        self.mesh = mesh
        self.placement = placement
        self.kernel_mode = kernel_mode
        self.interpret = interpret

    # -- single-shard (local) path -------------------------------------------
    def preprocess_local(self, pages: Dict[str, jax.Array]) -> MiniBatch:
        return preprocess_pages(
            pages, self.spec, mode=self.kernel_mode, interpret=self.interpret
        )

    # -- sharded global path ---------------------------------------------------
    def preprocess_global(self, pages: Dict[str, jax.Array]) -> MiniBatch:
        """Preprocess a global batch of encoded pages on the mesh.

        In presto placement, the body is pure local compute. In disagg
        placement, pages hop +1 on the data axis before compute and the
        mini-batch hops -1 after, modeling the disaggregated pool's
        copy-in/copy-out (the hops are real collective-permutes in the HLO).
        """
        if self.mesh is None:
            return self.preprocess_local(pages)
        mesh = self.mesh
        data_axis = "data"
        n_data = mesh.shape[data_axis]

        def body(pages):
            if self.placement == "disagg" and n_data > 1:
                perm_in = [(i, (i + 1) % n_data) for i in range(n_data)]
                pages = jax.tree.map(
                    lambda x: jax.lax.ppermute(x, data_axis, perm_in), pages
                )
            mb = self.preprocess_local(pages)
            if self.placement == "disagg" and n_data > 1:
                perm_out = [(i, (i - 1) % n_data) for i in range(n_data)]
                mb = jax.tree.map(
                    lambda x: jax.lax.ppermute(x, data_axis, perm_out), mb
                )
            return mb

        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(pages_pspec(),),
            out_specs=minibatch_pspec(),
            check_vma=False,
        )(pages)

    def jit_preprocess(self):
        """Compiled global preprocessing step with explicit shardings."""
        if self.mesh is None:
            return jax.jit(self.preprocess_local)
        in_sh = {
            k: NamedSharding(self.mesh, v) for k, v in pages_pspec().items()
        }
        out_sh = {
            k: NamedSharding(self.mesh, v) for k, v in minibatch_pspec().items()
        }
        return jax.jit(
            self.preprocess_global, in_shardings=(in_sh,), out_shardings=out_sh
        )

    # -- staging ----------------------------------------------------------------
    def stage_partition(self, store: PartitionedStore, pid: int) -> Dict[str, np.ndarray]:
        """Extract(Read): fetch + lay out one partition's pages (host side)."""
        return pages_from_partition(store.read(pid), self.spec)

    def pages_struct(self, rows: int) -> Dict[str, jax.ShapeDtypeStruct]:
        return pages_shape_dtypes(self.spec, rows)
