"""PreStoEngine: storage-centric vs. disaggregated vs. hybrid placement.

The paper's two system design points, plus the per-family generalization,
rendered in SPMD:

* ``presto`` (Fig. 8)   — every mesh shard preprocesses the partition rows it
  already owns; output batch sharding == input page sharding, so the compiled
  program contains **zero collectives** between Extract and Load.

* ``disagg`` (Fig. 7b)  — preprocessing happens on a *different* shard than
  both the storage shard and the consuming trainer shard.  We render the two
  network hops of server disaggregation as explicit ``ppermute``s on the
  ``data`` axis: raw pages hop storage→preprocessor, train-ready tensors hop
  preprocessor→trainer.  Their operand bytes are exactly the paper's
  copy-in/copy-out traffic and are measurable in the compiled HLO
  (see benchmarks/bench_comm.py and EXPERIMENTS.md §Dry-run).

* ``hybrid``            — per-column-family placement chosen by the cost
  model (``core.costmodel.choose_placement``) or passed explicitly: ISP
  families run the fused kernels locally (zero collectives); host families
  run the multi-pass kernels behind the two disagg hops — but only THEIR
  pages and outputs ride the permutes, so the HLO's collective bytes are
  exactly the host-placed families' traffic.

All placements execute the same operator graph (``core.opgraph``) — the
engine only decides per-family lowering (fused vs multi-pass) and which
family's traffic hops.  Both compose with the training step into ONE jit
program (`repro.train.step.make_train_step_with_ingest`), the end-to-end
"online preprocessing feeds training" pipeline of Fig. 1.
"""

from __future__ import annotations

import hashlib
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map
from repro.core.execcache import EXECUTABLES, ExecKey, mesh_key
from repro.core.opgraph import (
    FAMILIES,
    FAMILY_BATCH_KEYS,
    FAMILY_PAGE_VALUES,
    HOST,
    ISP,
    LoweredPlan,
    build_transform_graph,
    lower,
    prepare_env,
    resolve_placements,
)
from repro.core.preprocess import (
    MiniBatch,
    execute_plan,
    flatten_megabatch,
    pages_from_partition,
    pages_shape_dtypes,
    stack_pages,
)
from repro.core.spec import TransformSpec
from repro.data.columnar import inflate_partition
from repro.data.storage import PartitionedStore

PLACEMENTS = ("presto", "disagg", "hybrid")


def pages_pspec() -> Dict[str, P]:
    """Row-group axis of every page array is sharded over the data axis."""
    return {
        "dense_words": P(None, "data", None),
        "sparse_words": P(None, "data", None),
        "length_words": P(None, "data", None),
        "label_words": P("data"),
    }


def minibatch_pspec() -> Dict[str, P]:
    return {
        "dense": P("data", None),
        "multi_hot_ids": P("data", None, None),
        "lengths": P("data", None),
        "one_hot_ids": P("data", None),
        "labels": P("data"),
    }


class PreStoEngine:
    """Owns a TransformSpec and compiles the sharded preprocessing program."""

    def __init__(
        self,
        spec: TransformSpec,
        mesh: Optional[Mesh] = None,
        *,
        placement="presto",
        kernel_mode: Optional[str] = None,
        family_placements: Optional[Dict[str, str]] = None,
        interpret: bool | None = None,
        use_exec_cache: bool = True,
    ):
        if isinstance(placement, dict):
            family_placements, placement = dict(placement), "hybrid"
        assert placement in PLACEMENTS, placement
        self.spec = spec
        self.mesh = mesh
        self.placement = placement
        if placement == "hybrid":
            self.family_placements = resolve_placements(
                family_placements if family_placements is not None else "hybrid",
                spec,
            )
        else:
            uniform = ISP if placement == "presto" else HOST
            self.family_placements = {f: uniform for f in FAMILIES}
        # kernel_mode: "fused"/"unfused" force the kernel lowering regardless
        # of comm placement (presto/disagg historically both defaulted to the
        # fused kernels); None follows the family placements.
        self.kernel_mode = kernel_mode
        self.interpret = interpret
        # use_exec_cache=False opts out of the process-wide executable
        # registry (core.execcache): this engine then compiles privately,
        # exactly the pre-registry behavior (bench baseline / isolation).
        self.use_exec_cache = use_exec_cache
        self._plan: Optional[LoweredPlan] = None
        self._jit_cached = None
        self._jit_mega = None
        self._jit_rest = None
        self._jit_lock = threading.Lock()
        # Donating the page buffers lets XLA reuse their memory for outputs.
        # Only meaningful where the runtime honors donation (not the CPU
        # backend, which warns and ignores) and only safe for the produce
        # paths, which stage FRESH pages per call and never reuse them.
        self._donate = jax.default_backend() in ("gpu", "tpu")

    @property
    def lowered_plan(self) -> LoweredPlan:
        """The shared opgraph lowering every execution path runs through."""
        if self._plan is None:
            if self.kernel_mode is not None:
                kernel_placements = resolve_placements(self.kernel_mode, self.spec)
            elif self.placement == "disagg":
                # seed-compatible default: disagg moves the batch but still
                # runs the fused kernels on the preprocessing shard
                kernel_placements = resolve_placements("fused", self.spec)
            else:
                kernel_placements = self.family_placements
            self._plan = lower(
                build_transform_graph(self.spec),
                self.spec,
                kernel_placements,
                interpret=self.interpret,
            )
        return self._plan

    def host_families(self) -> tuple[str, ...]:
        return tuple(f for f in FAMILIES if self.family_placements[f] == HOST)

    def cache_signature(self) -> str:
        """Stable identity of this engine's Transform for feature-cache keys.

        Combines the lowered plan's structural hash (spec parameters + kernel
        placements + stage wiring) with the per-family comm placement (which
        families' traffic hops), so two engines that produce bitwise-equal
        batches for equal inputs — even engines built independently from an
        equal spec — share cache entries, and any placement that changes
        batch routing keys separately.  The engine-level placement *mode*
        string is deliberately NOT hashed here: it rides as ``CacheKey``'s
        third component (``core.service.JobSpec.cache_key_fn``)."""
        h = hashlib.sha256()
        h.update(self.lowered_plan.structural_hash().encode())
        h.update(json.dumps(sorted(self.family_placements.items())).encode())
        return h.hexdigest()[:16]

    def route_costs(self, rows: Optional[int] = None, model=None):
        """Whole-partition cost summary for the device-aware claim router.

        One ``costmodel.PartitionCosts`` per (engine, rows): modeled seconds
        on an idle ISP unit vs the host path, plus the ops and link bytes the
        device/host ledgers charge per produce.  Routing consumes these — it
        never changes the produced bytes."""
        from repro.core.costmodel import (  # local: costmodel is downstream
            DEFAULT_PLACEMENT_MODEL,
            partition_costs,
        )

        return partition_costs(
            self.spec, rows, model if model is not None else DEFAULT_PLACEMENT_MODEL
        )

    # -- single-shard (local) path -------------------------------------------
    def preprocess_local(self, pages: Dict[str, jax.Array]) -> MiniBatch:
        # dedup-staged pages (carrying ``sparse_refs``) run the sparse chain
        # at unique-block geometry and gather-expand inside the program —
        # bitwise identical to classic pages (preprocess.execute_plan)
        return execute_plan(self.lowered_plan, pages)

    # -- sharded global path ---------------------------------------------------
    def preprocess_global(self, pages: Dict[str, jax.Array]) -> MiniBatch:
        """Preprocess a global batch of encoded pages on the mesh.

        ISP-placed families are pure local compute.  Host-placed families'
        pages hop +1 on the data axis before compute and their mini-batch
        keys hop -1 after, modeling the disaggregated pool's copy-in/copy-out
        (the hops are real collective-permutes in the HLO).  ``presto`` = no
        host families (zero collectives); ``disagg`` = all host families.
        """
        if self.mesh is None:
            return self.preprocess_local(pages)
        mesh = self.mesh
        data_axis = "data"
        n_data = mesh.shape[data_axis]
        host_fams = self.host_families()
        plan = self.lowered_plan

        def body(pages):
            env = prepare_env(pages, self.spec)
            if host_fams and n_data > 1:
                perm_in = [(i, (i + 1) % n_data) for i in range(n_data)]
                # when dense pages hop anyway, gen's source planes are
                # recomputed from them on the far side instead of hopped —
                # disagg then moves exactly the seed's four page arrays
                skip_gen = "gen" in host_fams and "dense" in host_fams
                for fam in host_fams:
                    if fam == "gen" and skip_gen:
                        continue
                    for k in FAMILY_PAGE_VALUES[fam]:
                        env[k] = jax.lax.ppermute(env[k], data_axis, perm_in)
                if skip_gen:
                    src = jnp.asarray(
                        np.asarray(self.spec.generated_source, np.int32)
                    )
                    env["gen_words"] = jnp.take(env["dense_words"], src, axis=0)
            mb = plan.execute_env(env)
            if host_fams and n_data > 1:
                perm_out = [(i, (i - 1) % n_data) for i in range(n_data)]
                for fam in host_fams:
                    for k in FAMILY_BATCH_KEYS[fam]:
                        mb[k] = jax.lax.ppermute(mb[k], data_axis, perm_out)
            return mb

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(pages_pspec(),),
            out_specs=minibatch_pspec(),
            check_vma=False,
        )(pages)

    def jit_preprocess(self):
        """Compiled global preprocessing step with explicit shardings."""
        if self.mesh is None:
            return jax.jit(self.preprocess_local)
        in_sh = {
            k: NamedSharding(self.mesh, v) for k, v in pages_pspec().items()
        }
        out_sh = {
            k: NamedSharding(self.mesh, v) for k, v in minibatch_pspec().items()
        }
        return jax.jit(
            self.preprocess_global, in_shardings=(in_sh,), out_shardings=out_sh
        )

    def _exec_key(self, mode: str) -> ExecKey:
        # interpret changes the compiled program (interpreted vs native
        # Pallas), not the batch bytes — it keys the executable, never the
        # feature cache
        return ExecKey(
            signature=self.cache_signature(),
            mode=mode,
            mesh=mesh_key(self.mesh),
            interpret=self.interpret,
        )

    def _build_executable(self, mode: str, key: ExecKey):
        """jit wrapper for one execution mode, with trace accounting.

        The traced body notes each (re)compile in the process-wide registry
        — jit re-enters Python only when tracing, so the note count IS the
        compile count the discipline tests pin.  Page buffers are donated on
        backends that honor donation (the produce paths stage fresh pages
        every call and never reuse them).
        """
        if mode == "mega":
            inner = self.preprocess_megabatch

            def body(stacked):
                k = stacked["label_words"].shape[0]
                EXECUTABLES.note_trace(
                    key, k=int(k), rows=int(stacked["label_words"].shape[1])
                )
                return inner(stacked)

            return jax.jit(body, donate_argnums=(0,) if self._donate else ())
        if self.mesh is None:

            def body(pages):
                EXECUTABLES.note_trace(
                    key, k=1, rows=int(pages["label_words"].shape[0])
                )
                return self.preprocess_local(pages)

            return jax.jit(body, donate_argnums=(0,) if self._donate else ())
        in_sh = {k: NamedSharding(self.mesh, v) for k, v in pages_pspec().items()}
        out_sh = {
            k: NamedSharding(self.mesh, v) for k, v in minibatch_pspec().items()
        }

        def body(pages):
            EXECUTABLES.note_trace(
                key, k=1, rows=int(pages["label_words"].shape[0])
            )
            return self.preprocess_global(pages)

        return jax.jit(body, in_shardings=(in_sh,), out_shardings=out_sh)

    def jit_preprocess_cached(self):
        """The compiled preprocessing step, shared process-wide.

        Sessions, provisioning probes, and pool workers all reuse the same
        compiled program, so a job's service-fed batches are bitwise
        identical to its single-tenant batches.  The executable is resolved
        through ``core.execcache.EXECUTABLES``: independently built engines
        with equal cache signatures (the multi-tenant norm) share ONE
        compile instead of one per engine, and concurrent cold first calls
        collapse to a single trace.  Locked per engine: concurrent first use
        must not resolve two registry entries.

        On donating backends (gpu/tpu) the page argument is DONATED: do not
        reuse the arrays you pass in after the call — stage fresh pages per
        call (the produce paths do) or pass a private ``jax.device_put``
        copy.
        """
        with self._jit_lock:
            if self._jit_cached is None:
                key = self._exec_key("solo")
                if self.use_exec_cache:
                    self._jit_cached = EXECUTABLES.get_or_build(
                        key, lambda: self._build_executable("solo", key)
                    )
                else:
                    self._jit_cached = self._build_executable("solo", key)
        return self._jit_cached

    # -- megabatched execution --------------------------------------------------

    def preprocess_megabatch(self, stacked: Dict[str, jax.Array]):
        """Transform a leading-axis megabatch of K partitions in ONE launch.

        ``stacked`` is ``preprocess.stack_pages`` output: every page array
        with a leading K axis.  The leading axis folds into the row-group
        axis (every Transform operator is row-local — asserted against
        ``kernels.ROW_LOCAL_KINDS``), the whole plan executes once at K x
        rows, and the fused mini-batch ``jnp.split``s back into K
        per-partition mini-batches, bitwise identical to K solo runs.
        Traceable; mesh-less engines only (the pool-worker produce path).
        """
        assert self.mesh is None, "megabatching is a local (per-unit) launch"
        k = int(stacked["label_words"].shape[0])
        assert k == 1 or self.lowered_plan.megabatch_safe(), (
            "lowered plan has a non-row-local stage; megabatch would not be "
            "bitwise identical to solo runs"
        )
        mb = self.preprocess_local(flatten_megabatch(stacked))
        if k == 1:
            return (mb,)
        split = {key: jnp.split(v, k, axis=0) for key, v in mb.items()}
        return tuple({key: split[key][i] for key in mb} for i in range(k))

    def jit_preprocess_megabatch_cached(self):
        """Compiled megabatch launch, shared process-wide like the solo one.

        One registry entry per engine signature; megabatch width K and rows
        specialize inside it through jit's shape cache (static shapes — each
        (K, rows) compiles once per process, then every engine and worker
        reuses it).
        """
        with self._jit_lock:
            if self._jit_mega is None:
                key = self._exec_key("mega")
                if self.use_exec_cache:
                    self._jit_mega = EXECUTABLES.get_or_build(
                        key, lambda: self._build_executable("mega", key)
                    )
                else:
                    self._jit_mega = self._build_executable("mega", key)
        return self._jit_mega

    # -- staging ----------------------------------------------------------------
    def stage_partition(self, store: PartitionedStore, pid: int) -> Dict[str, np.ndarray]:
        """Extract(Read): fetch + lay out one partition's pages (host side).

        Meshed engines shard pages along the row-group axis
        (``pages_pspec``), which a dedup partition's unique-geometry pages
        would break — those inflate (``columnar.inflate_partition``, bitwise
        faithful) to the classic per-sample layout first.  The I/O ledger
        still charges only the UNIQUE bytes (``store.read`` streams the
        stored form; inflation is host-side decompression after the read).
        """
        part = store.read(pid)
        if self.mesh is not None:
            part = inflate_partition(part)
        return pages_from_partition(part, self.spec)

    def stage_megabatch(
        self, store: PartitionedStore, pids: Sequence[int]
    ) -> Dict[str, np.ndarray]:
        """Extract(Read) K partitions and stack their pages leading-axis.

        Reads go through ``store.read`` one partition at a time, so every
        partition's bytes are charged to its OWNING device's ledger — a
        megabatch never blurs per-device accounting.
        """
        return stack_pages(self.stage_partition(store, pid) for pid in pids)

    def _put_pages(self, pages):
        """Host pages -> device, donation-aware.

        On donating backends the pages are placed once and their buffers
        donated to the launch (no host round-trip copy survives the call);
        elsewhere the numpy arrays go straight into jit, which performs the
        single unavoidable host->device transfer itself — the old explicit
        ``tree.map(jnp.asarray, ...)`` pre-copy layer is gone.
        """
        return jax.device_put(pages) if self._donate else pages

    def produce_batch(self, store: PartitionedStore, pid: int) -> MiniBatch:
        """Extract + Transform one partition into a device-ready mini-batch.

        The unit of work one preprocessing worker performs (pool-shared or
        private); deterministic in (store, pid), which is what makes
        straggler re-issue and duplicate-drop safe.
        """
        pages = self._put_pages(self.stage_partition(store, pid))
        mb = self.jit_preprocess_cached()(pages)
        jax.block_until_ready(mb)
        return mb

    def produce_batches(
        self, store: PartitionedStore, pids: Sequence[int]
    ) -> List[MiniBatch]:
        """Extract + Transform K partitions with ONE megabatched launch.

        Returns the K mini-batches in `pids` order, bitwise identical to K
        ``produce_batch`` calls — the whole point is paying one kernel
        dispatch (and one compile, amortized process-wide) instead of K.
        Falls back to the solo path on meshed engines (megabatching is a
        per-unit local launch) and on plans with a non-row-local stage
        (where stacking rows would not be bitwise-safe).
        """
        pids = list(pids)
        if (
            len(pids) == 1
            or self.mesh is not None
            or not self.lowered_plan.megabatch_safe()
        ):
            return [self.produce_batch(store, pid) for pid in pids]
        stacked = self._put_pages(self.stage_megabatch(store, pids))
        batches = self.jit_preprocess_megabatch_cached()(stacked)
        jax.block_until_ready(batches)
        return list(batches)

    def produce_stream(
        self,
        store: PartitionedStore,
        pids: Iterable[int],
        *,
        megabatch: int = 1,
        overlap: bool = True,
        lookahead: int = 1,
    ) -> Iterator[Tuple[int, MiniBatch]]:
        """The zero-stall produce loop: megabatched launches, double-buffered.

        Yields ``(pid, mini-batch)`` in `pids` order.  Partitions are
        grouped into megabatches of up to ``megabatch`` and each group runs
        as one launch; with ``overlap`` the NEXT group's partition read and
        numpy page-build run on a staging thread while the current group's
        kernel executes (jax dispatch is async), and ``block_until_ready``
        happens only at delivery — per-partition cost tends to
        ``max(io, compute)`` instead of ``io + compute``.  Batches are
        bitwise identical to serial ``produce_batch`` calls either way —
        plans with a non-row-local stage degrade to K=1 (overlap only).

        ``lookahead`` is the staging window depth: how many chunks may be
        staged (read + page-built) ahead of the chunk whose kernel is in
        flight.  1 is the classic double buffer; deeper windows keep reads
        flowing while delivery (the consumer's side of ``yield``) stalls
        the dispatch loop, at the price of holding up to ``lookahead``
        chunks of pages in memory — the service path
        (``core.service.Session``) adds a byte budget on top
        (``JobSpec.stage_budget_bytes``); this raw loop does not.
        """
        pids = list(pids)
        k = max(1, int(megabatch))
        if k > 1 and not self.lowered_plan.megabatch_safe():
            k = 1
        chunks = [pids[i : i + k] for i in range(0, len(pids), k)]
        if not chunks:
            return
        assert self.mesh is None, "produce_stream is a per-unit local loop"
        lookahead = max(1, int(lookahead))

        def dispatch(stacked):
            """Launch one staged chunk without blocking on the result."""
            return self.jit_preprocess_megabatch_cached()(
                self._put_pages(stacked)
            )

        if not overlap:
            for chunk in chunks:
                batches = dispatch(self.stage_megabatch(store, chunk))
                jax.block_until_ready(batches)
                yield from zip(chunk, batches)
            return
        with ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="presto-stage"
        ) as stager:
            pending: List = []  # staged-chunk futures, window of `lookahead`
            nxt = 0

            def top_up() -> None:
                nonlocal nxt
                while len(pending) < lookahead and nxt < len(chunks):
                    pending.append(
                        stager.submit(self.stage_megabatch, store, chunks[nxt])
                    )
                    nxt += 1

            top_up()
            for chunk in chunks:
                batches = dispatch(pending.pop(0).result())
                top_up()  # refill behind the in-flight kernel
                for pid, mb in zip(chunk, batches):
                    jax.block_until_ready(mb)  # block only at delivery
                    yield pid, mb

    def pages_struct(self, rows: int) -> Dict[str, jax.ShapeDtypeStruct]:
        return pages_shape_dtypes(self.spec, rows)

    # -- block-granularity cache hooks (dedup datasets) -------------------------
    #
    # A dedup partition's train-ready sparse content is fully determined by
    # its unique blocks: rows sharing a block have identical multi_hot_ids /
    # lengths slices.  ``extract_blocks`` pulls those per-block slices out of
    # a produced batch (publish side) and ``assemble_from_blocks`` rebuilds a
    # full batch from cached blocks plus the partial "rest" program over the
    # per-sample families (dense/gen/labels) — so overlapping tenants reuse
    # hashed sparse blocks across partitions and datasets
    # (``core.featcache.BlockKey``), bitwise identical to cold compute.

    def _preprocess_rest(self, pages: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Partial Transform: every family EXCEPT sparse/lengths (traceable)."""
        plan = self.lowered_plan
        env = prepare_env(pages, self.spec)
        for st in plan.stages:
            if st.family in ("sparse", "lengths") or st.name == "form_batch":
                continue
            vals = st.fn(*(env[k] for k in st.inputs))
            env.update(zip(st.outputs, vals))
        # exactly form_batch's assembly expressions for these keys
        return {
            "dense": env["dense_norm"].T,
            "one_hot_ids": env["gen_hashed"].T,
            "labels": env["labels_f32"],
        }

    def jit_preprocess_rest_cached(self):
        """Compiled rest-program (dense/gen/labels), shared process-wide."""
        with self._jit_lock:
            if self._jit_rest is None:
                key = self._exec_key("rest")
                build = lambda: jax.jit(self._preprocess_rest)
                if self.use_exec_cache:
                    self._jit_rest = EXECUTABLES.get_or_build(key, build)
                else:
                    self._jit_rest = build()
        return self._jit_rest

    @staticmethod
    def extract_blocks(
        batch: MiniBatch, refs: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-unique-block hashed sparse content of a produced batch.

        Returns ``(ids (u, S, L) i32, lens (u, S) i32)`` — block b's slice is
        any row r with ``refs[r] == b`` (they are identical by construction;
        the first occurrence is taken).
        """
        refs = np.asarray(refs)
        _, first = np.unique(refs, return_index=True)
        ids = np.asarray(batch["multi_hot_ids"])[first]
        lens = np.asarray(batch["lengths"])[first]
        return ids, lens

    def assemble_from_blocks(
        self,
        pages: Dict[str, np.ndarray],
        block_ids: np.ndarray,
        block_lens: np.ndarray,
    ) -> MiniBatch:
        """Full batch from cached sparse blocks + the rest program.

        ``pages`` is dedup-staged (``stage_partition``) output; only its
        dense/label pages feed the compiled rest program — the sparse pages'
        decode+hash work is what the block cache saved.  Bitwise identical
        to a cold produce of the same partition.
        """
        refs = np.asarray(pages["sparse_refs"], dtype=np.int64)
        rest_pages = {
            "dense_words": pages["dense_words"],
            "label_words": pages["label_words"],
        }
        batch = dict(self.jit_preprocess_rest_cached()(rest_pages))
        batch["multi_hot_ids"] = jnp.asarray(np.asarray(block_ids)[refs])
        batch["lengths"] = jnp.asarray(np.asarray(block_lens)[refs])
        return batch
