"""PreStoEngine: storage-centric vs. disaggregated vs. hybrid placement.

The paper's two system design points, plus the per-family generalization,
rendered in SPMD:

* ``presto`` (Fig. 8)   — every mesh shard preprocesses the partition rows it
  already owns; output batch sharding == input page sharding, so the compiled
  program contains **zero collectives** between Extract and Load.

* ``disagg`` (Fig. 7b)  — preprocessing happens on a *different* shard than
  both the storage shard and the consuming trainer shard.  We render the two
  network hops of server disaggregation as explicit ``ppermute``s on the
  ``data`` axis: raw pages hop storage→preprocessor, train-ready tensors hop
  preprocessor→trainer.  Their operand bytes are exactly the paper's
  copy-in/copy-out traffic and are measurable in the compiled HLO
  (see benchmarks/bench_comm.py and EXPERIMENTS.md §Dry-run).

* ``hybrid``            — per-column-family placement chosen by the cost
  model (``core.costmodel.choose_placement``) or passed explicitly: ISP
  families run the fused kernels locally (zero collectives); host families
  run the multi-pass kernels behind the two disagg hops — but only THEIR
  pages and outputs ride the permutes, so the HLO's collective bytes are
  exactly the host-placed families' traffic.

All placements execute the same operator graph (``core.opgraph``) — the
engine only decides per-family lowering (fused vs multi-pass) and which
family's traffic hops.  Both compose with the training step into ONE jit
program (`repro.train.step.make_train_step_with_ingest`), the end-to-end
"online preprocessing feeds training" pipeline of Fig. 1.
"""

from __future__ import annotations

import hashlib
import json
import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map
from repro.core.opgraph import (
    FAMILIES,
    FAMILY_BATCH_KEYS,
    FAMILY_PAGE_VALUES,
    HOST,
    ISP,
    LoweredPlan,
    build_transform_graph,
    lower,
    prepare_env,
    resolve_placements,
)
from repro.core.preprocess import (
    MiniBatch,
    pages_from_partition,
    pages_shape_dtypes,
)
from repro.core.spec import TransformSpec
from repro.data.storage import PartitionedStore

PLACEMENTS = ("presto", "disagg", "hybrid")


def pages_pspec() -> Dict[str, P]:
    """Row-group axis of every page array is sharded over the data axis."""
    return {
        "dense_words": P(None, "data", None),
        "sparse_words": P(None, "data", None),
        "length_words": P(None, "data", None),
        "label_words": P("data"),
    }


def minibatch_pspec() -> Dict[str, P]:
    return {
        "dense": P("data", None),
        "multi_hot_ids": P("data", None, None),
        "lengths": P("data", None),
        "one_hot_ids": P("data", None),
        "labels": P("data"),
    }


class PreStoEngine:
    """Owns a TransformSpec and compiles the sharded preprocessing program."""

    def __init__(
        self,
        spec: TransformSpec,
        mesh: Optional[Mesh] = None,
        *,
        placement="presto",
        kernel_mode: Optional[str] = None,
        family_placements: Optional[Dict[str, str]] = None,
        interpret: bool | None = None,
    ):
        if isinstance(placement, dict):
            family_placements, placement = dict(placement), "hybrid"
        assert placement in PLACEMENTS, placement
        self.spec = spec
        self.mesh = mesh
        self.placement = placement
        if placement == "hybrid":
            self.family_placements = resolve_placements(
                family_placements if family_placements is not None else "hybrid",
                spec,
            )
        else:
            uniform = ISP if placement == "presto" else HOST
            self.family_placements = {f: uniform for f in FAMILIES}
        # kernel_mode: "fused"/"unfused" force the kernel lowering regardless
        # of comm placement (presto/disagg historically both defaulted to the
        # fused kernels); None follows the family placements.
        self.kernel_mode = kernel_mode
        self.interpret = interpret
        self._plan: Optional[LoweredPlan] = None
        self._jit_cached = None
        self._jit_lock = threading.Lock()

    @property
    def lowered_plan(self) -> LoweredPlan:
        """The shared opgraph lowering every execution path runs through."""
        if self._plan is None:
            if self.kernel_mode is not None:
                kernel_placements = resolve_placements(self.kernel_mode, self.spec)
            elif self.placement == "disagg":
                # seed-compatible default: disagg moves the batch but still
                # runs the fused kernels on the preprocessing shard
                kernel_placements = resolve_placements("fused", self.spec)
            else:
                kernel_placements = self.family_placements
            self._plan = lower(
                build_transform_graph(self.spec),
                self.spec,
                kernel_placements,
                interpret=self.interpret,
            )
        return self._plan

    def host_families(self) -> tuple[str, ...]:
        return tuple(f for f in FAMILIES if self.family_placements[f] == HOST)

    def cache_signature(self) -> str:
        """Stable identity of this engine's Transform for feature-cache keys.

        Combines the lowered plan's structural hash (spec parameters + kernel
        placements + stage wiring) with the per-family comm placement (which
        families' traffic hops), so two engines that produce bitwise-equal
        batches for equal inputs — even engines built independently from an
        equal spec — share cache entries, and any placement that changes
        batch routing keys separately.  The engine-level placement *mode*
        string is deliberately NOT hashed here: it rides as ``CacheKey``'s
        third component (``core.service.JobSpec.cache_key_fn``)."""
        h = hashlib.sha256()
        h.update(self.lowered_plan.structural_hash().encode())
        h.update(json.dumps(sorted(self.family_placements.items())).encode())
        return h.hexdigest()[:16]

    def route_costs(self, rows: Optional[int] = None, model=None):
        """Whole-partition cost summary for the device-aware claim router.

        One ``costmodel.PartitionCosts`` per (engine, rows): modeled seconds
        on an idle ISP unit vs the host path, plus the ops and link bytes the
        device/host ledgers charge per produce.  Routing consumes these — it
        never changes the produced bytes."""
        from repro.core.costmodel import (  # local: costmodel is downstream
            DEFAULT_PLACEMENT_MODEL,
            partition_costs,
        )

        return partition_costs(
            self.spec, rows, model if model is not None else DEFAULT_PLACEMENT_MODEL
        )

    # -- single-shard (local) path -------------------------------------------
    def preprocess_local(self, pages: Dict[str, jax.Array]) -> MiniBatch:
        return self.lowered_plan.execute(pages)

    # -- sharded global path ---------------------------------------------------
    def preprocess_global(self, pages: Dict[str, jax.Array]) -> MiniBatch:
        """Preprocess a global batch of encoded pages on the mesh.

        ISP-placed families are pure local compute.  Host-placed families'
        pages hop +1 on the data axis before compute and their mini-batch
        keys hop -1 after, modeling the disaggregated pool's copy-in/copy-out
        (the hops are real collective-permutes in the HLO).  ``presto`` = no
        host families (zero collectives); ``disagg`` = all host families.
        """
        if self.mesh is None:
            return self.preprocess_local(pages)
        mesh = self.mesh
        data_axis = "data"
        n_data = mesh.shape[data_axis]
        host_fams = self.host_families()
        plan = self.lowered_plan

        def body(pages):
            env = prepare_env(pages, self.spec)
            if host_fams and n_data > 1:
                perm_in = [(i, (i + 1) % n_data) for i in range(n_data)]
                # when dense pages hop anyway, gen's source planes are
                # recomputed from them on the far side instead of hopped —
                # disagg then moves exactly the seed's four page arrays
                skip_gen = "gen" in host_fams and "dense" in host_fams
                for fam in host_fams:
                    if fam == "gen" and skip_gen:
                        continue
                    for k in FAMILY_PAGE_VALUES[fam]:
                        env[k] = jax.lax.ppermute(env[k], data_axis, perm_in)
                if skip_gen:
                    src = jnp.asarray(
                        np.asarray(self.spec.generated_source, np.int32)
                    )
                    env["gen_words"] = jnp.take(env["dense_words"], src, axis=0)
            mb = plan.execute_env(env)
            if host_fams and n_data > 1:
                perm_out = [(i, (i - 1) % n_data) for i in range(n_data)]
                for fam in host_fams:
                    for k in FAMILY_BATCH_KEYS[fam]:
                        mb[k] = jax.lax.ppermute(mb[k], data_axis, perm_out)
            return mb

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(pages_pspec(),),
            out_specs=minibatch_pspec(),
            check_vma=False,
        )(pages)

    def jit_preprocess(self):
        """Compiled global preprocessing step with explicit shardings."""
        if self.mesh is None:
            return jax.jit(self.preprocess_local)
        in_sh = {
            k: NamedSharding(self.mesh, v) for k, v in pages_pspec().items()
        }
        out_sh = {
            k: NamedSharding(self.mesh, v) for k, v in minibatch_pspec().items()
        }
        return jax.jit(
            self.preprocess_global, in_shardings=(in_sh,), out_shardings=out_sh
        )

    def jit_preprocess_cached(self):
        """The compiled preprocessing step, built once per engine.

        Sessions, provisioning probes, and pool workers all reuse the same
        compiled program, so a job's service-fed batches are bitwise
        identical to its single-tenant batches.  Locked: concurrent first
        use by pool workers must not build two jit wrappers (two compiles).
        """
        with self._jit_lock:
            if self._jit_cached is None:
                self._jit_cached = self.jit_preprocess()
        return self._jit_cached

    # -- staging ----------------------------------------------------------------
    def stage_partition(self, store: PartitionedStore, pid: int) -> Dict[str, np.ndarray]:
        """Extract(Read): fetch + lay out one partition's pages (host side)."""
        return pages_from_partition(store.read(pid), self.spec)

    def produce_batch(self, store: PartitionedStore, pid: int) -> MiniBatch:
        """Extract + Transform one partition into a device-ready mini-batch.

        The unit of work one preprocessing worker performs (pool-shared or
        private); deterministic in (store, pid), which is what makes
        straggler re-issue and duplicate-drop safe.
        """
        pages = self.stage_partition(store, pid)
        pages = jax.tree.map(jnp.asarray, pages)
        mb = self.jit_preprocess_cached()(pages)
        jax.block_until_ready(mb)
        return mb

    def pages_struct(self, rows: int) -> Dict[str, jax.ShapeDtypeStruct]:
        return pages_shape_dtypes(self.spec, rows)
