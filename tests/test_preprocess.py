"""Transform graph: fused vs unfused equivalence, oracle agreement, stages."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.preprocess import (
    pages_from_partition,
    pages_shape_dtypes,
    preprocess_pages,
    stage_functions,
)
from repro.core.spec import TransformSpec
from repro.data.synth import RMDataConfig, SyntheticRecSysSource
from repro.kernels import ref


@pytest.fixture(scope="module")
def small_rm():
    cfg = RMDataConfig("t", 4, 3, 4, 8, 2, 32, 1 << 16, 1024, rows_per_partition=256)
    src = SyntheticRecSysSource(cfg, rows=256)
    return src, TransformSpec.from_source(src)


def _pages(src, spec, pid=0):
    return {k: jnp.asarray(v) for k, v in
            pages_from_partition(src.partition(pid), spec).items()}


def test_fused_equals_unfused(small_rm):
    src, spec = small_rm
    pages = _pages(src, spec)
    a = preprocess_pages(pages, spec, mode="fused")
    b = preprocess_pages(pages, spec, mode="unfused")
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]), err_msg=k)


def test_preprocess_matches_raw_oracle(small_rm):
    src, spec = small_rm
    raw = src.raw(1)
    mb = preprocess_pages(_pages(src, spec, 1), spec)
    np.testing.assert_allclose(
        np.asarray(mb["dense"]), np.log1p(np.maximum(raw.dense, 0)), atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(mb["lengths"]), raw.sparse_lengths)
    np.testing.assert_allclose(np.asarray(mb["labels"]), raw.labels)
    # multi-hot = sigridhash(raw ids); generated = sigridhash(digitize(dense))
    s0 = np.asarray(ref.sigridhash(jnp.asarray(raw.sparse_values[:, 0]),
                                   int(spec.sparse_seeds[0]), int(spec.sparse_max[0])))
    np.testing.assert_array_equal(np.asarray(mb["multi_hot_ids"][:, 0]), s0)
    b0 = np.digitize(raw.dense[:, spec.generated_source[0]], spec.bucket_boundaries[0])
    g0 = np.asarray(ref.sigridhash(jnp.asarray(b0.astype(np.int32)),
                                   int(spec.gen_seeds[0]), int(spec.gen_max[0])))
    np.testing.assert_array_equal(np.asarray(mb["one_hot_ids"][:, 0]), g0)


def test_stage_functions_compose(small_rm):
    src, spec = small_rm
    pages = _pages(src, spec)
    stages = stage_functions(spec)
    dense_raw, sparse_raw = stages["extract_decode"](pages)
    bucket_ids = stages["gen_bucketize"](dense_raw)
    hashed, gen_hashed = stages["norm_sigridhash"](sparse_raw, bucket_ids)
    dense_norm = stages["norm_log"](dense_raw)
    mb = stages["form_minibatch"](pages, dense_norm, hashed, gen_hashed)
    direct = preprocess_pages(pages, spec)
    for k in direct:
        np.testing.assert_array_equal(np.asarray(mb[k]), np.asarray(direct[k]), k)


def test_pages_shape_dtypes_match(small_rm):
    src, spec = small_rm
    pages = _pages(src, spec)
    struct = pages_shape_dtypes(spec, 256)
    assert set(struct) == set(pages)
    for k in pages:
        assert tuple(struct[k].shape) == tuple(pages[k].shape), k
        assert struct[k].dtype == pages[k].dtype, k


def test_preprocess_jit_once(small_rm):
    """One compiled program serves every partition (static schema)."""
    src, spec = small_rm
    fn = jax.jit(lambda p: preprocess_pages(p, spec))
    fn(_pages(src, spec, 0))
    n0 = fn._cache_size()
    fn(_pages(src, spec, 1))
    assert fn._cache_size() == n0
