"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import encoding as enc
from repro.kernels import ops, ref

_settings = settings(max_examples=25, deadline=None)


@_settings
@given(
    vals=st.lists(st.floats(-1e6, 1e6, width=32), min_size=1, max_size=200),
    bounds=st.lists(st.floats(-1e6, 1e6, width=32), min_size=1, max_size=64),
)
def test_bucketize_bounds_and_monotonicity(vals, bounds):
    v = np.array(vals, np.float32)[None]
    b = np.sort(np.array(bounds, np.float32))[None]
    out = np.asarray(ops.bucketize(v, b))[0]
    m = b.shape[1]
    assert out.min() >= 0 and out.max() <= m  # ids within [0, m]
    # monotonicity: larger value -> >= bucket id
    order = np.argsort(v[0], kind="stable")
    assert (np.diff(out[order]) >= 0).all()


@_settings
@given(
    ids=st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200),
    seed=st.integers(0, 2**32 - 1),
    d=st.integers(1, 2**31 - 1),
)
def test_sigridhash_range_determinism(ids, seed, d):
    v = np.array(ids, np.int32)[None]
    a = np.asarray(ops.sigridhash(v, [seed], [d]))[0]
    b = np.asarray(ref.sigridhash(jnp.asarray(v[0]), seed, d))
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < d


@_settings
@given(
    data=st.data(),
    width=st.integers(1, 32),
)
def test_bitpack_roundtrip(data, width):
    n = data.draw(st.integers(1, 300))
    vals = data.draw(
        st.lists(st.integers(0, 2**width - 1), min_size=n, max_size=n)
    )
    v = np.array(vals, np.uint64)
    packed = enc.bitpack(v, width)
    out = enc.bitunpack(packed, n, width)
    np.testing.assert_array_equal(out, v.astype(np.uint32))


@_settings
@given(vals=st.lists(st.floats(width=32, allow_nan=False), min_size=1, max_size=300))
def test_bytesplit_roundtrip(vals):
    v = np.array(vals, np.float32)
    words, n = enc.bytesplit_encode(v)
    np.testing.assert_array_equal(enc.bytesplit_decode(words, n), v)


@_settings
@given(
    rows=st.integers(1, 64),
    lens=st.data(),
)
def test_lengths_mask_invariant(rows, lens):
    """Lengths decoded from a partition always bound the padded ids."""
    from repro.data.synth import RMDataConfig, SyntheticRecSysSource

    cfg = RMDataConfig("t", 2, 3, 4, 8, 1, 16, 1 << 12, 256, rows_per_partition=rows)
    src = SyntheticRecSysSource(cfg, rows=rows)
    raw = src.raw(lens.draw(st.integers(0, 5)))
    assert (raw.sparse_lengths >= 1).all()
    assert (raw.sparse_lengths <= cfg.max_sparse_len).all()
    mask = np.arange(cfg.max_sparse_len)[None, None] >= raw.sparse_lengths[..., None]
    assert (np.where(mask, raw.sparse_values, 0) == 0).all()
