"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import encoding as enc
from repro.kernels import ops, ref
from workqueue_model import TIMEOUT, apply_ops

# Pinned profile: bounded example count, NO per-example deadline (jit
# compilation on first call would trip any wall-clock budget), derandomized
# so CI failures replay exactly.  requirements-dev.txt carries hypothesis,
# so every CI job runs these for real — the importorskip above only fires
# on bare local installs (where test_data.py's seeded driver still covers
# the WorkQueue invariants).
settings.register_profile(
    "presto", max_examples=25, deadline=None, derandomize=True)
settings.load_profile("presto")
_settings = settings(max_examples=25, deadline=None)


@_settings
@given(
    vals=st.lists(st.floats(-1e6, 1e6, width=32), min_size=1, max_size=200),
    bounds=st.lists(st.floats(-1e6, 1e6, width=32), min_size=1, max_size=64),
)
def test_bucketize_bounds_and_monotonicity(vals, bounds):
    v = np.array(vals, np.float32)[None]
    b = np.sort(np.array(bounds, np.float32))[None]
    out = np.asarray(ops.bucketize(v, b))[0]
    m = b.shape[1]
    assert out.min() >= 0 and out.max() <= m  # ids within [0, m]
    # monotonicity: larger value -> >= bucket id
    order = np.argsort(v[0], kind="stable")
    assert (np.diff(out[order]) >= 0).all()


@_settings
@given(
    ids=st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200),
    seed=st.integers(0, 2**32 - 1),
    d=st.integers(1, 2**31 - 1),
)
def test_sigridhash_range_determinism(ids, seed, d):
    v = np.array(ids, np.int32)[None]
    a = np.asarray(ops.sigridhash(v, [seed], [d]))[0]
    b = np.asarray(ref.sigridhash(jnp.asarray(v[0]), seed, d))
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < d


@_settings
@given(
    data=st.data(),
    width=st.integers(1, 32),
)
def test_bitpack_roundtrip(data, width):
    n = data.draw(st.integers(1, 300))
    vals = data.draw(
        st.lists(st.integers(0, 2**width - 1), min_size=n, max_size=n)
    )
    v = np.array(vals, np.uint64)
    packed = enc.bitpack(v, width)
    out = enc.bitunpack(packed, n, width)
    np.testing.assert_array_equal(out, v.astype(np.uint32))


@_settings
@given(vals=st.lists(st.floats(width=32, allow_nan=False), min_size=1, max_size=300))
def test_bytesplit_roundtrip(vals):
    v = np.array(vals, np.float32)
    words, n = enc.bytesplit_encode(v)
    np.testing.assert_array_equal(enc.bytesplit_decode(words, n), v)


@_settings
@given(
    rows=st.integers(1, 64),
    lens=st.data(),
)
def test_lengths_mask_invariant(rows, lens):
    """Lengths decoded from a partition always bound the padded ids."""
    from repro.data.synth import RMDataConfig, SyntheticRecSysSource

    cfg = RMDataConfig("t", 2, 3, 4, 8, 1, 16, 1 << 12, 256, rows_per_partition=rows)
    src = SyntheticRecSysSource(cfg, rows=rows)
    raw = src.raw(lens.draw(st.integers(0, 5)))
    assert (raw.sparse_lengths >= 1).all()
    assert (raw.sparse_lengths <= cfg.max_sparse_len).all()
    mask = np.arange(cfg.max_sparse_len)[None, None] >= raw.sparse_lengths[..., None]
    assert (np.where(mask, raw.sparse_values, 0) == 0).all()


# --- WorkQueue invariants under arbitrary interleavings -------------------
# Ops are drawn as data tuples and replayed against a reference model (see
# tests/workqueue_model.py): after EVERY op the queue's _pending_set must
# agree with the model and with its own per-device order deques, peek_ahead
# must be pure, and a completed partition must never be resurrected by a
# tombstoned deque entry.  The drain epilogue then asserts exactly-once
# delivery of every partition.

_DEVICES = 3

_claim_op = st.tuples(
    st.just("claim"),
    st.booleans(),  # reissue_only
    st.one_of(st.none(), st.integers(0, _DEVICES - 1)),  # prefer_device
    st.booleans(),  # fallback_ok admits everything?
)
_complete_op = st.tuples(st.just("complete"), st.integers(0, 63))
_expire_op = st.tuples(st.just("expire"), st.integers(0, 63))
_peek_op = st.tuples(
    st.just("peek"),
    st.integers(0, 24),
    st.one_of(st.none(), st.integers(0, _DEVICES - 1)),
)
_advance_op = st.tuples(
    st.just("advance"), st.floats(0.0, TIMEOUT * 1.5, allow_nan=False)
)
_ops = st.lists(
    st.one_of(_claim_op, _complete_op, _expire_op, _peek_op, _advance_op),
    max_size=60,
)


@_settings
@given(ops_seq=_ops, partitions=st.integers(1, 20))
def test_workqueue_interleaving_invariants(ops_seq, partitions):
    """_pending_set consistent with the per-device deques, claims FIFO
    within preference class, re-issue only when overdue, tombstones never
    resurrect, exactly-once drain — under ANY op interleaving."""
    apply_ops(list(ops_seq), partitions=partitions, devices=_DEVICES)


@_settings
@given(
    ops_seq=_ops,
    partitions=st.integers(1, 16),
    n=st.integers(0, 20),
    prefer=st.one_of(st.none(), st.integers(0, _DEVICES - 1)),
)
def test_workqueue_peek_ahead_never_claims(ops_seq, partitions, n, prefer):
    """peek_ahead after an arbitrary history is a pure snapshot: claim
    order preserved, nothing marked inflight, remaining() untouched."""
    wq = apply_ops(
        list(ops_seq), partitions=partitions, devices=_DEVICES, drain=False)
    before = (wq.pending_snapshot(), wq.remaining())
    out = wq.peek_ahead(n, prefer_device=prefer)
    assert len(out) == len(set(out)) and len(out) <= max(n, 0)
    assert set(out) <= set(before[0])
    assert (wq.pending_snapshot(), wq.remaining()) == before


@_settings
@given(ops_seq=_ops, partitions=st.integers(1, 16))
def test_workqueue_completed_never_resurrected(ops_seq, partitions):
    """After the queue drains, every further claim mode returns None —
    lingering tombstones and back-dated straggler stamps stay dead."""
    wq = apply_ops(list(ops_seq), partitions=partitions, devices=_DEVICES)
    assert wq.exhausted
    for reissue_only in (False, True):
        for prefer in (None, 0):
            assert wq.claim(
                reissue_only=reissue_only, prefer_device=prefer,
                fallback_ok=lambda p: True) is None
