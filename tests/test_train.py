"""Training substrate: optimizers, microbatching, checkpoint, elastic."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import ShardingRules
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train import (
    CheckpointManager,
    ElasticTrainer,
    adafactor,
    adamw,
    clip_by_global_norm,
    make_train_step,
    warmup_cosine,
)

RULES = ShardingRules.make(None)
CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
                  remat="none")


def _setup(opt):
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    loss_fn = lambda p, b: T.loss_fn(p, b, CFG, RULES)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = {"tokens": jnp.ones((4, 64), jnp.int32),
             "labels": jnp.ones((4, 64), jnp.int32),
             "mask": jnp.ones((4, 64), jnp.float32)}
    return loss_fn, state, batch


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_loss_decreases(opt_name):
    opt = (adamw if opt_name == "adamw" else adafactor)(
        warmup_cosine(1e-3, 2, 100)
    )
    loss_fn, state, batch = _setup(opt)
    step = jax.jit(make_train_step(loss_fn, opt))
    losses = []
    for _ in range(5):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (opt_name, losses)


def test_microbatch_equivalence():
    opt = adamw(warmup_cosine(1e-3, 2, 100))
    loss_fn, state, batch = _setup(opt)
    s1, m1 = jax.jit(make_train_step(loss_fn, opt, microbatches=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(loss_fn, opt, microbatches=2))(state, batch)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s1["params"], s2["params"]
    )
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-4


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert abs(float(gn) - 10.0) < 1e-5
    leaves = jax.tree_util.tree_leaves(clipped)
    norm = float(jnp.sqrt(sum(jnp.sum(g * g) for g in leaves)))
    assert abs(norm - 1.0) < 1e-5


def test_checkpoint_roundtrip_atomic_and_gc():
    opt = adamw(warmup_cosine(1e-3, 2, 100))
    _, state, _ = _setup(opt)
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep=2, async_save=False)
        for s in (1, 2, 3):
            ck.save(s, state)
        assert ck.latest_step() == 3
        # keep=2 garbage-collects step 1
        assert not os.path.exists(os.path.join(d, "step_000000001"))
        restored = ck.restore(target=state)
        same = jax.tree.map(
            lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
            state, restored)
        assert all(jax.tree_util.tree_leaves(same))
        # a stale .tmp dir is cleaned up on next manager start
        os.makedirs(os.path.join(d, "step_000000009.tmp"))
        CheckpointManager(d)
        assert not os.path.exists(os.path.join(d, "step_000000009.tmp"))


def test_elastic_failure_restart_continues():
    opt = adamw(warmup_cosine(1e-3, 2, 100))
    loss_fn, state0, batch = _setup(opt)
    step = jax.jit(make_train_step(loss_fn, opt))

    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, async_save=False)
        trainer = ElasticTrainer(
            make_mesh=lambda: None,
            make_state=lambda mesh: {k: v for k, v in state0.items()},
            make_step=lambda mesh: step,
            state_shardings=lambda mesh: None,
            ckpt=ck,
            checkpoint_every=2,
        )
        batches = lambda: ((i, batch) for i in range(6))
        with pytest.raises(RuntimeError, match="simulated failure"):
            trainer.run(batches(), max_steps=6, fail_at=5)
        assert ck.latest_step() == 4  # checkpointed before the crash
        # new incarnation restores and finishes; replayed steps are skipped
        state, metrics = trainer.run(batches(), max_steps=6)
        assert int(state["step"]) == 6
        # straight-through run (no failure) matches the restarted run
        ck2_state = state0
        for i in range(6):
            ck2_state, _ = step(ck2_state, batch)
        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))),
            state["params"], ck2_state["params"])
        assert max(jax.tree_util.tree_leaves(diffs)) < 1e-5
