"""Self-tuning produce path: online K autotuning, peek window, staging budget.

Four invariant groups of the self-tuning loop:

* **Tuner convergence** — on monotone synthetic cost curves the hill climb
  reaches the best rung and permanently stops moving; interior optima are
  found; the improvement-move cap freezes oscillation; off-proposal and
  off-ladder launches never steer the climb.
* **Queue discipline** — the per-device pending index claims in FIFO order
  with O(1) pops, ``peek_ahead`` never claims and never double-exposes a
  pid, and every partition is still claimed exactly once.
* **Staging budget** — pages pre-staged ahead of claims never exceed
  ``JobSpec.stage_budget_bytes`` (a too-small budget disables pre-staging
  entirely) and never change delivered bytes.
* **K feedback** — a tuner K move re-bases the planner's P estimate and
  observably re-balances ``plan_pool`` shares across tenants.
"""

import math
import threading
import time

import numpy as np
import pytest

from repro.configs.registry import get_recsys
from repro.core.autotune import DEFAULT_AUTOTUNE_KMAX, MegabatchTuner, k_ladder
from repro.core.costmodel import DEFAULT_PLACEMENT_MODEL
from repro.core.planner import qos_demand_units
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.loader import WorkQueue
from repro.data.storage import PartitionedStore
from repro.data.synth import SyntheticRecSysSource


@pytest.fixture(scope="module")
def rm1():
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=256)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(12, num_devices=4, source=src)
    engine = PreStoEngine(spec)  # one jit cache across every run in the module
    return spec, store, engine


def _assert_bitwise(ref, got):
    assert sorted(got) == sorted(ref)
    for pid in ref:
        for key in ref[pid]:
            np.testing.assert_array_equal(
                np.asarray(ref[pid][key]), np.asarray(got[pid][key]),
                err_msg=f"pid={pid} key={key}",
            )


def _drive(tuner: MegabatchTuner, cost_of, iters: int = 64) -> MegabatchTuner:
    """Feed the tuner launches at its OWN proposal until it converges —
    exactly what the pipelined worker loop does, minus the wall clock."""
    for _ in range(iters):
        if tuner.converged:
            break
        k = tuner.k
        tuner.record(k, cost_of(k) * k)
    assert tuner.converged, "tuner failed to converge within the iteration cap"
    return tuner


# -- ladder + seeding ---------------------------------------------------------


def test_k_ladder_powers_of_two():
    assert k_ladder(1) == [1]
    assert k_ladder(2) == [1, 2]
    assert k_ladder(8) == [1, 2, 4, 8]
    assert k_ladder(12) == [1, 2, 4, 8]  # clipped to the last full rung
    assert k_ladder(0) == [1]  # degenerate cap still yields a valid ladder


def test_predicted_megabatch_k_knee():
    model = DEFAULT_PLACEMENT_MODEL
    # huge per-partition cost: nothing to amortize, the knee is K=1
    assert model.predicted_megabatch_k(10.0, 8) == 1
    # negligible per-partition cost: dispatch overhead dominates, go deep
    assert model.predicted_megabatch_k(1e-7, 8) == 8
    # the knee is monotone non-increasing in per-partition cost
    ks = [model.predicted_megabatch_k(pps, 8)
          for pps in (1e-7, 1e-5, 1e-3, 1e-1, 10.0)]
    assert ks == sorted(ks, reverse=True)
    # restricting candidates restricts the answer
    assert model.predicted_megabatch_k(1e-7, 8, candidates=[1, 2]) == 2


def test_qos_demand_units_clamps_and_caps():
    assert qos_demand_units(1000.0, 0.0) == 1  # no measurement yet
    assert qos_demand_units(1000.0, 100.0) == 10
    assert qos_demand_units(50.0, 100.0) == 1  # floor
    assert qos_demand_units(1e9, 1.0, cap=64) == 64  # cap


def test_tuner_seeds_from_cost_model():
    cheap = MegabatchTuner(8, per_partition_s=1e-7)
    assert cheap.seeded_k == 8  # overhead-dominated: seed at the top
    dear = MegabatchTuner(8, per_partition_s=10.0)
    assert dear.seeded_k == 1
    assert MegabatchTuner(8).seeded_k == 1  # no estimate: conservative


# -- hill climb ---------------------------------------------------------------


def test_tuner_climbs_monotone_decreasing_cost():
    """Per-partition cost strictly improving with K: the climb explores
    uphill rung by rung and converges at the top."""
    t = _drive(MegabatchTuner(8), lambda k: 1.0 / k)
    assert t.k == 8


def test_tuner_converges_at_one_for_increasing_cost():
    """Per-partition cost worsening with K: one uphill probe, then back to
    K=1 — without ever paying for the expensive top rungs."""
    t = _drive(MegabatchTuner(8), lambda k: float(k))
    assert t.k == 1
    assert t.arm_cost(4) is None and t.arm_cost(8) is None


def test_tuner_finds_interior_optimum():
    costs = {1: 1.0, 2: 0.4, 4: 0.8, 8: 1.2}
    t = _drive(MegabatchTuner(8), costs.__getitem__)
    assert t.k == 2


def test_tuner_frozen_after_convergence():
    t = _drive(MegabatchTuner(8), lambda k: 1.0 / k)
    k = t.k
    # a later regime change keeps updating EMAs but never moves the proposal
    for _ in range(8):
        assert t.record(k, 100.0 * k) is False
    assert t.k == k and t.converged


def test_tuner_ignores_off_ladder_and_foreign_launches():
    t = MegabatchTuner(8)
    assert t.k == 1
    assert t.record(3, 1.0) is False  # off-ladder partial chunk: no rung
    assert t.arm_cost(3) is None
    assert t.record(0, 1.0) is False and t.record(1, -1.0) is False
    # a foreign-rung launch updates that rung's EMA but never advances
    for _ in range(8):
        assert t.record(2, 1.0) is False
    assert t.k == 1 and t.arm_cost(2) == pytest.approx(0.5)


def test_tuner_move_cap_freezes_oscillation():
    """With zero improvement moves allowed, the first wanted move trips the
    backstop: the tuner freezes where it stands instead of bouncing."""
    t = MegabatchTuner(2, max_moves=0)
    costs = {1: 1.0, 2: 5.0}
    _drive(t, costs.__getitem__)
    assert t.converged and t.moves == 0 and t.k == 2


def test_tuner_summary_reports_measured_arms():
    t = _drive(MegabatchTuner(4), lambda k: 1.0 / k)
    s = t.summary()
    assert s["k"] == 4 and s["converged"] is True
    assert set(s["arms"]) == {1, 2, 4}
    assert all(a["samples"] >= 1 for a in s["arms"].values())


# -- work-queue device index + peek window ------------------------------------


def test_workqueue_device_index_fifo_and_fallback():
    q = WorkQueue(range(8), owner_of=lambda pid: pid % 2)
    # device-preferred claims pop the device index in FIFO order
    assert [q.claim(prefer_device=0) for _ in range(4)] == [0, 2, 4, 6]
    # device 0 drained: no fallback predicate means no claim
    assert q.claim(prefer_device=0) is None
    # fallback admits foreign pids in global FIFO order (pid 1 first)
    assert q.claim(prefer_device=0, fallback_ok=lambda p: True) == 1
    # pid 1 is now a tombstone in device 1's index: skipped, not re-claimed
    assert q.claim(prefer_device=1) == 3
    assert sorted(q.claim() for _ in range(2)) == [5, 7]
    assert q.claim() is None and q.remaining() == 8  # all inflight
    for pid in range(8):
        assert q.complete(pid)
    assert q.exhausted and q.remaining() == 0


def test_workqueue_peek_ahead_is_non_claiming_and_ordered():
    q = WorkQueue(range(8), owner_of=lambda pid: pid % 2)
    # device window first, then the global FIFO, no duplicates
    assert q.peek_ahead(3, prefer_device=1) == [1, 3, 5]
    assert q.peek_ahead(6, prefer_device=1) == [1, 3, 5, 7, 0, 2]
    assert q.peek_ahead(100) == list(range(8))
    assert q.peek_ahead(0) == []
    # peeking claimed nothing: every pid is still claimable exactly once
    assert q.remaining() == 8
    claimed = [q.claim() for _ in range(8)]
    assert sorted(claimed) == list(range(8))
    # peek excludes inflight/claimed pids
    assert q.peek_ahead(8) == []


def test_workqueue_peek_tracks_claims():
    q = WorkQueue(range(6))
    assert q.is_pending(0)
    q.claim()
    assert not q.is_pending(0)
    assert q.pending_snapshot() == [1, 2, 3, 4, 5]
    assert q.peek_ahead(2) == [1, 2]


# -- lookahead staging budget -------------------------------------------------


def _page_nbytes(engine, rows: int) -> int:
    return int(sum(
        math.prod(s.shape) * np.dtype(s.dtype).itemsize
        for s in engine.pages_struct(rows).values()
    ))


def test_lookahead_staging_respects_byte_budget(rm1):
    spec, store, engine = rm1
    solo = {pid: engine.produce_batch(store, pid) for pid in range(12)}
    budget = 2 * _page_nbytes(engine, 256)  # room for two pre-staged pages
    with PreprocessingService(num_workers=1) as svc:
        session = svc.submit(JobSpec(
            name="la", partitions=range(12), engine=engine, store=store,
            units=1, queue_depth=12, lookahead=4,
            stage_budget_bytes=budget))
        got = {pid: mb for pid, mb in session}
        st = session.stats()
    _assert_bitwise(solo, got)
    assert 0 < st.staged_bytes_peak <= budget


def test_tiny_budget_disables_prestaging(rm1):
    spec, store, engine = rm1
    solo = {pid: engine.produce_batch(store, pid) for pid in range(12)}
    with PreprocessingService(num_workers=1) as svc:
        session = svc.submit(JobSpec(
            name="la0", partitions=range(12), engine=engine, store=store,
            units=1, queue_depth=12, megabatch=2, lookahead=4,
            stage_budget_bytes=1))  # smaller than one page: nothing staged
        got = {pid: mb for pid, mb in session}
        st = session.stats()
    _assert_bitwise(solo, got)
    assert st.staged_bytes_peak == 0


# -- autotuned end-to-end -----------------------------------------------------


def test_autotuned_session_bitwise_and_stats(rm1):
    spec, store, engine = rm1
    solo = {pid: engine.produce_batch(store, pid) for pid in range(12)}
    with PreprocessingService(num_workers=2) as svc:
        session = svc.submit(JobSpec(
            name="auto", partitions=range(12), engine=engine, store=store,
            units=2, queue_depth=12, autotune=True, lookahead=2))
        got = {pid: mb for pid, mb in session}
        st = session.stats()
    _assert_bitwise(solo, got)
    assert st.done and st.produced == 12
    assert st.tuned_k in k_ladder(DEFAULT_AUTOTUNE_KMAX)


def test_megabatch_caps_the_autotune_ladder(rm1):
    spec, store, engine = rm1
    with PreprocessingService(num_workers=1) as svc:
        session = svc.submit(JobSpec(
            name="capped", partitions=range(12), engine=engine, store=store,
            units=1, queue_depth=12, autotune=True, megabatch=2))
        got = {pid: mb for pid, mb in session}
        st = session.stats()
    assert sorted(got) == list(range(12))
    assert st.tuned_k in (1, 2)  # never above the cap


# -- K feedback into plan_pool ------------------------------------------------


def test_tuned_k_move_rebalances_pool(rm1):
    """A tuner K move re-bases P, re-estimates QoS demand, and the pool's
    unit shares observably shift toward the tuned job."""
    spec, store, engine = rm1
    gate = threading.Event()
    entered = threading.Semaphore(0)

    def blocker(pid):
        entered.release()
        gate.wait(10.0)
        return {"labels": np.zeros((4,), np.float32)}

    try:
        with PreprocessingService(num_workers=3) as svc:
            blk = svc.submit(JobSpec(name="blk", partitions=range(3),
                                     produce_fn=blocker, units=3))
            # park every worker inside a blocked produce so the tuned job's
            # tuner state is entirely ours to drive
            for _ in range(3):
                assert entered.acquire(timeout=5.0)
            tuned = svc.submit(JobSpec(
                name="tuned", partitions=range(12), engine=engine,
                store=store, autotune=True,
                target_samples_per_s=1024.0))
            before = dict(svc.plan.shares)
            assert before["tuned"] == 1  # demand 1 before any measurement
            # measured regime: 1.0 s per partition at every rung -> with 256
            # rows/partition, P = 256 rows/s, demand = ceil(1024/256) = 4
            _drive(tuned._tuner, lambda k: 1.0)
            tuned._on_tuned_k_changed()
            after = dict(svc.plan.shares)
            st = tuned.stats()
            tuned.cancel()
            blk.cancel()
            gate.set()
    finally:
        gate.set()
    assert st.demand_units == 4
    assert after != before
    assert after["tuned"] > before["tuned"]
