"""Config registry + roofline bookkeeping sanity."""

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch, get_recsys
from repro.launch.roofline import model_flops, param_counts
from repro.launch.specs import input_specs
from repro.models.config import SHAPES


def test_all_archs_load_and_periods_divide():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        e = get_arch(a)
        assert e.config.n_layers % len(e.config.period()) == 0
        assert e.reduced.n_layers % len(e.reduced.period()) == 0
        assert e.config.padded_vocab % 256 == 0


def test_param_counts_known_scales():
    # each assigned arch's declared parameter count should be in the
    # ballpark of its name (backbone-only for vlm)
    expectations = {
        "h2o-danube-1.8b": (1.4e9, 2.4e9),
        "gemma-7b": (7e9, 10e9),
        "glm4-9b": (8e9, 13e9),
        "gemma3-12b": (10e9, 14e9),
        "internvl2-76b": (6.5e10, 8.5e10),
        "grok-1-314b": (2.8e11, 3.6e11),
        "llama4-maverick-400b-a17b": (3.4e11, 4.6e11),
        "jamba-v0.1-52b": (4.4e11 / 10, 6e10),
        "mamba2-1.3b": (1.0e9, 1.9e9),
    }
    for a, (lo, hi) in expectations.items():
        total, active = param_counts(get_arch(a).config)
        assert lo <= total <= hi, (a, total)
        assert active <= total


def test_moe_active_params_smaller():
    total, active = param_counts(get_arch("grok-1-314b").config)
    assert active < 0.5 * total  # top-2 of 8 experts
    total_d, active_d = param_counts(get_arch("gemma-7b").config)
    assert total_d == active_d


def test_model_flops_kinds():
    cfg = get_arch("gemma-7b").config
    t = model_flops(cfg, SHAPES["train_4k"])
    p = model_flops(cfg, SHAPES["prefill_32k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    assert t == 3 * p  # same tokens, 6NP vs 2NP
    assert d < p / 1000  # one token per seq


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_all_cells(arch_id, shape):
    e = get_arch(arch_id)
    specs = input_specs(e.config, shape)
    assert specs, (arch_id, shape)
    for leaf in jax.tree_util.tree_leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
        assert all(d > 0 for d in leaf.shape)


def test_recsys_configs():
    full = get_recsys("rm5")
    red = get_recsys("rm5", reduced=True)
    assert full.data.n_dense == 504 and full.data.n_sparse == 42
    assert full.data.bucket_size == 4096 and full.n_tables == 84
    assert red.data.embedding_rows <= 4096  # smoke-sized tables
