"""Trip-count-aware HLO cost model: exact flop counting across scans."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import HloCostModel, analyze, _type_numel_bytes


def test_scan_flops_multiplied():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
    ).compile()
    cost = analyze(c.as_text())
    assert cost.flops == 7 * 2 * 64 * 128 * 128
    assert cost.trans == 7 * 64 * 128


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
    ).compile()
    cost = analyze(c.as_text())
    assert cost.flops == 5 * 3 * 2 * 32 * 64 * 64


def test_type_bytes():
    assert _type_numel_bytes("f32[4,8]{1,0}") == 128
    assert _type_numel_bytes("bf16[10]") == 20
    assert _type_numel_bytes("(f32[2]{0}, s8[4]{0})") == 12
    assert _type_numel_bytes("pred[]") == 1


def test_dot_flops_counted_without_loops():
    def f(a, b):
        return a @ b

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((16, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 8), jnp.float32),
    ).compile()
    cost = analyze(c.as_text())
    assert cost.flops == 2 * 16 * 32 * 8
