"""Preprocessing-as-a-service: shared pool, sessions, admission, QoS shares.

The acceptance invariant: N tenants sharing one pool each receive exactly
the batches they would have received running alone — bitwise — because
partitions are deterministic and the straggler machinery is winner-takes-
first / duplicate-drop per session.
"""

import threading
import time

import numpy as np
import pytest

from repro.configs.registry import get_recsys
from repro.core.featcache import FeatureCache
from repro.core.planner import AdmissionError, plan_pool
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.loader import SessionQueue
from repro.data.storage import PartitionedStore
from repro.data.synth import SyntheticRecSysSource


@pytest.fixture(scope="module")
def rm1():
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=256)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(12, num_devices=4, source=src)
    engine = PreStoEngine(spec)  # one jit cache across every run in the module
    return spec, store, engine


def _collect(session):
    return {pid: mb for pid, mb in session}


def _collect_into(session, out: dict):
    out.update(_collect(session))


# -- planner ------------------------------------------------------------------


def test_plan_pool_floor_and_proportional_shares():
    plan = plan_pool(8, {"a": 6, "b": 2, "c": 1})
    assert plan.shares == {"a": 5, "b": 2, "c": 1}  # floor 1 + largest remainder
    assert sum(plan.shares.values()) <= plan.capacity
    assert plan.oversubscribed
    # surplus beyond aggregate demand stays idle (capped at demand)
    plan = plan_pool(16, {"a": 2, "b": 1})
    assert plan.shares == {"a": 2, "b": 1}
    assert not plan.oversubscribed


def test_plan_pool_admission_floor():
    with pytest.raises(AdmissionError):
        plan_pool(2, {"a": 1, "b": 1, "c": 1})


# -- session queue (the per-session half of the pool contract) ----------------


def test_session_queue_backpressure_allows_reissue_only():
    q = SessionQueue(range(4), depth=2, straggler_timeout=0.0)
    a = q.claim()
    b = q.claim()
    assert a[0] == 0 and b[0] == 1
    # two undelivered claims = at depth: fresh claims refused...
    time.sleep(0.01)
    c = q.claim()
    assert c is not None and c[0] in (0, 1)  # ...but a straggler backup is not
    assert c[1] is (a[1] if c[0] == 0 else b[1])  # same future, no new delivery
    assert q.work.reissues == 1
    # duplicate completion is dropped, winner resolves the future
    assert q.complete(c[0], "first") is True
    assert q.complete(c[0], "second") is False
    assert q.out.get_nowait().result(timeout=1)[1] == "first"
    # backpressure keys on the consumer's pacing signal, not queue residency:
    # still at depth, so only the overdue straggler (pid 1) is claimable again
    d = q.claim()
    assert d[0] == 1 and q.work.reissues == 2
    q.mark_delivered()
    assert q.claim()[0] == 2  # pacing signal reopens fresh claims
    # completed futures are dropped from the claim map (memory stays bounded
    # by depth, not job size)
    assert c[0] not in q._futures


def test_raw_futures_stream_accounts_delivery_and_done():
    """Consuming via futures() must leave the same done/delivered accounting
    as plain iteration (delivery recorded when each future resolves)."""
    with PreprocessingService(num_workers=2) as svc:
        s = svc.submit(JobSpec(name="raw", partitions=range(6),
                               produce_fn=lambda pid: pid))
        got = [fut.result(timeout=10) for fut in s.futures()]
    assert sorted(pid for pid, _ in got) == list(range(6))
    st = s.stats()
    assert st.done and st.delivered == 6 and not st.cancelled


def test_duplicate_partition_ids_deduped_not_hung():
    """A JobSpec repeating a pid must not strand the consumer waiting for a
    batch that duplicate-drop will never deliver."""
    with PreprocessingService(num_workers=2) as svc:
        s = svc.submit(JobSpec(name="dups", partitions=[0, 0, 1, 2, 1],
                               produce_fn=lambda pid: pid))
        assert s.total == 3
        assert sorted(pid for pid, _ in s) == [0, 1, 2]
        assert s.stats().done


def test_session_reiteration_resumes_where_it_stopped():
    """A partially consumed session can be re-iterated / drain()-ed: the
    hand-off counter is session state, not per-generator state."""
    with PreprocessingService(num_workers=2) as svc:
        s = svc.submit(JobSpec(name="resume", partitions=range(10),
                               produce_fn=lambda pid: pid))
        it = iter(s)
        first = [next(it) for _ in range(3)]
        rest = s.drain()  # fresh iterator: must deliver the remaining 7, not hang
        assert len(first) == 3 and rest == 7
        assert s.stats().done and s.stats().delivered == 10


# -- the acceptance criterion -------------------------------------------------


@pytest.mark.parametrize("cached", [False, True], ids=["no-cache", "cache"])
def test_two_sessions_bitwise_identical_to_single_tenant(rm1, cached):
    """The acceptance invariant, with and without the shared feature cache:
    overlapping tenants (cache on) must still each see exactly their solo
    batches — a cache hit IS the solo batch, bitwise."""
    spec, store, engine = rm1
    if cached:
        parts = {"tenant-a": range(0, 8), "tenant-b": range(4, 12)}  # overlap
    else:
        parts = {"tenant-a": range(0, 6), "tenant-b": range(6, 12)}

    def job(name):
        return JobSpec(name=name, partitions=parts[name], engine=engine,
                       store=store, units=2)

    solo = {}
    for name in parts:
        with PreprocessingService(num_workers=2) as svc:
            solo[name] = _collect(svc.submit(job(name)))

    cache = FeatureCache(256 << 20) if cached else None
    shared = {name: {} for name in parts}
    with PreprocessingService(num_workers=2, cache=cache) as svc:
        sessions = {name: svc.submit(job(name)) for name in parts}
        threads = [
            threading.Thread(target=_collect_into, args=(sessions[n], shared[n]))
            for n in parts
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = {name: sessions[name].stats() for name in parts}

    for name in parts:
        assert sorted(shared[name]) == list(parts[name])  # all pids, no dupes
        assert stats[name].done and not stats[name].cancelled
        for pid, mb in solo[name].items():
            for key in mb:
                np.testing.assert_array_equal(
                    np.asarray(mb[key]), np.asarray(shared[name][pid][key]),
                    err_msg=f"{name} pid={pid} key={key} diverged under sharing",
                )
    if cached:
        cs = cache.stats()
        assert cs.hits + cs.follows >= 4  # the overlap deduplicated


# -- straggler re-issue through the Session API (satellite) -------------------


def test_straggler_reissue_and_duplicate_drop_two_sessions():
    def make_produce(slow_pid, delay):
        def produce(pid):
            if pid == slow_pid:
                time.sleep(delay)
            return {"pid": pid}
        return produce

    with PreprocessingService(num_workers=3) as svc:
        slow = svc.submit(JobSpec(
            name="slow", partitions=range(6),
            produce_fn=make_produce(2, 0.5), straggler_timeout=0.05, units=2))
        fast = svc.submit(JobSpec(
            name="fast", partitions=range(6),
            produce_fn=make_produce(-1, 0.0), units=1))
        out_fast: dict = {}
        t = threading.Thread(target=_collect_into, args=(fast, out_fast))
        t.start()
        out_slow = _collect(slow)
        t.join()
        # the injected straggler was re-issued; the slow copy's completion
        # may still be in flight, so give the pool a beat to record the drop
        deadline = time.monotonic() + 2.0
        while slow.stats().duplicates_dropped == 0 and time.monotonic() < deadline:
            time.sleep(0.01)

    assert sorted(out_slow) == list(range(6))  # every batch once, no dupes
    assert sorted(out_fast) == list(range(6))
    assert slow.stats().reissues > 0
    assert slow.stats().duplicates_dropped >= 1
    assert fast.stats().reissues == 0


# -- admission, rebalance, cancel ---------------------------------------------


def test_admission_and_rebalance_on_join_and_leave():
    def produce(pid):
        time.sleep(0.002)
        return pid

    with PreprocessingService(num_workers=2) as svc:
        s1 = svc.submit(JobSpec(name="j1", partitions=range(50),
                                produce_fn=produce, units=2))
        assert s1.share == 2  # alone: full pool
        s2 = svc.submit(JobSpec(name="j2", partitions=range(50),
                                produce_fn=produce, units=2))
        assert s1.share == 1 and s2.share == 1  # join rebalances
        with pytest.raises(AdmissionError):
            svc.submit(JobSpec(name="j3", partitions=range(4),
                               produce_fn=produce))
        with pytest.raises(ValueError, match="already active"):
            svc.submit(JobSpec(name="j2", partitions=range(4),
                               produce_fn=produce))
        s1.cancel()
        assert s2.share == 2  # leave rebalances
        s3 = svc.submit(JobSpec(name="j3", partitions=range(4),
                                produce_fn=produce))  # admission slot freed
        assert sorted(pid for pid, _ in s3) == list(range(4))
        assert s1.stats().cancelled
        s2.cancel()


def test_cancel_stops_stream_and_pool_serves_others():
    def produce(pid):
        time.sleep(0.005)
        return pid

    with PreprocessingService(num_workers=2) as svc:
        s1 = svc.submit(JobSpec(name="big", partitions=range(40),
                                produce_fn=produce))
        s2 = svc.submit(JobSpec(name="small", partitions=range(8),
                                produce_fn=produce))
        it = iter(s1)
        got = [next(it) for _ in range(3)]
        s1.cancel()
        assert s1.drain() == 0  # cancelled stream yields nothing further
        assert len(got) == 3 and s1.stats().delivered == 3
        assert sorted(pid for pid, _ in s2) == list(range(8))
        assert s2.stats().done


def test_worker_error_propagates_to_consumer_only():
    def explode(pid):
        if pid == 1:
            raise RuntimeError("storage device on fire")
        return pid

    with PreprocessingService(num_workers=2) as svc:
        bad = svc.submit(JobSpec(name="bad", partitions=range(3),
                                 produce_fn=explode))
        good = svc.submit(JobSpec(name="good", partitions=range(5),
                                  produce_fn=lambda pid: pid))
        with pytest.raises(RuntimeError, match="on fire"):
            _collect(bad)
        bad.cancel()
        assert sorted(pid for pid, _ in good) == list(range(5))


def test_closed_service_raises_for_blocked_consumer():
    svc = PreprocessingService(num_workers=1)
    session = svc.submit(JobSpec(name="orphan", partitions=range(4),
                                 produce_fn=lambda pid: time.sleep(0.05) or pid))
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        for _ in session:
            pass
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(JobSpec(name="late", partitions=range(1),
                           produce_fn=lambda pid: pid))


def test_qos_demand_reestimated_from_measured_P():
    rows = 64

    def produce(pid):
        time.sleep(0.01)  # P ~= 6400 samples/s per worker
        return {"labels": np.zeros((rows,), np.float32)}

    with PreprocessingService(num_workers=4) as svc:
        s = svc.submit(JobSpec(name="qos", partitions=range(30),
                               produce_fn=produce,
                               target_samples_per_s=12_000.0))
        assert s.stats().demand_units == 1  # before any P measurement
        _collect(s)
        st = s.stats()
    # demand converges to ceil(target/P) ~ 2, and shares follow
    assert st.demand_units >= 2
    assert st.worker_samples_per_s > 0
