"""Device-aware scheduling: the simulated ISP devices as first-class
schedulable resources.

The correctness anchor throughout: routing NEVER changes batch bytes — a
Zipf-skewed ownership map with host fallback delivers exactly the batches of
the uniform run, bitwise; only the ledgers (where/when the work is charged)
differ.
"""

import numpy as np
import pytest

from repro.configs.registry import get_recsys
from repro.data.columnar import decode_partition_numpy
from repro.core.costmodel import (
    ContentionAwareCostModel,
    partition_costs,
)
from repro.core.featcache import CacheKey, FeatureCache, batch_nbytes
from repro.core.planner import DeviceTopology, plan_pool
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.storage import (
    CacheSpillStore,
    DeviceFleet,
    IspDevice,
    PartitionedStore,
    zipf_owner_map,
)
from repro.data.synth import SyntheticRecSysSource


@pytest.fixture(scope="module")
def rm1():
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=64)
    spec = TransformSpec.from_source(src)
    engine = PreStoEngine(spec)  # one jit cache across every run in the module
    return src, spec, engine


# -- the device itself --------------------------------------------------------


def test_isp_device_ledger_and_occupancy():
    d = IspDevice(0, stream_bytes_per_s=1e6, compute_ops_per_s=1e6)
    assert d.charge_stream(500_000) == pytest.approx(0.5)
    assert d.busy_s == pytest.approx(0.5) and d.bytes_streamed == 500_000
    assert d.charge_compute(1_000_000) == pytest.approx(1.0)
    assert d.busy_s == pytest.approx(1.5) and d.compute_ops == 1e6
    # spill traffic shares the SAME stream ledger (contends with reads)
    d.charge_stream(100_000, spill=True)
    assert d.spill_bytes == 100_000 and d.bytes_streamed == 600_000
    assert d.spill_io_s == pytest.approx(0.1)
    assert d.busy_s == pytest.approx(1.6)
    # occupancy: backlog + in-flight high-water mark
    d.enqueue(3)
    d.dequeue()
    assert d.queue_depth == 2
    d.begin_claim()
    d.begin_claim()
    assert d.inflight == 2 and d.max_inflight == 2
    d.end_claim()
    assert d.inflight == 1 and d.max_inflight == 2
    snap = d.snapshot()
    assert snap["device"] == 0 and snap["queue_depth"] == 2


def test_partition_reads_charge_owning_device(rm1):
    src, spec, engine = rm1
    fleet = DeviceFleet(4)
    store = PartitionedStore(8, num_devices=4, source=src, fleet=fleet)
    part = store.read(5)
    assert fleet[1].bytes_streamed == part.nbytes()  # 5 % 4 == 1
    assert all(fleet[d].bytes_streamed == 0 for d in (0, 2, 3))
    assert fleet[1].busy_s > 0
    # an explicit owner_map reroutes ownership (content is unchanged)
    fleet2 = DeviceFleet(4)
    skewed = PartitionedStore(
        8, num_devices=4, source=src, fleet=fleet2, owner_map=[0] * 8
    )
    assert skewed.owner_of(5) == 0 and skewed.partitions_of(0) == list(range(8))
    assert skewed.partitions_of(1) == []
    part2 = skewed.read(5)
    assert fleet2[0].bytes_streamed == part2.nbytes()
    # ownership never changes partition bytes
    d1, d2 = decode_partition_numpy(part), decode_partition_numpy(part2)
    for col in d1["dense"]:
        np.testing.assert_array_equal(d1["dense"][col], d2["dense"][col])


def test_zipf_owner_map_deterministic_and_skewed():
    m = zipf_owner_map(16, 4, alpha=1.1, seed=0)
    assert len(m) == 16 and set(m) <= set(range(4))
    assert m == zipf_owner_map(16, 4, alpha=1.1, seed=0)  # deterministic
    counts = [m.count(d) for d in range(4)]
    assert counts[0] == max(counts) and counts[0] >= 2 * min(counts)
    # alpha=0 degenerates to uniform quotas
    flat = zipf_owner_map(16, 4, alpha=0.0, seed=0)
    assert [flat.count(d) for d in range(4)] == [4, 4, 4, 4]


# -- spill accounting (per-device, not global) --------------------------------


def _batch(pid: int, kb: int = 8):
    rng = np.random.default_rng(pid)
    return {
        "labels": rng.random(kb * 256).astype(np.float32),
        "dense": np.full((4,), pid, np.int32),
    }


def test_spill_promote_charges_owning_device():
    fleet = DeviceFleet(3)
    spill = CacheSpillStore(num_devices=3, fleet=fleet)
    one = batch_nbytes(_batch(0))
    cache = FeatureCache(capacity_bytes=2 * one, spill=spill)
    keys = [CacheKey(f"part{i:04d}", "plan", "presto") for i in range(4)]
    for i, k in enumerate(keys):
        cache.put(k, _batch(i))
    assert cache.stats().evictions == 2  # keys 0 and 1 spilled
    owner = spill.owner_of(keys[0].block_id())
    io_before = spill.io_s_by_device[owner]
    block = cache.get(keys[0])  # spill hit -> promote
    assert block is not None
    np.testing.assert_array_equal(block["labels"], _batch(0)["labels"])
    # the promote's read bytes landed on the owning device's ledger
    assert spill.io_s_by_device[owner] > io_before
    assert fleet[owner].spill_bytes > 0 and fleet[owner].busy_s > 0
    # the per-device seconds sum to the global aggregate
    assert sum(spill.io_s_by_device) == pytest.approx(spill.modeled_io_s)
    st = cache.stats()
    assert st.spill_io_s_by_device and owner in st.spill_io_s_by_device


# -- contention-aware cost model ----------------------------------------------


def test_contention_model_prices_queue_wait(rm1):
    src, spec, engine = rm1
    model = ContentionAwareCostModel(queue_threshold=3)
    costs = partition_costs(spec)
    assert costs.isp_s > 0 and costs.host_s > 0 and costs.link_bytes > 0
    # wait pricing is linear in the queue
    assert model.contended_isp_s(costs.isp_s, 4) == pytest.approx(5 * costs.isp_s)
    # below the threshold locality always wins, whatever the queue price
    assert not model.should_offload(costs, 0)
    assert not model.should_offload(costs, 2)
    # above it, the contended comparison decides
    q = 6
    expect = model.contended_isp_s(costs.isp_s, q) > costs.host_s
    assert model.should_offload(costs, q) == expect
    # cost-less work (produce_fn test hooks): the threshold alone rules
    assert model.should_offload(None, 3) and not model.should_offload(None, 2)


# -- per-device provisioning --------------------------------------------------


def test_plan_pool_learns_device_topology():
    topo = DeviceTopology.round_robin(4, 2)
    assert topo.units_per_device == {0: 2, 1: 2}
    assert topo.total_units == 4 and topo.manned == {0, 1}
    # hot job lives entirely on device 0, cold job on device 1: neither can
    # starve the other's device slice
    plan = plan_pool(
        4,
        {"hot": 4, "cold": 4},
        topology=topo,
        device_weights={"hot": {0: 1.0}, "cold": {1: 1.0}},
    )
    assert plan.device_shares == {0: {"hot": 2, "cold": 0}, 1: {"hot": 0, "cold": 2}}
    assert plan.device_utilized_units(0) == 2
    # without weights jobs spread uniformly across devices
    plan = plan_pool(4, {"a": 2, "b": 2}, topology=topo)
    assert plan.device_shares == {0: {"a": 1, "b": 1}, 1: {"a": 1, "b": 1}}
    # no topology -> no device plan (seed behavior intact)
    assert plan_pool(4, {"a": 2}).device_shares is None


# -- the acceptance criterion: skewed routing, bitwise-identical --------------


def _run_job(engine, src, *, owner_map, locality, partitions, devices, threshold):
    fleet = DeviceFleet(devices)
    store = PartitionedStore(
        partitions, num_devices=devices, source=src, fleet=fleet,
        owner_map=owner_map,
    )
    model = ContentionAwareCostModel(queue_threshold=threshold)
    with PreprocessingService(
        num_workers=devices, devices=fleet, locality=locality, cost_model=model
    ) as svc:
        sess = svc.submit(JobSpec(
            name="skewed", partitions=range(partitions), engine=engine,
            store=store, units=devices, queue_depth=partitions,
        ))
        out = {pid: mb for pid, mb in sess}
        stats = sess.stats()
    return out, stats, fleet


def test_zipf_routing_bitwise_fallback_and_inflight_bound(rm1):
    """Satellite: Zipf-skewed claims over 4 devices — (a) batches bitwise
    identical to the uniform run, (b) host fallback engages only above the
    queue threshold, (c) no device exceeds its provisioned share by more
    than one in-flight claim."""
    src, spec, engine = rm1
    devices, partitions = 4, 16
    # uniform backlog is 16/4 = 4 bound partitions per device: a threshold
    # of 5 sits between the uniform and the skewed (hot owns 8) backlogs
    threshold = 5
    skew_map = zipf_owner_map(partitions, devices, alpha=1.1, seed=0)
    assert max(skew_map.count(d) for d in range(devices)) > threshold

    uniform, st_u, _ = _run_job(
        engine, src, owner_map=None, locality=True,
        partitions=partitions, devices=devices, threshold=threshold)
    blind, st_b, fleet_b = _run_job(
        engine, src, owner_map=skew_map, locality=False,
        partitions=partitions, devices=devices, threshold=threshold)
    routed, st_r, fleet_r = _run_job(
        engine, src, owner_map=skew_map, locality=True,
        partitions=partitions, devices=devices, threshold=threshold)

    # (b) below the threshold no claim ever leaves its device; above it the
    # hot device sheds work to the host
    assert st_u.host_fallbacks == 0  # uniform backlog < threshold everywhere
    assert st_b.host_fallbacks == 0  # locality-blind: no fallback path at all
    assert st_r.host_fallbacks > 0
    assert fleet_r.host_produces == st_r.host_fallbacks

    # (a) bitwise identity: routing changed WHERE work ran, never the bytes
    for name, run in (("blind", blind), ("routed", routed)):
        assert sorted(run) == list(range(partitions))
        for pid in uniform:
            for key in uniform[pid]:
                np.testing.assert_array_equal(
                    np.asarray(uniform[pid][key]), np.asarray(run[pid][key]),
                    err_msg=f"{name} pid={pid} key={key} diverged under skew",
                )

    # (c) under device-aware scheduling no device ever exceeds its
    # provisioned share by more than one in-flight claim (the blind
    # baseline carries no such bound — any worker may pile onto the hot
    # device, which is exactly the over-subscription being fixed)
    topo = DeviceTopology.round_robin(devices, devices)
    for dev in fleet_r:
        assert dev.max_inflight <= topo.units_per_device[dev.device_id] + 1

    # offloading work off the hot device strictly improves the modeled
    # end-to-end makespan (each device serializes its own ledger)
    assert fleet_r.makespan_s(host_parallelism=devices) < fleet_b.makespan_s(
        host_parallelism=devices)
    # every delivered batch was produced exactly once somewhere
    assert sum(st_r.device_produced.values()) + st_r.host_fallbacks >= partitions


def test_host_fallback_covers_unmanned_devices(rm1):
    """Fewer workers than devices: partitions owned by a device with no
    bound unit are always host-eligible — nothing starves."""
    src, spec, engine = rm1
    fleet = DeviceFleet(4)
    store = PartitionedStore(8, num_devices=4, source=src, fleet=fleet)
    with PreprocessingService(num_workers=2, devices=fleet) as svc:
        sess = svc.submit(JobSpec(
            name="undermanned", partitions=range(8), engine=engine,
            store=store, units=2, queue_depth=8,
        ))
        out = {pid: mb for pid, mb in sess}
        st = sess.stats()
    assert sorted(out) == list(range(8))
    # devices 2 and 3 are unmanned: their partitions went host
    assert st.host_fallbacks >= 4
    assert st.done and not st.cancelled


def test_locality_blind_charges_owner_devices(rm1):
    """The round-robin baseline still runs every produce ON the owning
    device's ledger (classic PreSto placement), so skew shows up as a hot
    busy ledger even without routing."""
    src, spec, engine = rm1
    out, st, fleet = _run_job(
        engine, src, owner_map=[0] * 6 + [1, 2], locality=False,
        partitions=8, devices=4, threshold=100)
    assert st.host_fallbacks == 0
    assert st.device_produced.get(0, 0) == 6
    assert fleet[0].busy_s > fleet[1].busy_s > 0
    assert fleet[3].busy_s == 0.0
