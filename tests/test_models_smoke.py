"""Per-arch REDUCED-config smoke tests (assignment requirement): one
forward/train step on CPU asserting output shapes + no NaNs, for all 10
assigned architectures, plus a decode-step smoke."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch
from repro.distributed.sharding import ShardingRules
from repro.launch.specs import _model_module
from repro.models import transformer as tfm
from repro.train import adamw, make_train_step, warmup_cosine

B, S = 2, 64
RULES = ShardingRules.make(None)


def _batch(cfg, rng):
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                      jnp.float32)
    if cfg.family == "vlm" and cfg.frontend_positions:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_positions, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train_step(arch_id, rng):
    cfg = get_arch(arch_id).reduced
    mod = _model_module(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(warmup_cosine(1e-3, 5, 50))
    loss_fn = lambda p, b: mod.loss_fn(p, b, cfg, RULES)
    step = jax.jit(make_train_step(loss_fn, opt))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = _batch(cfg, rng)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch_id, loss)
    assert int(state["step"]) == 1
    # output (= updated params) finite
    for leaf in jax.tree_util.tree_leaves(state["params"])[:5]:
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # loss-shape sanity: logits head dims
    lv, m = loss_fn(state["params"], batch)
    assert np.isfinite(float(lv))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_decode_step(arch_id, rng):
    cfg = get_arch(arch_id).reduced
    mod = _model_module(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    token = jnp.ones((B, 1), jnp.int32)
    caches = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), mod.cache_spec(cfg, B, S)
    )
    logits, new_caches = jax.jit(
        lambda p, t, c, n: mod.decode_step(p, t, c, n, cfg, RULES)
    )(params, token, caches, jnp.int32(3))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id
    # cache was actually written
    changed = jax.tree.map(
        lambda a, b: bool((np.asarray(a) != np.asarray(b)).any()), caches, new_caches
    )
    assert any(jax.tree_util.tree_leaves(changed)), arch_id


def test_vlm_prefix_changes_loss(rng):
    cfg = get_arch("internvl2-76b").reduced
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    l1, _ = tfm.loss_fn(params, batch, cfg, RULES)
    batch2 = dict(batch, prefix_embeds=batch["prefix_embeds"] * 2.0)
    l2, _ = tfm.loss_fn(params, batch2, cfg, RULES)
    assert float(l1) != float(l2)  # image tokens influence text loss
