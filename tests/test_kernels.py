"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) vs ref.py
oracle vs the numpy encoders in repro.data.encoding."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import encoding as enc
from repro.kernels import ops, ref


@pytest.mark.parametrize("n_feat,rows,m", [(1, 256, 64), (3, 1500, 1000),
                                           (7, 1024, 128), (2, 4096, 4096)])
def test_bucketize_matches_digitize(rng, n_feat, rows, m):
    vals = rng.lognormal(1, 2, size=(n_feat, rows)).astype(np.float32)
    bounds = np.sort(rng.lognormal(1, 2, size=(n_feat, m)).astype(np.float32), -1)
    out = np.asarray(ops.bucketize(vals, bounds))
    for f in range(n_feat):
        np.testing.assert_array_equal(out[f], np.digitize(vals[f], bounds[f]))


def test_bucketize_oracle_agreement(rng):
    vals = rng.normal(size=(2, 777)).astype(np.float32)
    bounds = np.sort(rng.normal(size=(2, 100)).astype(np.float32), -1)
    kern = np.asarray(ops.bucketize(vals, bounds))
    orac = np.asarray(ref.bucketize(jnp.asarray(vals), jnp.asarray(bounds[0])))
    np.testing.assert_array_equal(kern[0], np.asarray(
        ref.bucketize(jnp.asarray(vals[0]), jnp.asarray(bounds[0]))))


@pytest.mark.parametrize("d", [500_000, 123_457, 65_536, 7])
def test_sigridhash_range_and_oracle(rng, d):
    ids = rng.integers(0, 2**31, size=(2, 2048)).astype(np.int32)
    seeds = np.array([1, 99], np.uint32)
    ds = np.array([d, d], np.uint32)
    out = np.asarray(ops.sigridhash(ids, seeds, ds))
    assert out.min() >= 0 and out.max() < d
    for f in range(2):
        expect = np.asarray(ref.sigridhash(jnp.asarray(ids[f]), int(seeds[f]), d))
        np.testing.assert_array_equal(out[f], expect)


def test_sigridhash_deterministic_and_seed_sensitive(rng):
    ids = rng.integers(0, 2**31, size=(1, 1024)).astype(np.int32)
    a = np.asarray(ops.sigridhash(ids, [7], [10_000]))
    b = np.asarray(ops.sigridhash(ids, [7], [10_000]))
    c = np.asarray(ops.sigridhash(ids, [8], [10_000]))
    np.testing.assert_array_equal(a, b)
    assert (a != c).mean() > 0.9  # different seed -> different mapping


@pytest.mark.parametrize("shape", [(8, 1024), (37, 53), (1, 1)])
def test_lognorm(rng, shape):
    x = rng.normal(size=shape).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(ops.lognorm(x)), np.log1p(np.maximum(x, 0)), atol=1e-6
    )


@pytest.mark.parametrize("width", [1, 5, 7, 8, 13, 17, 24, 31, 32])
def test_bitpack_decode_widths(rng, width):
    n = 32 * 300
    hi = (1 << width) if width < 33 else 2**32
    v = rng.integers(0, min(hi, 2**63), size=n, dtype=np.uint64) % hi
    packed = enc.bitpack(v, width)
    grouped = ops.regroup_bitpack(packed, n, width)[None]
    dec = np.asarray(ops.decode_bitpack(grouped, width=width))[0].astype(np.uint32)
    np.testing.assert_array_equal(dec, v.astype(np.uint32))
    orac = np.asarray(ref.bitunpack_grouped(jnp.asarray(grouped[0]), width))
    np.testing.assert_array_equal(orac.reshape(-1), v.astype(np.uint32))


@pytest.mark.parametrize("n", [4 * 128, 4 * 999])
def test_bytesplit_decode(rng, n):
    v = rng.normal(size=n).astype(np.float32)
    words, _ = enc.bytesplit_encode(v)
    grouped = ops.regroup_bytesplit(words, n)[None]
    np.testing.assert_array_equal(np.asarray(ops.decode_bytesplit(grouped))[0], v)


def test_fused_dense_equals_decode_then_log(rng):
    n = 4 * 512
    v = rng.lognormal(1, 2, size=n).astype(np.float32)
    words, _ = enc.bytesplit_encode(v)
    grouped = ops.regroup_bytesplit(words, n)[None]
    fused = np.asarray(ops.fused_dense(grouped))[0]
    unfused = np.asarray(ops.lognorm(ops.decode_bytesplit(grouped)))[0]
    np.testing.assert_array_equal(fused, unfused)
    np.testing.assert_allclose(fused, np.log1p(np.maximum(v, 0)), atol=1e-6)


@pytest.mark.parametrize("width", [13, 24, 31])
def test_fused_sparse_equals_decode_then_hash(rng, width):
    n = 32 * 256
    v = rng.integers(0, 2**width, size=n, dtype=np.uint64)
    packed = enc.bitpack(v, width)
    grouped = ops.regroup_bitpack(packed, n, width)[None]
    fused = np.asarray(ops.fused_sparse(grouped, [3], [99991], width=width))[0]
    dec = ops.decode_bitpack(grouped, width=width)
    unfused = np.asarray(ops.sigridhash(dec, [3], [99991]))[0]
    np.testing.assert_array_equal(fused, unfused)
