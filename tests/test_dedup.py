"""Sample-level dedup (RecD): shared blocks, unique-bytes ledgers, bitwise.

The load-bearing invariant everywhere: a dedup-aware path (encode, solo
preprocess, megabatch, block-cache assembly, spill tier) is bitwise
identical to the undeduped path it replaces — dedup only changes which
bytes move, never which batch comes out.
"""

import dataclasses
import os
import tempfile

import numpy as np
import pytest

from repro.core.costmodel import (
    DEFAULT_PLACEMENT_MODEL,
    ContentionAwareCostModel,
    family_compute_ops,
    partition_costs,
)
from repro.core.featcache import BlockKey, FeatureCache
from repro.core.opgraph import family_page_bytes
from repro.core.preprocess import pages_from_partition, stack_pages
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.simclock import synthetic_costs
from repro.core.spec import TransformSpec
from repro.data.columnar import (
    decode_partition_numpy,
    inflate_partition,
    partition_refs,
    read_partition,
    write_partition,
)
from repro.data.storage import CacheSpillStore, DeviceFleet, PartitionedStore
from repro.data.synth import RM_CONFIGS, SyntheticRecSysSource


def _dedup_cfg(dup_factor=4, dup_pool=0, rows=128, name="rm2"):
    return dataclasses.replace(
        RM_CONFIGS[name],
        rows_per_partition=rows,
        dup_factor=dup_factor,
        dup_pool=dup_pool,
    )


@pytest.fixture(scope="module")
def dedup4():
    cfg = _dedup_cfg(dup_factor=4)
    src = SyntheticRecSysSource(cfg, seed=3)
    return cfg, src, TransformSpec.from_source(src)


# -- columnar round-trip ------------------------------------------------------


def test_dedup_partition_roundtrip_bitwise(dedup4):
    cfg, src, _ = dedup4
    part = src.partition(5)
    raw = src.raw(5)
    assert part.schema.dup_factor == 4
    assert part.schema.unique_rows == cfg.rows_per_partition // 4
    # stored strictly less than logical: sparse pages shrink by ~dup factor
    assert part.nbytes() < part.logical_nbytes()
    dec = decode_partition_numpy(part)
    np.testing.assert_array_equal(dec["sparse_values"]["s0"], raw.sparse_values[:, 0])
    np.testing.assert_array_equal(dec["sparse_lengths"]["s0"], raw.sparse_lengths[:, 0])
    np.testing.assert_allclose(dec["dense"]["d0"], raw.dense[:, 0])
    np.testing.assert_allclose(dec["dense"]["label"], raw.labels)
    np.testing.assert_array_equal(dec["sparse_refs"], raw.sparse_refs)
    # disk round-trip preserves the dedup encoding AND the decode
    with tempfile.TemporaryDirectory() as root:
        path = os.path.join(root, "p5.col")
        write_partition(path, part)
        back = read_partition(path)
        assert back.schema.dup_factor == 4
        assert back.nbytes() == part.nbytes()
        dec2 = decode_partition_numpy(back)
        np.testing.assert_array_equal(
            dec2["sparse_values"]["s0"], dec["sparse_values"]["s0"]
        )
        np.testing.assert_array_equal(dec2["sparse_refs"], dec["sparse_refs"])


def test_dup_factor_one_degenerates_to_classic_layout():
    """dup_factor=1 must be byte-identical to the pre-dedup format."""
    cfg = _dedup_cfg(dup_factor=1)
    src = SyntheticRecSysSource(cfg, seed=3)
    part = src.partition(2)
    assert part.schema.dup_factor == 1
    assert part.schema.unique_rows == cfg.rows_per_partition
    # no refs column, no dup_factor key in the serialized header
    assert all(c.kind != "refs" for c in part.schema.columns)
    assert "dup_factor" not in part.schema.to_json()
    assert part.nbytes() == part.logical_nbytes()
    assert partition_refs(part) is None
    dec = decode_partition_numpy(part)
    assert "sparse_refs" not in dec


def test_inflate_partition_bitwise(dedup4):
    _, src, _ = dedup4
    part = src.partition(1)
    flat = inflate_partition(part)
    assert flat.schema.dup_factor == 1
    assert flat.nbytes() == part.logical_nbytes()
    a, b = decode_partition_numpy(part), decode_partition_numpy(flat)
    for name in a["sparse_values"]:
        np.testing.assert_array_equal(a["sparse_values"][name], b["sparse_values"][name])
        np.testing.assert_array_equal(
            a["sparse_lengths"][name], b["sparse_lengths"][name]
        )
    for name in a["dense"]:
        np.testing.assert_array_equal(a["dense"][name], b["dense"][name])


def test_dedup_blocks_repeat_within_session(dedup4):
    """The duplication model: refs tile each unique block dup_factor times."""
    _, src, _ = dedup4
    raw = src.raw(0)
    refs = raw.sparse_refs
    assert refs is not None and refs.shape == (src.rows,)
    np.testing.assert_array_equal(refs, np.arange(src.rows) // 4)
    # every sample in a block carries the same sparse features
    for b in range(src.rows // 4):
        rows = slice(4 * b, 4 * b + 4)
        np.testing.assert_array_equal(
            raw.sparse_values[rows], np.broadcast_to(
                raw.sparse_values[4 * b], raw.sparse_values[rows].shape
            )
        )


# -- engine bitwise across every lowering -------------------------------------


@pytest.mark.parametrize("kernel_mode", ["fused", "unfused", "hybrid"])
def test_execute_plan_bitwise_vs_inflated(dedup4, kernel_mode):
    _, src, spec = dedup4
    eng = PreStoEngine(spec, interpret=True, kernel_mode=kernel_mode)
    part = src.partition(0)
    got = eng.preprocess_local(pages_from_partition(part, spec))
    ref = eng.lowered_plan.execute(
        pages_from_partition(inflate_partition(part), spec)
    )
    for k in ref:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(ref[k]))


def test_megabatch_bitwise_vs_solo(dedup4):
    _, src, spec = dedup4
    eng = PreStoEngine(spec, interpret=True)
    pages = [pages_from_partition(src.partition(p), spec) for p in (0, 1, 2)]
    mega = eng.preprocess_megabatch(stack_pages(pages))
    assert len(mega) == 3
    for i, pg in enumerate(pages):
        solo = eng.preprocess_local(pg)
        for k in solo:
            np.testing.assert_array_equal(np.asarray(mega[i][k]), np.asarray(solo[k]))


def test_pages_struct_matches_dedup_pages(dedup4):
    cfg, src, spec = dedup4
    eng = PreStoEngine(spec, interpret=True)
    pages = pages_from_partition(src.partition(0), spec)
    structs = eng.pages_struct(cfg.rows_per_partition)
    assert set(structs) == set(pages)
    for k, s in structs.items():
        assert tuple(s.shape) == tuple(pages[k].shape), k
        assert np.dtype(s.dtype) == pages[k].dtype, k


# -- ledgers: unique bytes charged, logical reported --------------------------


def test_store_charges_unique_bytes_under_skewed_ownership(dedup4):
    _, src, _ = dedup4
    fleet = DeviceFleet(4)
    # skew: device 0 owns 6 of 8 partitions
    owner_map = [0, 0, 0, 0, 0, 0, 1, 2]
    store = PartitionedStore(
        8, num_devices=4, source=src, fleet=fleet, owner_map=owner_map
    )
    parts = [store.read(p) for p in range(8)]
    unique = sum(p.nbytes() for p in parts)
    logical = sum(p.logical_nbytes() for p in parts)
    assert store.bytes_read == unique
    assert store.logical_bytes_read == logical
    assert unique < logical
    # the owning devices streamed exactly the UNIQUE bytes, skew preserved
    per_dev = [0] * 4
    for pid, p in enumerate(parts):
        per_dev[owner_map[pid]] += p.nbytes()
    for d in range(4):
        assert fleet[d].bytes_streamed == per_dev[d]
    assert per_dev[3] == 0 and per_dev[0] > per_dev[1]


def test_spill_store_row_dedup_roundtrip_and_charging():
    store = CacheSpillStore(num_devices=2)
    rng = np.random.default_rng(0)
    uniq = rng.integers(0, 1 << 20, size=(8, 64), dtype=np.int64)
    ids = uniq[np.arange(64) % 8]  # heavy row duplication
    flo = rng.random((64, 4)).astype(np.float32)  # floats: never row-deduped
    block = {"multi_hot_ids": ids, "dense": flo}
    written = store.write("k0", block)
    raw = ids.nbytes + flo.nbytes
    assert written < raw  # stored deduped: unique rows + refs
    assert store.bytes_written == written
    back = store.read("k0")
    np.testing.assert_array_equal(back["multi_hot_ids"], ids)
    np.testing.assert_array_equal(back["dense"], flo)
    assert store.bytes_read == written  # reads charge stored bytes too


# -- cost model: unique bytes/ops priced --------------------------------------


def test_costmodel_prices_unique_bytes_and_ops(dedup4):
    cfg, _, spec = dedup4
    flat_spec = TransformSpec.from_source(
        SyntheticRecSysSource(dataclasses.replace(cfg, dup_factor=1), seed=3)
    )
    rows = cfg.rows_per_partition
    pb_d, pb_f = family_page_bytes(spec, rows), family_page_bytes(flat_spec, rows)
    assert pb_d["sparse"] < pb_f["sparse"]
    assert pb_d["lengths"] < pb_f["lengths"]
    assert pb_d["dense"] == pb_f["dense"]  # dense is per-sample, unchanged
    ops_d, ops_f = family_compute_ops(spec, rows), family_compute_ops(flat_spec, rows)
    assert ops_d["sparse"] < ops_f["sparse"]  # hash at unique rows + gather
    c_d = partition_costs(spec, rows)
    c_f = partition_costs(flat_spec, rows)
    assert c_d.page_bytes < c_f.page_bytes
    assert c_d.ops < c_f.ops
    assert c_d.isp_s < c_f.isp_s
    assert c_d.batch_bytes == c_f.batch_bytes  # output tensors are logical


def test_simclock_costs_calibrate_from_spec(dedup4):
    cfg, _, spec = dedup4
    model = ContentionAwareCostModel()
    got = synthetic_costs(model, spec=spec, rows=cfg.rows_per_partition)
    assert got == partition_costs(spec, cfg.rows_per_partition, model)
    # no spec: the round synthetic defaults, unchanged
    dflt = synthetic_costs(model)
    assert dflt.page_bytes == 48 << 20


# -- block fingerprints + block cache tier ------------------------------------


def test_store_block_fingerprints_source_and_file(dedup4):
    _, src, _ = dedup4
    store = PartitionedStore(4, num_devices=2, source=src)
    fps = store.block_fingerprints(0)
    refs = store.block_refs(0)
    assert fps is not None and len(fps) == src.rows // 4
    np.testing.assert_array_equal(refs, np.arange(src.rows) // 4)
    assert fps == store.block_fingerprints(0)  # cached, stable
    # classic data: no block identity
    flat = SyntheticRecSysSource(_dedup_cfg(dup_factor=1), seed=3)
    assert PartitionedStore(4, num_devices=2, source=flat).block_fingerprints(0) is None
    # file-backed: content-hashed fps, equal content => equal fp
    with tempfile.TemporaryDirectory() as root:
        dstore = PartitionedStore(4, num_devices=2, source=src, root=root)
        dstore.materialize(range(2))
        ffps = dstore.block_fingerprints(0)
        assert ffps is not None and len(ffps) == len(fps)
        assert len(set(ffps)) == len(ffps)  # no pool: all blocks distinct


def test_pool_blocks_overlap_across_partitions():
    cfg = _dedup_cfg(dup_factor=4, dup_pool=8)
    src = SyntheticRecSysSource(cfg, seed=3)
    store = PartitionedStore(4, num_devices=2, source=src)
    a = set(store.block_fingerprints(0))
    b = set(store.block_fingerprints(1))
    assert a and a <= set(store.block_fingerprints(0))
    assert a & b, "pooled datasets must share blocks across partitions"
    assert len(a | b) <= 8  # at most the pool size


def test_feature_cache_block_tier():
    cache = FeatureCache(capacity_bytes=1 << 20, block_capacity_bytes=1 << 16)
    rng = np.random.default_rng(0)
    keys = [BlockKey(f"fp{i}", "plan", "presto") for i in range(4)]
    blocks = [
        (
            rng.integers(0, 100, size=(2, 8), dtype=np.int32),
            rng.integers(0, 8, size=(2,), dtype=np.int32),
        )
        for _ in range(4)
    ]
    for k, (ids, lens) in zip(keys, blocks):
        cache.put_block(k, ids, lens)
    got = cache.get_block(keys[1])
    np.testing.assert_array_equal(got[0], blocks[1][0])
    # all-or-nothing gather: full coverage stacks in ref order
    stacked = cache.get_blocks([keys[0], keys[2], keys[0]])
    assert stacked is not None
    ids, lens = stacked
    assert ids.shape == (3, 2, 8) and lens.shape == (3, 2)
    np.testing.assert_array_equal(ids[0], blocks[0][0])
    np.testing.assert_array_equal(ids[1], blocks[2][0])
    np.testing.assert_array_equal(ids[2], blocks[0][0])
    assert cache.get_blocks([keys[0], BlockKey("nope", "plan", "presto")]) is None
    st = cache.stats()
    assert st.block_insertions >= 4 and st.block_hits >= 4 and st.block_misses >= 1
    # LRU bound: a tiny block budget evicts, never overflows
    tiny = FeatureCache(capacity_bytes=1 << 20, block_capacity_bytes=256)
    big_ids = np.zeros((2, 16), np.int32)
    big_lens = np.zeros((2,), np.int32)
    for i in range(8):
        tiny.put_block(BlockKey(f"b{i}", "p", "x"), big_ids, big_lens)
    ts = tiny.stats()
    assert ts.block_resident_bytes <= 256
    assert ts.block_entries < 8


def test_service_cross_tenant_block_assembly():
    """Tenant B's batches assemble from tenant A's published blocks — and
    stay bitwise identical to a cold single-tenant run."""
    cfg = _dedup_cfg(dup_factor=4, dup_pool=16)
    src = SyntheticRecSysSource(cfg, seed=3)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(16, num_devices=2, source=src)
    eng = PreStoEngine(spec, interpret=True)
    svc = PreprocessingService(num_workers=2, cache=FeatureCache(capacity_bytes=64 << 20))
    try:
        # tenant A runs the self-tuning megabatched worker path: dedup pages
        # must stay bitwise through coalesced launches and the tuner too
        sA = svc.submit(
            JobSpec(name="A", spec=spec, store=store, engine=eng,
                    partitions=range(8), megabatch=4, autotune=True)
        )
        outA = dict(iter(sA))
        sB = svc.submit(
            JobSpec(name="B", spec=spec, store=store, engine=eng, partitions=range(8, 16))
        )
        outB = dict(iter(sB))
        stA, stB = sA.stats(), sB.stats()
    finally:
        svc.close()
    assert stA.blocks_published > 0
    assert stB.block_hits > 0  # cross-tenant: B never produced cold
    assert stB.block_hits == stB.cache_hits  # block assemblies count as hits
    ref = PreStoEngine(spec, interpret=True, use_exec_cache=False)
    for pid in range(16):
        want = ref.lowered_plan.execute(
            pages_from_partition(inflate_partition(src.partition(pid)), spec)
        )
        got = outA[pid] if pid in outA else outB[pid]
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))
    # the store charged unique bytes throughout
    assert store.bytes_read < store.logical_bytes_read
