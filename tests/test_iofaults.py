"""Storage fault domain: I/O fault injection, integrity, retry/failover.

The acceptance invariants (ISSUE 10):

* a seeded ``IoFaultInjector`` deterministically throws transient read
  errors, torn (bit-flipped) blocks, slow reads, spill-block corruption,
  and whole-device-offline into ``PartitionedStore.read`` and
  ``CacheSpillStore`` get/put;
* end-to-end integrity: every delivered read is verified against the
  trusted content digest — a corrupted block is RAISED (and a corrupt
  cached block dropped + recomputed cold), never silently delivered, so a
  session under faults yields batches bitwise identical to a fault-free
  run;
* the claim path absorbs retryable faults with bounded exponential-backoff
  retries, re-routes an offline device's partitions through the store's
  failover path, and quarantines a persistently failing partition with a
  structured ``SessionError`` (never a hang), all visible in ``stats()``
  and the event stream;
* torn checkpoints and unreadable/corrupt spill blocks are detected and
  skipped — boot (``warm_start``) survives garbage on disk.
"""

import dataclasses
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.configs.registry import get_recsys
from repro.core.ctrlplane import SessionCheckpoint, SessionError
from repro.core.featcache import FeatureCache, default_spill_store
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.simclock import VirtualClock
from repro.core.spec import TransformSpec
from repro.data import columnar
from repro.data.columnar import (
    CorruptPartitionFile,
    partition_digest,
    read_partition,
    write_partition,
)
from repro.data.loader import SessionQueue, WorkQueue
from repro.data.storage import (
    CacheSpillStore,
    CorruptPartitionError,
    DeviceFleet,
    DeviceOfflineError,
    IoFaultInjector,
    PartitionedStore,
    TransientReadError,
    parse_iofault_spec,
)
from repro.data.synth import SyntheticRecSysSource

N_PARTS = 8

# the produce-path modes the bitwise-under-faults invariant must hold across
MODES = {
    "pipeline": dict(megabatch=2, lookahead=2),
    "autotune": dict(autotune=True),
    "cache": dict(megabatch=2),
}


@pytest.fixture(scope="module")
def rm1():
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=192)
    spec = TransformSpec.from_source(src)
    engine = PreStoEngine(spec)  # one jit cache across every run here
    ref_store = PartitionedStore(N_PARTS, num_devices=4, source=src)
    # the fault-free ground truth every injected run must match bitwise
    ref = {pid: engine.produce_batch(ref_store, pid) for pid in range(N_PARTS)}
    return {"rcfg": rcfg, "src": src, "spec": spec, "engine": engine, "ref": ref}


def _assert_bitwise(got: dict, ref: dict) -> None:
    assert sorted(got) == sorted(ref)
    for pid, batch in got.items():
        want = ref[pid]
        assert sorted(batch) == sorted(want)
        for key in want:
            np.testing.assert_array_equal(
                np.asarray(batch[key]), np.asarray(want[key])
            )


class _Events:
    """Duck-typed EventLog stand-in for data-layer observers."""

    def __init__(self):
        self.kinds = []

    def emit(self, kind, **data):
        self.kinds.append(kind)


# -- spec parsing --------------------------------------------------------------


def test_parse_iofault_spec_full():
    inj = parse_iofault_spec(
        "transient=0.2,corrupt=0.1,spill=0.3,slow=0.05:0.01,offline=2@6,seed=7"
    )
    assert inj.transient == 0.2 and inj.corrupt == 0.1 and inj.spill == 0.3
    assert inj.slow == 0.05 and inj.slow_s == 0.01
    assert inj.offline_device == 2 and inj.offline_after == 6
    assert inj.seed == 7
    # slow without an explicit latency keeps the default
    assert parse_iofault_spec("slow=0.5").slow_s > 0


@pytest.mark.parametrize(
    "bad", ["transient", "transient=x", "offline=2", "offline=a@b", "nope=1"]
)
def test_parse_iofault_spec_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_iofault_spec(bad)


# -- injector determinism ------------------------------------------------------


def test_injector_same_seed_same_schedule():
    def schedule(seed):
        inj = IoFaultInjector(seed=seed, transient=0.5, spill=0.5)
        fails = [inj.on_spill_read(f"k{i}") for i in range(32)]
        arrays = {"a": np.arange(64, dtype=np.int32)}
        corrupted = []
        for i in range(16):
            got = inj.maybe_corrupt_spill(f"w{i}", dict(arrays))
            corrupted.append(not np.array_equal(got["a"], arrays["a"]))
        return fails, corrupted

    assert schedule(3) == schedule(3)
    assert schedule(3) != schedule(4)  # and the seed actually matters


# -- partition reads: transient / corrupt / offline ----------------------------


def test_transient_read_retries_to_bitwise_clean_bytes(rm1):
    inj = IoFaultInjector(seed=5, transient=0.5)
    store = PartitionedStore(
        N_PARTS, num_devices=4, source=rm1["src"], fault_injector=inj
    )
    clean = PartitionedStore(N_PARTS, num_devices=4, source=rm1["src"])
    transients = 0
    for pid in range(N_PARTS):
        for _attempt in range(64):
            try:
                part = store.read(pid)
                break
            except TransientReadError:
                transients += 1
        else:
            pytest.fail(f"pid {pid} never read through transient=0.5")
        # a read that SUCCEEDS delivers exactly the clean bytes
        assert partition_digest(part) == partition_digest(clean.read(pid))
    assert transients > 0, "transient=0.5 over 8 partitions injected nothing"
    assert inj.summary().get("transient", 0) == transients


def test_torn_read_detected_never_delivered(rm1):
    inj = IoFaultInjector(seed=2, corrupt=1.0)
    store = PartitionedStore(
        N_PARTS, num_devices=4, source=rm1["src"], fault_injector=inj
    )
    # every attempt corrupts: the digest check must catch every one
    for _ in range(4):
        with pytest.raises(CorruptPartitionError) as ei:
            store.read(0)
        assert ei.value.retryable  # torn read: a retry CAN succeed
    # at corrupt=0.5 a retry loop eventually lands a verified-clean read
    inj2 = IoFaultInjector(seed=2, corrupt=0.5)
    store2 = PartitionedStore(
        N_PARTS, num_devices=4, source=rm1["src"], fault_injector=inj2
    )
    clean = PartitionedStore(N_PARTS, num_devices=4, source=rm1["src"])
    for _ in range(64):
        try:
            part = store2.read(1)
            break
        except CorruptPartitionError:
            continue
    else:
        pytest.fail("never read through corrupt=0.5")
    assert partition_digest(part) == partition_digest(clean.read(1))


def test_slow_read_charges_injected_latency(rm1):
    slept = []
    inj = IoFaultInjector(seed=1, slow=1.0, slow_s=0.25, sleep=slept.append)
    store = PartitionedStore(
        N_PARTS, num_devices=4, source=rm1["src"], fault_injector=inj
    )
    store.read(0)
    assert slept == [0.25]
    # the virtual clock is a drop-in sleep: no real time passes
    clock = VirtualClock()
    inj2 = IoFaultInjector(seed=1, slow=1.0, slow_s=3.0, sleep=clock.sleep)
    store2 = PartitionedStore(
        N_PARTS, num_devices=4, source=rm1["src"], fault_injector=inj2
    )
    t0 = time.perf_counter()
    store2.read(0)
    assert clock.now() == 3.0 and time.perf_counter() - t0 < 1.0


def test_device_offline_then_failover_reads_charge_host(rm1):
    fleet = DeviceFleet(4)
    inj = IoFaultInjector(seed=0, offline_device=1, offline_after=1)
    store = PartitionedStore(
        N_PARTS, num_devices=4, source=rm1["src"], fleet=fleet,
        fault_injector=inj,
    )
    pid = store.partitions_of(1)[0]
    with pytest.raises(DeviceOfflineError) as ei:
        store.read(pid)  # the triggering read itself finds the device dark
    assert ei.value.device == 1 and not fleet[1].offline is False
    assert fleet[1].offline is True
    # other devices' partitions read straight through
    other = store.partitions_of(0)[0]
    store.read(other)
    # failover: the replica read succeeds and crosses the HOST link
    assert not store.is_failover(pid)
    store.allow_failover(pid)
    assert store.failover_partitions == [pid]
    host0 = fleet.host_link_bytes
    part = store.read(pid)
    assert fleet.host_link_bytes > host0
    clean = PartitionedStore(N_PARTS, num_devices=4, source=rm1["src"])
    assert partition_digest(part) == partition_digest(clean.read(pid))
    assert inj.summary().get("device_offline") == 1  # fire-once


def test_at_rest_corruption_is_nonretryable(rm1, tmp_path):
    store = PartitionedStore(
        N_PARTS, num_devices=4, source=rm1["src"], root=str(tmp_path),
        fault_injector=IoFaultInjector(seed=0),
    )
    store.materialize([0])
    path = store._path(0)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(CorruptPartitionError) as ei:
        store.read(0)
    assert not ei.value.retryable  # same bytes fail identically: no retry


# -- columnar decode hardening -------------------------------------------------


def test_columnar_roundtrip_carries_checksum(rm1, tmp_path):
    part = rm1["src"].partition(0)
    path = str(tmp_path / "p0.col")
    write_partition(path, part)
    got = read_partition(path)
    assert partition_digest(got) == partition_digest(part)
    with open(path, "rb") as f:
        f.read(8)
        hlen = int.from_bytes(f.read(4), "little")
        header = json.loads(f.read(hlen))
    assert "checksum" in header


def test_columnar_rejects_truncated_bad_magic_and_bitflips(rm1, tmp_path):
    part = rm1["src"].partition(0)
    path = str(tmp_path / "p0.col")
    write_partition(path, part)
    blob = open(path, "rb").read()
    hlen = int.from_bytes(blob[8:12], "little")
    body_start = 12 + hlen

    def write_and_read(payload: bytes):
        bad = str(tmp_path / "bad.col")
        with open(bad, "wb") as f:
            f.write(payload)
        return read_partition(bad)

    for cut in (0, 4, 11, body_start - 1, len(blob) - 1):
        with pytest.raises(CorruptPartitionFile):
            write_and_read(blob[:cut])  # truncation at every layer
    with pytest.raises(CorruptPartitionFile):
        write_and_read(b"NOTMAGIC" + blob[8:])
    # a bit flip anywhere in the page payload trips the body checksum —
    # never a silent mis-decode
    step = max(1, (len(blob) - body_start) // 16)
    for off in range(body_start, len(blob), step):
        flipped = bytearray(blob)
        flipped[off] ^= 0x01
        with pytest.raises(CorruptPartitionFile):
            write_and_read(bytes(flipped))


# -- spill-block integrity -----------------------------------------------------


def _block():
    return {
        "dense": np.arange(48, dtype=np.float32).reshape(4, 12),
        "ids": np.arange(64, dtype=np.int32),
    }


@pytest.mark.parametrize("rooted", [False, True], ids=["memory", "rooted"])
def test_spill_corrupt_block_dropped_not_served(tmp_path, rooted):
    spill = CacheSpillStore(4, root=str(tmp_path / "sp") if rooted else None)
    spill.events = _Events()
    spill.fault_injector = IoFaultInjector(seed=0, spill=1.0)
    spill.write("blk", _block())
    assert "blk" in spill
    assert spill.read("blk") is None  # detected, dropped, a plain miss
    assert spill.corrupt_drops == 1 and "blk" not in spill
    assert "spill_corrupt" in spill.events.kinds
    # a clean store round-trips bitwise
    clean = CacheSpillStore(4, root=str(tmp_path / "cl") if rooted else None)
    clean.write("blk", _block())
    got = clean.read("blk")
    for k, v in _block().items():
        np.testing.assert_array_equal(got[k], v)


def test_spill_transient_read_fault_is_a_miss(tmp_path):
    spill = CacheSpillStore(4, root=str(tmp_path))
    spill.fault_injector = IoFaultInjector(seed=1, transient=1.0)
    spill.write("blk", _block())
    assert spill.read("blk") is None  # failed read = miss, never an exception
    assert "blk" in spill  # the block itself is intact for a later retry
    spill.fault_injector = None
    assert spill.read("blk") is not None


def test_warm_start_skips_garbage_npz(tmp_path):
    root = str(tmp_path)
    # warm_start only promotes 3-part CacheKey names: use job-pid-sig keys
    good, bad = "job-1-good", "job-0-bad"
    seeder = CacheSpillStore(4, root=root)
    seeder.write(good, _block())
    # hand-plant an unreadable block where the rescan will find it
    bad_dir = os.path.join(root, f"device{seeder.owner_of(bad):03d}")
    os.makedirs(bad_dir, exist_ok=True)
    with open(os.path.join(bad_dir, f"cache_{bad}.npz"), "wb") as f:
        f.write(b"this is not an npz archive")
    spill = CacheSpillStore(4, root=root)  # restart: rescan indexes both
    spill.events = _Events()
    assert len(spill) == 2
    cache = FeatureCache(1 << 30, spill=spill)
    warmed = cache.warm_start()  # must not raise on the garbage block
    assert warmed == 1
    assert spill.corrupt_drops == 1 and bad not in spill
    assert "spill_corrupt" in spill.events.kinds


# -- checkpoint atomicity ------------------------------------------------------


def test_checkpoint_save_is_atomic_and_load_rejects_torn(tmp_path):
    ck = SessionCheckpoint(
        job="j", partitions=[0, 1, 2], delivered=[0], stats={"delivered": 1}
    )
    path = str(tmp_path / "ck.json")
    ck.save(path)
    assert not [p for p in os.listdir(tmp_path) if ".tmp." in p]  # no litter
    got = SessionCheckpoint.load(path)
    assert got.job == "j" and got.delivered == [0]
    raw = open(path).read()
    with open(path, "w") as f:
        f.write(raw[: len(raw) // 2])  # a torn write (crash mid-flush)
    with pytest.raises(ValueError, match="torn or truncated"):
        SessionCheckpoint.load(path)
    with open(path, "w") as f:
        f.write("[1, 2, 3]")  # valid JSON, not a checkpoint
    with pytest.raises(ValueError):
        SessionCheckpoint.load(path)


# -- queue requeue / embargo ---------------------------------------------------


def test_workqueue_requeue_embargo_and_deadline():
    t = [0.0]
    q = WorkQueue([0, 1], straggler_timeout=60.0, clock=lambda: t[0])
    assert q.claim() == 0
    assert q.requeue(0, delay=5.0) is True
    assert not q.exhausted  # a requeued pid keeps the session alive
    assert q.claim() == 1  # 0 is embargoed; fresh work drains meanwhile
    assert q.claim() is None
    assert q.next_deadline() == 5.0  # the embargo expiry is the next wake
    t[0] = 5.0
    assert q.claim() == 0 and q.requeues == 1
    q.complete(0)
    q.complete(1)
    assert q.requeue(0) is False  # done: nothing to retry
    assert q.exhausted


def test_workqueue_requeue_rejects_pending_and_unclaimed():
    q = WorkQueue([0, 1], straggler_timeout=60.0)
    assert q.requeue(0) is False  # never claimed
    assert q.claim() == 0
    assert q.requeue(0) is True
    assert q.requeue(0) is False  # already pending again (twin raced)


def test_sessionqueue_requeued_claim_bypasses_backpressure():
    sq = SessionQueue([0, 1, 2], depth=1)
    pid, fut, _ = sq.claim()
    assert pid == 0
    # depth=1 and one undelivered claim: fresh work is backpressured...
    assert sq.claim() is None
    # ...but a fault-retry requeue is NOT fresh — its future already exists
    # and the consumer may be blocked on exactly this pid (liveness)
    assert sq.requeue(0) is True
    pid2, fut2, _ = sq.claim()
    assert pid2 == 0 and fut2 is fut
    assert sq.complete(0, {"labels": np.zeros((1,))})
    assert fut.result()[0] == 0


# -- service-level chaos matrix ------------------------------------------------


def _run_faulted(rm1, tag, inj, *, cache=None, io_retries=4, **job_kw):
    fleet = DeviceFleet(4)
    store = PartitionedStore(
        N_PARTS, num_devices=4, source=rm1["src"], fleet=fleet,
        fault_injector=inj,
    )
    svc = PreprocessingService(num_workers=3, devices=fleet, cache=cache)
    try:
        session = svc.submit(JobSpec(
            name=tag, partitions=range(N_PARTS), engine=rm1["engine"],
            store=store, io_retries=io_retries, io_backoff_s=0.002, **job_kw,
        ))
        got = dict(session)
        return got, session.stats(), svc.events.counts()
    finally:
        svc.close()


@pytest.mark.parametrize("mode", sorted(MODES))
def test_session_bitwise_identical_under_io_faults(rm1, mode):
    inj = IoFaultInjector(
        seed=13, transient=0.3, corrupt=0.2, spill=0.5, slow=0.2, slow_s=1e-4,
        offline_device=1, offline_after=N_PARTS,
    )
    cache = None
    if mode == "cache":
        # a tiny memory tier forces evictions into the (corruptible) spill
        # store; corrupt spill hits must recompute cold, never mis-serve
        spill = default_spill_store(4)
        spill.fault_injector = inj
        cache = FeatureCache(1 << 16, spill=spill)
    got, st, events = _run_faulted(rm1, f"chaos-{mode}", inj,
                                   cache=cache, **MODES[mode])
    _assert_bitwise(got, rm1["ref"])
    assert st.done and not st.cancelled and st.quarantined == 0
    assert sum(inj.summary().values()) > 0, "the drill injected nothing"
    if st.retries:
        assert events.get("retry", 0) >= 1  # every retry is observable
    if mode == "cache":
        # a second tenant over the same store content re-probes the cache —
        # corrupt spill blocks must yield recomputes, still bitwise clean
        got2, st2, _ = _run_faulted(rm1, "chaos-cache-2", inj, cache=cache,
                                    **MODES[mode])
        _assert_bitwise(got2, rm1["ref"])
        assert st2.quarantined == 0


def test_session_chaos_matrix_records_retries_somewhere(rm1):
    """At these rates the seeded schedule must retry at least once overall
    (per-mode counts may legitimately be zero — determinism is per seed)."""
    total = 0
    for i, (mode, kw) in enumerate(sorted(MODES.items())):
        inj = IoFaultInjector(seed=100 + i, transient=0.4, corrupt=0.2)
        _got, st, _ev = _run_faulted(rm1, f"retry-{mode}", inj, **kw)
        total += st.retries
    assert total > 0


def test_quarantine_raises_structured_error_without_hanging(rm1):
    inj = IoFaultInjector(seed=7, transient=1.0)
    fleet = DeviceFleet(4)
    store = PartitionedStore(
        N_PARTS, num_devices=4, source=rm1["src"], fleet=fleet,
        fault_injector=inj,
    )
    svc = PreprocessingService(num_workers=2, devices=fleet)
    try:
        session = svc.submit(JobSpec(
            name="poison", partitions=range(N_PARTS), engine=rm1["engine"],
            store=store, io_retries=2, io_backoff_s=1e-3,
        ))
        t0 = time.perf_counter()
        with pytest.raises(SessionError) as ei:
            for _ in session:
                pass
        elapsed = time.perf_counter() - t0
        assert elapsed < 30.0, "quarantine took implausibly long"
        err = ei.value
        assert err.job == "poison" and err.attempts == 2
        assert isinstance(err.cause, TransientReadError)
        st = session.stats()
        assert st.quarantined >= 1 and st.retries >= 2
        assert svc.events.counts().get("quarantine", 0) >= 1
        session.cancel()
    finally:
        svc.close()


def test_offline_device_fails_over_and_completes(rm1):
    inj = IoFaultInjector(seed=3, offline_device=1, offline_after=1)
    got, st, events = _run_faulted(rm1, "failover", inj, megabatch=2)
    _assert_bitwise(got, rm1["ref"])
    assert st.failovers >= 1 and st.quarantined == 0
    assert events.get("device_offline", 0) == 1
    assert events.get("failover", 0) >= 1


def test_dedup_session_bitwise_identical_under_io_faults(rm1):
    data_cfg = dataclasses.replace(rm1["rcfg"].data, dup_factor=2, dup_pool=8)
    src = SyntheticRecSysSource(data_cfg, rows=192)
    spec = TransformSpec.from_source(src)
    engine = PreStoEngine(spec)
    ref_store = PartitionedStore(N_PARTS, num_devices=4, source=src)
    ref = {p: engine.produce_batch(ref_store, p) for p in range(N_PARTS)}
    inj = IoFaultInjector(seed=21, transient=0.3, corrupt=0.2)
    fleet = DeviceFleet(4)
    store = PartitionedStore(
        N_PARTS, num_devices=4, source=src, fleet=fleet, fault_injector=inj
    )
    svc = PreprocessingService(num_workers=3, devices=fleet)
    try:
        session = svc.submit(JobSpec(
            name="dedup-chaos", partitions=range(N_PARTS), engine=engine,
            store=store, megabatch=2, io_retries=4, io_backoff_s=0.002,
        ))
        got = dict(session)
        st = session.stats()
    finally:
        svc.close()
    _assert_bitwise(got, ref)
    assert st.done and st.quarantined == 0


def test_injector_events_wired_to_service_stream(rm1):
    inj = IoFaultInjector(seed=13, transient=0.5)
    assert inj.events is None
    _got, st, events = _run_faulted(rm1, "wired", inj)
    assert inj.events is not None  # Session.__init__ bound it
    if st.retries:
        assert events.get("io_fault", 0) >= 1
